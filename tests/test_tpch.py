"""W5: TPC-H-style query results vs numpy oracles."""
import numpy as np
import pytest

from repro.analytics.tpch import DATE1, generate, run_query


@pytest.fixture(scope="module")
def data():
    return generate(scale=0.004, seed=1)


def test_q1_oracle(data):
    li = data.tables["lineitem"]
    m = li["l_shipdate"] <= DATE1 - 90
    g = li["l_returnflag"] * 2 + li["l_linestatus"]
    out = run_query("q1", data)
    for i in range(6):
        sel = (g == i) & m
        np.testing.assert_allclose(np.asarray(out["sum_qty"])[i],
                                   li["l_quantity"][sel].sum(), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(out["count_order"])[i],
                                   sel.sum(), rtol=1e-6)


def test_q6_oracle(data):
    li = data.tables["lineitem"]
    m = ((li["l_shipdate"] >= 0) & (li["l_shipdate"] < 365)
         & (np.abs(li["l_discount"] - 0.06) <= 0.011)
         & (li["l_quantity"] < 24))
    ref = (li["l_extendedprice"][m] * li["l_discount"][m]).sum()
    got = float(run_query("q6", data)["revenue"][0])
    assert abs(got - ref) / max(ref, 1) < 1e-5


def test_q18_oracle(data):
    li = data.tables["lineitem"]
    orders = data.tables["orders"]
    qty = np.zeros(len(orders["o_orderkey"]), np.float32)
    np.add.at(qty, li["l_orderkey"], li["l_quantity"])
    big = qty > 212.0
    ref_count = big.sum()
    out = run_query("q18", data)
    got_orders = (np.asarray(out["_count"]) > 0).sum()
    # every qualifying order maps to one customer row contribution
    assert int(np.asarray(out["_count"]).sum()) == int(ref_count)
    assert got_orders <= ref_count


def test_q3_returns_top10(data):
    out = run_query("q3", data)
    rev = np.asarray(out["revenue"])
    assert rev.shape == (10,)
    assert (np.diff(rev) <= 1e-3).all()  # descending


def test_q5_group_count(data):
    out = run_query("q5", data)
    assert np.asarray(out["revenue"]).shape == (25,)
    assert np.asarray(out["revenue"]).sum() > 0
