"""W1-W4 analytics operators vs numpy oracles + property tests."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.analytics.aggregate import (count_direct, count_partitioned,
                                       median_direct)
from repro.analytics.datasets import (AGG_DATASETS, blanas_join,
                                      heavy_hitter, moving_cluster,
                                      sequential, zipf)
from repro.analytics.join import hash_join, index_join


def _median_oracle(keys, vals, G):
    out = np.full(G, np.nan, np.float32)
    for g in np.unique(keys):
        v = np.sort(vals[keys == g])
        out[g] = (v[(len(v) - 1) // 2] + v[len(v) // 2]) / 2
    return out


@pytest.mark.parametrize("gen", sorted(AGG_DATASETS))
def test_count_all_datasets(gen):
    ds = AGG_DATASETS[gen](8192, 256, seed=3)
    ref = np.bincount(ds.keys, minlength=256).astype(np.float32)
    got = np.asarray(count_direct(jnp.asarray(ds.keys), 256))
    np.testing.assert_array_equal(got, ref)
    got_p, ovf = count_partitioned(jnp.asarray(ds.keys), 256,
                                   n_partitions=8, capacity_factor=4.0,
                                   mode="ref")
    if int(ovf) == 0:
        np.testing.assert_array_equal(np.asarray(got_p), ref)


@pytest.mark.parametrize("gen", ["moving_cluster", "zipf", "heavy_hitter"])
def test_median_all_datasets(gen):
    ds = AGG_DATASETS[gen](4096, 128, seed=4)
    ref = _median_oracle(ds.keys, ds.vals, 128)
    got = np.asarray(median_direct(jnp.asarray(ds.keys),
                                   jnp.asarray(ds.vals), 128))
    np.testing.assert_allclose(got, ref, atol=1e-6, equal_nan=True)


def test_hash_join_blanas(rng):
    jd = blanas_join(1024, 16384, seed=5)
    lookup = dict(zip(jd.build_keys.tolist(), jd.build_vals.tolist()))
    ref_sum = float(sum(lookup[k] for k in jd.probe_keys.tolist()))
    cnt, chk, ovf = hash_join(jnp.asarray(jd.build_keys),
                              jnp.asarray(jd.build_vals),
                              jnp.asarray(jd.probe_keys),
                              n_partitions=8, mode="ref")
    assert int(ovf) == 0
    assert int(cnt) == len(jd.probe_keys)
    assert abs(float(chk) - ref_sum) / ref_sum < 1e-4


def test_hash_join_with_misses(rng):
    bk = jnp.asarray(np.arange(0, 512, 2), jnp.int32)   # even keys only
    bv = jnp.ones((256,), jnp.float32)
    pk = jnp.asarray(np.arange(512), jnp.int32)          # half miss
    cnt, chk, ovf = hash_join(bk, bv, pk, n_partitions=4,
                              capacity_factor=4.0, mode="ref")
    assert int(cnt) == 256
    assert abs(float(chk) - 256.0) < 1e-3


@pytest.mark.parametrize("kind", ["radix", "sorted", "hash"])
def test_index_join_kinds(kind):
    jd = blanas_join(512, 4096, seed=6)
    lookup = dict(zip(jd.build_keys.tolist(), jd.build_vals.tolist()))
    ref_sum = float(sum(lookup[k] for k in jd.probe_keys.tolist()))
    cnt, chk = index_join(jnp.asarray(jd.build_keys),
                          jnp.asarray(jd.build_vals),
                          jnp.asarray(jd.probe_keys), kind)
    assert int(cnt) == len(jd.probe_keys)
    assert abs(float(chk) - ref_sum) / ref_sum < 1e-4


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_count_property(data):
    """Property: COUNT is exact for any key distribution, and the
    partitioned kernel path agrees whenever nothing overflowed."""
    n = data.draw(st.integers(256, 4096))
    G = data.draw(st.sampled_from([16, 64, 256]))
    seed = data.draw(st.integers(0, 2**31 - 1))
    r = np.random.RandomState(seed)
    keys = r.randint(0, G, n).astype(np.int32)
    ref = np.bincount(keys, minlength=G).astype(np.float32)
    got = np.asarray(count_direct(jnp.asarray(keys), G))
    np.testing.assert_array_equal(got, ref)


@settings(max_examples=15, deadline=None)
@given(data=st.data())
def test_median_permutation_invariance(data):
    """Property: median is invariant to record order."""
    seed = data.draw(st.integers(0, 2**31 - 1))
    r = np.random.RandomState(seed)
    n, G = 512, 32
    keys = r.randint(0, G, n).astype(np.int32)
    vals = r.rand(n).astype(np.float32)
    perm = r.permutation(n)
    a = np.asarray(median_direct(jnp.asarray(keys), jnp.asarray(vals), G))
    b = np.asarray(median_direct(jnp.asarray(keys[perm]),
                                 jnp.asarray(vals[perm]), G))
    np.testing.assert_allclose(a, b, atol=1e-6, equal_nan=True)
