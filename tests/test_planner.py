"""Logical-plan IR + cost-based physical planner.

Covers: the documented cost-model choice table, logical-plan parity vs the
imperative queries under both executors, the placement-policy x
kernel-executor compose path on a multi-device CPU mesh, the bounded LRU
plan cache, the join-index pool (argsort survival across Table/pytree
reconstruction), and the join_probe-kernel join lowering.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from conftest import run_with_devices

from repro.analytics import plan as L
from repro.analytics import planner
from repro.analytics.columnar import Table, pkfk_join, pkfk_join_kernel
from repro.analytics.planner import (CostProfile, ExecutionContext,
                                     choose_aggregate, choose_dist_join,
                                     choose_join, configure_plan_cache,
                                     dist_join_costs, explain,
                                     join_index_pool, plan_cache_info)
from repro.analytics.tpch import (LOGICAL_QUERIES, QUERIES,
                                  clear_plan_cache, generate, run_query)


@pytest.fixture(scope="module")
def data():
    return generate(scale=0.004, seed=1)


@pytest.fixture(autouse=True)
def _fresh_cache_config():
    yield
    configure_plan_cache(planner.DEFAULT_PLAN_CACHE_ENTRIES)
    planner.set_cost_profile(None)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_rows,n_groups,n_cols,expect", [
    # small domain, single aggregate (C = weights + 1 source): segment ops
    (10_000, 37, 2, "xla"),
    # small domain, several fused aggregates: one dense fused sweep wins
    (10_000, 37, 3, "dense"),
    (24_000, 6, 5, "dense"),
    # large domain, single aggregate: the ROADMAP fix — do NOT pay the
    # range-partition argsort; dense is invalid, xla wins
    (24_000, 6_000, 2, "xla"),
    # large domain, very wide fused stack: the argsort is amortized
    (24_000, 6_000, 12, "partitioned"),
])
def test_cost_model_choice_table(n_rows, n_groups, n_cols, expect):
    assert choose_aggregate(n_rows, n_groups, n_cols, "cost") == expect


def test_executor_preference_overrides_cost():
    # "kernel" keeps the PR-1 tuned behavior: always fused, layout by domain
    assert choose_aggregate(24_000, 37, 2, "kernel") == "dense"
    assert choose_aggregate(24_000, 6_000, 2, "kernel") == "partitioned"
    assert choose_aggregate(24_000, 6, 5, "xla") == "xla"


def test_cost_profile_overrides_constants(tmp_path, data):
    """A calibrated profile replaces the hand-set constants, flips the
    cost-based choice accordingly, and keys the plan cache (a recalibration
    can never serve a plan compiled under stale constants)."""
    # default constants: Q1's 5-column stack picks the fused dense sweep
    assert choose_aggregate(24_000, 6, 5, "cost") == "dense"
    prof = tmp_path / "profile.json"
    prof.write_text('{"fused_fixed": 400.0, "fused_per_col": 60.0,'
                    ' "sort_pass_factor": 14.0, "backend": "cpu-ref"}')
    installed = planner.load_cost_profile(str(prof))
    assert installed.source == "cpu-ref"
    try:
        # measured profile says the fused sweep never pays off here
        assert choose_aggregate(24_000, 6, 5, "cost") == "xla"
        # ... and the executor="kernel" preference still overrides cost
        assert choose_aggregate(24_000, 6, 5, "kernel") == "dense"
        clear_plan_cache()
        run_query("q1", data, executor="cost")
        assert plan_cache_info().currsize == 1
        planner.set_cost_profile(None)
        run_query("q1", data, executor="cost")   # same ctx, new profile
        assert plan_cache_info().currsize == 2   # distinct cache entry
    finally:
        planner.set_cost_profile(None)


def test_dist_join_cost_model():
    """Broadcast wins for small dimension builds; partitioned wins once
    the build side outgrows ~probe/(n-1); overrides and profiles apply."""
    ctx = ExecutionContext(executor="xla")
    # tiny dimension table vs a big fact probe: broadcast
    assert choose_dist_join(1 << 18, 1 << 10, 8, ctx) == "broadcast"
    # build as large as the probe: the all-gather moves ~n x more rows
    # than routing both sides once
    assert choose_dist_join(1 << 18, 1 << 18, 8, ctx) == "partitioned"
    # wider mesh moves the crossover lower, never higher
    assert choose_dist_join(1 << 18, 1 << 15, 2, ctx) == "broadcast"
    assert choose_dist_join(1 << 18, 1 << 16, 16, ctx) == "partitioned"
    # explicit override beats the model
    forced = ExecutionContext(executor="xla", dist_join="broadcast")
    assert choose_dist_join(1 << 18, 1 << 18, 8, forced) == "broadcast"
    with pytest.raises(ValueError):
        ExecutionContext(dist_join="bogus")
    # a measured routing overhead shifts the crossover
    costs = dist_join_costs(1 << 18, 1 << 14, 8)
    assert costs["broadcast"] < costs["partitioned"]
    heavy = CostProfile(dist_route_factor=30.0)
    assert choose_dist_join(1 << 18, 1 << 18, 8, ctx, heavy) == "broadcast"


def test_explain_reports_dist_join_choice(data):
    """explain() surfaces the distributed-join decision (with costs) when
    the context carries a mesh: TPC-H dimension builds stay broadcast."""
    import jax
    mesh = jax.make_mesh((1,), ("data",))
    tables = data.as_jax()
    dj = [d for d in explain(LOGICAL_QUERIES["q5"], tables,
                             ExecutionContext(executor="xla", mesh=mesh))
          if d.node == "DistJoin"]
    assert len(dj) == 4 and all(d.costs for d in dj)
    assert all(d.choice == "broadcast" for d in dj)     # small dim builds
    # and honors a forced strategy
    forced = [d for d in explain(LOGICAL_QUERIES["q5"], tables,
                                 ExecutionContext(executor="xla", mesh=mesh,
                                                  dist_join="partitioned"))
              if d.node == "DistJoin"]
    assert all(d.choice == "partitioned" for d in forced)
    # without a mesh the local sorted/kernel decision is reported instead
    local = explain(LOGICAL_QUERIES["q5"], tables,
                    ExecutionContext(executor="xla"))
    assert not any(d.node == "DistJoin" for d in local)


def test_validate_rejects_malformed_plans(data):
    with pytest.raises(ValueError, match="unknown agg op"):
        L.validate(L.scan("t").aggregate("k", 4, x=("mode", "v")))
    with pytest.raises(ValueError, match="at least one aggregate"):
        L.validate(L.Aggregate(L.scan("t"), "k", 4, ()))
    with pytest.raises(ValueError, match="n_groups"):
        L.validate(L.scan("t").aggregate("k", 0, x=("sum", "v")))
    with pytest.raises(ValueError, match="TopK"):
        L.validate(L.scan("t").top_k("v", 5, "i"))
    with pytest.raises(ValueError, match="unknown binary op"):
        L.validate(L.scan("t").filter(L.BinOp("xor", L.col("a"),
                                              L.col("b"))))
    # group dicts cannot feed Table-consuming nodes (would die mid-trace)
    agg = L.scan("t").aggregate("k", 4, x=("sum", "v"))
    with pytest.raises(ValueError, match="must be a Table node"):
        L.validate(agg.filter(L.col("x") > 0))
    with pytest.raises(ValueError, match="must be a Table node"):
        L.validate(agg.project(_y=L.col("x") * 2))
    with pytest.raises(ValueError, match="must be a Table node"):
        L.validate(agg.join(L.scan("d"), "x", "pk"))
    with pytest.raises(ValueError, match="must be a Table node"):
        L.validate(agg.aggregate("x", 4, y=("sum", "x")))
    # the planner validates on cache miss and refuses to trace garbage
    bad = L.LogicalPlan(L.scan("lineitem").aggregate(
        "l_returnflag", 3, x=("mode", "l_quantity")), None)
    with pytest.raises(ValueError, match="unknown agg op"):
        planner.execute_plan(bad, data.as_jax())
    # the median op is a valid aggregate kind
    L.validate(L.scan("t").aggregate("k", 4, m=("median", "v")))


def test_join_choice_is_sorted_without_mxu():
    # the broadcast-compare probe only pays off when Pallas compiles it;
    # on the CPU reference lowering the planner must keep the sorted gather
    ctx = ExecutionContext(executor="cost", mode="ref")
    assert choose_join(1 << 20, 1 << 15, ctx) == "sorted"
    assert choose_join(100, 50, ExecutionContext(join="kernel")) == "kernel"


def test_explain_q3_q18_avoid_partition_argsort(data):
    tables = data.as_jax()
    for name in ("q3", "q18"):
        aggs = [d for d in explain(LOGICAL_QUERIES[name], tables,
                                   ExecutionContext(executor="cost"))
                if d.node == "Aggregate"]
        assert aggs and all(d.choice == "xla" for d in aggs), name
    q1 = [d for d in explain(LOGICAL_QUERIES["q1"], tables,
                             ExecutionContext(executor="cost"))
          if d.node == "Aggregate"]
    assert [d.choice for d in q1] == ["dense"]


# ---------------------------------------------------------------------------
# logical-plan parity vs the imperative reference queries
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("executor", ["xla", "kernel", "cost"])
@pytest.mark.parametrize("name", sorted(QUERIES))
def test_logical_plan_parity(data, name, executor):
    tables = data.as_jax()
    ref_exec = "kernel" if executor == "kernel" else "xla"
    ref = QUERIES[name](tables, executor=ref_exec)
    got = run_query(name, data, executor=executor)
    assert set(got) == set(ref), name
    for k in ref:
        if k == "_overflow":
            assert int(np.asarray(got[k])) == int(np.asarray(ref[k]))
            continue
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   atol=1e-3, rtol=1e-4,
                                   err_msg=f"{name}/{executor}/{k}")


# ---------------------------------------------------------------------------
# placement-policy backend: same plans on a multi-device CPU mesh
# ---------------------------------------------------------------------------
DIST_TEST = """
import numpy as np, jax
from repro.core.config import PlacementPolicy
from repro.analytics.tpch import QUERIES, generate, run_query
from repro.analytics.planner import ExecutionContext

mesh = jax.make_mesh((8,), ("data",))
data = generate(scale=0.004, seed=1)
cases = [(name, "xla", pol) for name in sorted(QUERIES)
         for pol in (PlacementPolicy.FIRST_TOUCH, PlacementPolicy.INTERLEAVE)]
# the compose axis: fused-kernel executor under placement policies
cases += [("q1", "kernel", PlacementPolicy.INTERLEAVE),
          ("q1", "kernel", PlacementPolicy.LOCAL_ALLOC),
          ("q18", "kernel", PlacementPolicy.PREFERRED)]
for name, ex, pol in cases:
    ref = run_query(name, data, executor="xla")
    ctx = ExecutionContext(executor=ex, mesh=mesh, policy=pol,
                           capacity_factor=4.0)
    got = run_query(name, data, context=ctx)
    assert set(got) == set(ref), (name, pol)
    for k in ref:
        if k == "_overflow":
            assert int(np.asarray(got[k])) == 0, (name, pol, k)
            continue
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   atol=1e-2, rtol=1e-4,
                                   err_msg=f"{name}/{pol}/{ex}/{k}")
print("DIST_PLANNER_OK")
"""


def test_placement_policies_execute_logical_plans():
    out = run_with_devices(DIST_TEST, timeout=900)
    assert "DIST_PLANNER_OK" in out


INTERLEAVE_LARGE_DOMAIN_TEST = """
import numpy as np, jax
from repro.analytics.plan import LogicalPlan, scan
from repro.analytics.planner import ExecutionContext, execute_plan
from repro.core.config import PlacementPolicy

# slot domain G/n > DENSE_GROUP_LIMIT: the routed interleave buffer masses
# its padding on the drop slot, so the local aggregation must fall back to
# an occupancy-independent layout — no phantom overflow, no dropped rows
rng = np.random.RandomState(0)
N, G = 65536, 40000
tables = {"t": {"k": rng.randint(0, G, N).astype(np.int32),
                "v": rng.rand(N).astype(np.float32)}}
plan = LogicalPlan(scan("t").aggregate("k", G, s=("sum", "v")),
                   ("s", "_count", "_overflow"))
ref = execute_plan(plan, tables, ExecutionContext(executor="xla"))
mesh = jax.make_mesh((4,), ("data",))
got = execute_plan(plan, tables, ExecutionContext(
    executor="kernel", mesh=mesh, policy=PlacementPolicy.INTERLEAVE))
assert int(np.asarray(got["_overflow"])) == 0, "phantom overflow"
np.testing.assert_allclose(np.asarray(got["s"]), np.asarray(ref["s"]),
                           atol=1e-2, rtol=1e-5)
print("INTERLEAVE_LARGE_OK")
"""


def test_interleave_kernel_large_slot_domain_exact():
    out = run_with_devices(INTERLEAVE_LARGE_DOMAIN_TEST, n_devices=4,
                           timeout=600)
    assert "INTERLEAVE_LARGE_OK" in out


def test_key_index_does_not_cache_tracers(rng):
    """An eager Table joined inside a jit trace must stay usable after."""
    import jax

    dim = Table({"dk": jnp.asarray(rng.permutation(100), jnp.int32),
                 "p": jnp.asarray(rng.randn(100), jnp.float32)})
    fk = jnp.asarray(rng.randint(0, 100, 512), jnp.int32)

    @jax.jit
    def inside(keys):
        return pkfk_join(Table({"fk": keys}), dim, "fk", "dk",
                         {"p": "p"}).col("p")

    a = inside(fk)                   # dim closed over eagerly by the trace
    assert "dk" not in dim.index_cache
    b = pkfk_join(Table({"fk": fk}), dim, "fk", "dk", {"p": "p"}).col("p")
    assert "dk" in dim.index_cache   # eager call may cache concrete arrays
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_join_index_pool_does_not_pin_arrays(rng):
    import gc
    import weakref

    pool = join_index_pool()
    pool.clear()
    arr = jnp.asarray(rng.permutation(1000).astype(np.int32))
    ref = weakref.ref(arr)
    pool.get("t", "k", arr)
    del arr
    gc.collect()
    assert ref() is None             # the pool must not keep datasets alive


# ---------------------------------------------------------------------------
# bounded LRU plan cache
# ---------------------------------------------------------------------------
def test_plan_cache_lru_bound(data):
    clear_plan_cache()
    configure_plan_cache(2)
    run_query("q1", data, executor="xla")
    run_query("q1", data, executor="kernel")
    run_query("q1", data, executor="cost")       # evicts the oldest entry
    info = plan_cache_info()
    assert info.currsize == 2 and info.maxsize == 2
    run_query("q1", data, executor="cost")       # still resident -> hit
    assert plan_cache_info().hits >= 1
    # shrinking evicts immediately
    configure_plan_cache(1)
    assert plan_cache_info().currsize == 1
    with pytest.raises(ValueError):
        configure_plan_cache(0)


# ---------------------------------------------------------------------------
# join-index pool: argsorts survive Tables-pytree reconstruction
# ---------------------------------------------------------------------------
def test_join_index_pool_survives_reruns(data):
    clear_plan_cache()
    pool = join_index_pool()
    pool.clear()
    run_query("q5", data, executor="xla")
    first = pool.builds
    assert first == 4                    # nation, customer, orders, supplier
    # re-dispatch, a different executor, and a REBUILT Tables mapping (new
    # dict objects, same column arrays) must all reuse the pooled argsorts
    run_query("q5", data, executor="xla")
    run_query("q5", data, executor="kernel")
    rebuilt = {t: dict(cols) for t, cols in data.as_jax().items()}
    run_query("q5", rebuilt, executor="xla")
    assert pool.builds == first
    # q3 joins through orders/customer again -> shared entries, +0 new
    run_query("q3", data, executor="xla")
    assert pool.builds == first
    # genuinely new column arrays do build new indexes
    other = generate(scale=0.004, seed=9)
    run_query("q3", other, executor="xla")
    assert pool.builds > first


# ---------------------------------------------------------------------------
# kernel-probed PK-FK join lowering
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_pkfk_join_kernel_matches_sorted(rng, mode):
    n_dim, n_fact = 500, 4096
    dk = jnp.asarray(rng.permutation(n_dim), jnp.int32)
    dim = Table({"dk": dk,
                 "payload": jnp.asarray(rng.randn(n_dim), jnp.float32)})
    dim = dim.filter(jnp.asarray(rng.rand(n_dim) < 0.8))
    # fact keys include misses (>= n_dim) which must zero the mask
    fk = jnp.asarray(rng.randint(0, n_dim + 100, n_fact), jnp.int32)
    fact = Table({"fk": fk}).filter(jnp.asarray(rng.rand(n_fact) < 0.9))
    ref = pkfk_join(fact, dim, "fk", "dk", {"p": "payload"})
    got, ovf = pkfk_join_kernel(fact, dim, "fk", "dk", {"p": "payload"},
                                mode=mode, capacity_factor=4.0)
    assert int(np.asarray(ovf)) == 0
    np.testing.assert_array_equal(np.asarray(got.weights()),
                                  np.asarray(ref.weights()))
    np.testing.assert_allclose(
        np.asarray(got.col("p")) * np.asarray(got.weights()),
        np.asarray(ref.col("p")) * np.asarray(ref.weights()), rtol=1e-6)


def test_pkfk_join_kernel_counts_overflow(rng):
    # all build keys hash-collide into few partitions at capacity 1.0 ->
    # without the residual pass, overflow must be surfaced and overflowed
    # rows degrade to misses (the PR-2 accounting behavior)
    n = 4096
    dim = Table({"dk": jnp.asarray(np.arange(n), jnp.int32),
                 "v": jnp.ones((n,), jnp.float32)})
    fact = Table({"fk": jnp.asarray(np.arange(n), jnp.int32)})
    got, ovf = pkfk_join_kernel(fact, dim, "fk", "dk", {"v": "v"},
                                n_partitions=2, capacity_factor=0.25,
                                mode="ref", residual=False)
    assert int(np.asarray(ovf)) > 0
    assert float(np.asarray(got.weights()).sum()) < n


def test_pkfk_join_kernel_residual_pass_exact(rng):
    """Deliberate capacity overflow on both sides: the residual sorted
    re-probe (default) must recover every missed match — zero misses, and
    values identical to the exact sorted join."""
    n_dim, n_fact = 2048, 4096
    dk = jnp.asarray(rng.permutation(n_dim), jnp.int32)
    dim = Table({"dk": dk,
                 "payload": jnp.asarray(rng.randn(n_dim), jnp.float32)})
    # skewed probe: half the probes hammer 32 hot keys, so partitions
    # overflow at capacity_factor 0.25 on either side
    hot = rng.randint(0, 32, n_fact // 2)
    cold = rng.randint(0, n_dim + 64, n_fact - n_fact // 2)
    fk = jnp.asarray(np.concatenate([hot, cold]), jnp.int32)
    fact = Table({"fk": fk}).filter(jnp.asarray(rng.rand(n_fact) < 0.9))
    ref = pkfk_join(fact, dim, "fk", "dk", {"p": "payload"})

    # sanity: this configuration really does overflow without the residual
    _, raw_ovf = pkfk_join_kernel(fact, dim, "fk", "dk", {"p": "payload"},
                                  n_partitions=2, capacity_factor=0.25,
                                  mode="ref", residual=False)
    assert int(np.asarray(raw_ovf)) > 0

    got, ovf = pkfk_join_kernel(fact, dim, "fk", "dk", {"p": "payload"},
                                n_partitions=2, capacity_factor=0.25,
                                mode="ref")
    assert int(np.asarray(ovf)) == 0          # repaired, not surfaced
    np.testing.assert_array_equal(np.asarray(got.weights()),
                                  np.asarray(ref.weights()))
    np.testing.assert_allclose(
        np.asarray(got.col("p")) * np.asarray(got.weights()),
        np.asarray(ref.col("p")) * np.asarray(ref.weights()), rtol=1e-6)
