"""Data pipeline: determinism (restart replay), sharding, prefetch."""
import numpy as np

from repro.configs.reduced import REDUCED
from repro.data.pipeline import PrefetchingLoader, synth_batch


def test_determinism():
    arch = REDUCED["qwen2-0.5b"]
    a = synth_batch(arch, 4, 16, step=7, seed=1)
    b = synth_batch(arch, 4, 16, step=7, seed=1)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synth_batch(arch, 4, 16, step=8, seed=1)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_next_tokens():
    arch = REDUCED["qwen2-0.5b"]
    b = synth_batch(arch, 2, 16, step=0, seed=0)
    assert b["labels"].shape == b["tokens"].shape
    # labels[t] == tokens[t+1] for the shared region
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_host_sharding_distinct():
    arch = REDUCED["qwen2-0.5b"]
    a = synth_batch(arch, 4, 16, step=3, seed=1, host_id=0)
    b = synth_batch(arch, 4, 16, step=3, seed=1, host_id=1)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_vlm_batch_shapes():
    arch = REDUCED["qwen2-vl-2b"]
    b = synth_batch(arch, 2, 16, step=0)
    P = arch.n_patches
    assert b["patch_embeds"].shape == (2, P, arch.d_model)
    assert b["patch_pos"].shape == (2, P, 3)
    assert b["tokens"].shape == (2, 16 - P)


def test_musicgen_batch_shapes():
    arch = REDUCED["musicgen-large"]
    b = synth_batch(arch, 2, 16, step=0)
    assert b["embeds"].shape == (2, 16, arch.d_model)
    assert b["labels"].shape == (2, 16, arch.n_codebooks)


def test_prefetch_loader():
    arch = REDUCED["qwen2-0.5b"]
    loader = PrefetchingLoader(arch, 2, 8, seed=5, prefetch=3)
    try:
        batches = [next(loader) for _ in range(4)]
        ref = [synth_batch(arch, 2, 8, step=s, seed=5) for s in range(4)]
        for got, exp in zip(batches, ref):
            np.testing.assert_array_equal(got["tokens"], exp["tokens"])
    finally:
        loader.close()
