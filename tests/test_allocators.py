"""Allocator invariants: no overlapping live blocks, arena bounds respected,
stats consistent — swept across all four designs with hypothesis."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.config import AllocatorKind
from repro.memory.allocators import make_allocator
from repro.memory.microbench import run_microbench


@pytest.mark.parametrize("kind", list(AllocatorKind))
def test_no_overlap_and_bounds(kind):
    rng = np.random.RandomState(0)
    alloc = make_allocator(kind, capacity=1 << 22, granule=64)
    live = []
    for i in range(2000):
        if live and rng.rand() < 0.4:
            idx = rng.randint(len(live))
            alloc.free(live.pop(idx), stream=idx % 8)
        else:
            blk = alloc.alloc(int(rng.randint(1, 4096)), stream=i % 8)
            if blk is not None:
                assert blk.offset >= 0
                assert blk.offset + blk.size <= alloc.capacity
                live.append(blk)
        # invariant: live blocks never overlap
    spans = sorted((b.offset, b.offset + b.size) for b in live)
    for (s1, e1), (s2, e2) in zip(spans, spans[1:]):
        assert e1 <= s2, f"overlap in {kind}: ({s1},{e1}) vs ({s2},{e2})"


@pytest.mark.parametrize("kind", list(AllocatorKind))
def test_stats_consistency(kind):
    alloc = make_allocator(kind, capacity=1 << 20, granule=64)
    blocks = [alloc.alloc(100, stream=s) for s in range(10)]
    assert alloc.stats.allocs == 10
    assert alloc.stats.bytes_requested == 1000
    assert alloc.stats.bytes_reserved >= 1000
    for b in blocks:
        alloc.free(b, stream=0)
    assert alloc.stats.frees == 10
    assert alloc.stats.live_reserved == 0
    assert alloc.stats.overhead_ratio >= 1.0


def test_reuse_after_free():
    """Freed memory must be reusable (the allocator doesn't leak)."""
    for kind in AllocatorKind:
        alloc = make_allocator(kind, capacity=1 << 16, granule=64)
        for _ in range(200):  # far more ops than capacity without reuse
            blk = alloc.alloc(1024, stream=0)
            assert blk is not None, f"{kind} failed to reuse freed memory"
            alloc.free(blk, stream=0)


def test_contention_ordering():
    """Paper Fig 2a: the single-lock design must contend the most."""
    results = {k: run_microbench(k, n_streams=8, ops_per_stream=400)
               for k in AllocatorKind}
    assert results[AllocatorKind.BUMP].contention_rate > \
        results[AllocatorKind.SLAB].contention_rate
    assert results[AllocatorKind.BUMP].contention_rate > \
        results[AllocatorKind.ARENA].contention_rate


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       kind=st.sampled_from(list(AllocatorKind)))
def test_alloc_free_property(seed, kind):
    """Property: any alloc/free interleaving keeps blocks disjoint and
    within capacity."""
    rng = np.random.RandomState(seed)
    alloc = make_allocator(kind, capacity=1 << 18, granule=64)
    live = {}
    for i in range(300):
        if live and rng.rand() < 0.5:
            key = list(live)[rng.randint(len(live))]
            alloc.free(live.pop(key), stream=int(rng.randint(4)))
        else:
            blk = alloc.alloc(int(rng.randint(1, 2048)),
                              stream=int(rng.randint(4)))
            if blk is not None:
                live[i] = blk
    spans = sorted((b.offset, b.offset + b.size) for b in live.values())
    for (s1, e1), (s2, e2) in zip(spans, spans[1:]):
        assert e1 <= s2
