"""Compressed data-parallel training: gradient fidelity + convergence on a
real multi-device mesh (subprocess)."""
from conftest import run_with_devices


def test_compressed_dp_training_converges():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs.reduced import REDUCED
from repro.core.config import (LM_SHAPES, RunConfig, ShardingConfig,
                               TrainConfig)
from repro.core.params import init_params
from repro.data.pipeline import synth_batch
from repro.models.lm import LMModel
from repro.optim import adamw
from repro.runtime.dp_step import init_error_feedback, make_dp_train_step

mesh = jax.make_mesh((8,), ("data",))
arch = REDUCED["qwen2-0.5b"]
model = LMModel(arch, tp=1, remat="none")

def run(compress):
    cfg = RunConfig(arch=arch, shape=LM_SHAPES["train_4k"],
                    sharding=ShardingConfig(gradient_compression=compress),
                    train=TrainConfig(learning_rate=2e-3, warmup_steps=1))
    params = init_params(model.schema(), jax.random.PRNGKey(0), jnp.float32)
    opt = adamw.init(params, cfg.train)
    errors = init_error_feedback(params)
    step = jax.jit(make_dp_train_step(model, cfg, mesh))
    losses = []
    b = {k: jnp.asarray(v) for k, v in
         synth_batch(arch, 16, 16, step=0, seed=3).items()}
    for i in range(10):   # overfit a fixed batch: deterministic descent
        params, opt, errors, m = step(params, opt, errors, b,
                                      jnp.asarray(i))
        losses.append(float(m["loss"]))
    return losses

plain = run(False)
comp = run(True)
assert all(np.isfinite(plain)) and all(np.isfinite(comp))
assert plain[-1] < plain[0], plain
assert comp[-1] < comp[0], comp
# compression must track the uncompressed trajectory closely
assert abs(comp[-1] - plain[-1]) < 0.15, (plain[-1], comp[-1])
print("DP_COMPRESSION_OK", plain[-1], comp[-1])
""", timeout=600)
    assert "DP_COMPRESSION_OK" in out
