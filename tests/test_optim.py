"""Optimizer: convergence, schedules, grad compression roundtrip."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.config import TrainConfig
from repro.optim import adamw, schedules
from repro.optim.compression import dequantize_int8, quantize_int8


def test_adamw_converges_quadratic():
    cfg = TrainConfig(learning_rate=0.1, weight_decay=0.0, grad_clip=0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adamw.init(params, cfg)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw.update(grads, state, params,
                                        jnp.asarray(0.05), cfg)
    assert float(loss(params)) < 1e-3


def test_adamw_bf16_moments_still_converge():
    cfg = TrainConfig(moment_dtype="bfloat16", weight_decay=0.0, grad_clip=0)
    target = jnp.asarray([0.5, -0.5])
    params = {"w": jnp.zeros(2)}
    state = adamw.init(params, cfg)
    assert state.mu["w"].dtype == jnp.bfloat16
    for _ in range(300):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state, _ = adamw.update(grads, state, params,
                                        jnp.asarray(0.03), cfg)
    assert float(jnp.abs(params["w"] - target).max()) < 0.05


def test_grad_clip_metric():
    cfg = TrainConfig(grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    state = adamw.init(params, cfg)
    grads = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = adamw.update(grads, state, params, jnp.asarray(1e-3), cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)
    assert float(metrics["clip"]) < 0.01


def test_warmup_cosine_shape():
    steps = jnp.arange(0, 1000)
    lr = schedules.warmup_cosine(steps, peak_lr=1.0, warmup_steps=100,
                                 total_steps=1000)
    assert float(lr[0]) == 0.0
    assert float(lr[99]) <= 1.0
    assert float(lr[100]) == pytest.approx(1.0, abs=0.02)
    assert float(lr[-1]) >= 0.1 - 1e-3         # min_ratio floor
    assert (np.diff(np.asarray(lr[100:])) <= 1e-6).all()  # monotone decay


@pytest.mark.parametrize("shape", [(17,), (256,), (3, 100)])
def test_quantize_roundtrip(rng, shape):
    x = jnp.asarray(rng.randn(*shape) * 5, jnp.float32)
    q, s = quantize_int8(x, block=64)
    back = dequantize_int8(q.astype(jnp.float32), s, shape, block=64)
    # error bounded by scale/2 per element
    max_scale = float(s.max())
    assert float(jnp.abs(back - x).max()) <= max_scale * 0.51 + 1e-6
