"""Partitioning engine + padding invariants (hypothesis property tests)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import ARCHS
from repro.core.config import ArchConfig, PaddedDims, pad_to
from repro.core.topology import TorusTopology
from repro.core.meshes import layout_report


def test_pad_to():
    assert pad_to(56, 16) == 64
    assert pad_to(64, 16) == 64
    assert pad_to(1, 128) == 128
    with pytest.raises(ValueError):
        pad_to(5, 0)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_padded_dims_all_archs(name):
    """Every assigned arch must pad cleanly for the production TP=16."""
    arch = ARCHS[name]
    pd = PaddedDims.for_tp(arch, 16)
    assert pd.n_heads % 16 == 0
    assert pd.n_heads >= arch.n_heads
    if arch.n_kv_heads:
        assert pd.n_kv_heads % 16 == 0
        assert pd.n_heads % pd.n_kv_heads == 0   # intact GQA grouping
    assert pd.vocab_size % 128 == 0
    assert pd.vocab_size >= arch.vocab_size
    assert pd.d_ff % 16 == 0


@settings(max_examples=50, deadline=None)
@given(heads=st.integers(1, 128), kv=st.integers(1, 32),
       tp=st.sampled_from([1, 2, 4, 8, 16]))
def test_padding_property(heads, kv, tp):
    kv = min(kv, heads)
    arch = ArchConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=heads, n_kv_heads=kv, d_ff=64, vocab_size=100)
    pd = PaddedDims.for_tp(arch, tp)
    assert pd.n_heads % tp == 0
    assert pd.n_kv_heads % tp == 0
    assert pd.n_heads >= heads
    assert pd.n_kv_heads >= kv
    assert pd.n_heads % pd.n_kv_heads == 0


def test_layout_hops():
    """NONE (OS-default analogue) must dilate ring hops; affinitized
    layouts ride physical rings (paper Fig 3/Table 2)."""
    rep = layout_report(TorusTopology(n_pods=1))
    assert rep["sparse"]["data"] == 1.0
    assert rep["dense"]["model"] == 1.0
    assert rep["none"]["data"] > 4.0
    assert rep["none"]["model"] > 4.0


def test_relative_latency_table():
    """Mirrors the paper's Table 3 latency tiers (local < 1 hop < 2 hop)."""
    topo = TorusTopology(n_pods=2)
    assert topo.relative_latency(0, 0) == 1.0
    near = topo.relative_latency(0, 1)
    far = topo.relative_latency(0, 8 * 16 + 8)   # across the pod
    cross = topo.relative_latency(0, topo.chips_per_pod)  # other pod
    assert 1.0 < near < far < cross
