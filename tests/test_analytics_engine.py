"""Distributed placement-policy engine: all four policies produce the same
query answers (on an 8-device subprocess mesh) — the paper's thesis that
placement changes performance, never results."""
import pytest

from conftest import run_with_devices

ENGINE_TEST = """
import numpy as np, jax, jax.numpy as jnp
from repro.core.config import PlacementPolicy
from repro.analytics.engine import dist_count, dist_median, dist_hash_join
from repro.analytics.datasets import moving_cluster, zipf, blanas_join

mesh = jax.make_mesh((8,), ("data",))
G, N = 64, 8192
ds = {dataset}(N, G, seed=5)
keys = jnp.asarray(ds.keys); vals = jnp.asarray(ds.vals)
ref = np.bincount(ds.keys, minlength=G).astype(np.float32)

def expand_interleave(out, n=8):
    full = np.zeros(G, np.float32)
    per = out.reshape(n, G // n)
    for s in range(n):
        full[np.arange(G)[np.arange(G) % n == s]] = per[s]
    return full

for pol in PlacementPolicy:
    out = np.asarray(jax.jit(dist_count(mesh, pol, G))(keys))
    if pol == PlacementPolicy.INTERLEAVE:
        got = expand_interleave(out)
    else:
        got = out[:G]
    assert np.abs(got - ref).max() == 0, (pol, np.abs(got - ref).max())

med_ref = np.full(G, np.nan, np.float32)
for g in range(G):
    v = np.sort(ds.vals[ds.keys == g])
    if len(v):
        med_ref[g] = (v[(len(v)-1)//2] + v[len(v)//2]) / 2
for pol in (PlacementPolicy.FIRST_TOUCH, PlacementPolicy.INTERLEAVE):
    out = np.asarray(jax.jit(dist_median(mesh, pol, G))(keys, vals))
    got = expand_interleave(out) if pol == PlacementPolicy.INTERLEAVE else out
    assert np.nanmax(np.abs(got - med_ref)) < 1e-5, pol

jd = blanas_join(1024, 8192, seed=6)
bk, bv, pk = map(jnp.asarray, (jd.build_keys, jd.build_vals, jd.probe_keys))
lookup = dict(zip(jd.build_keys.tolist(), jd.build_vals.tolist()))
ref_sum = sum(lookup[k] for k in jd.probe_keys.tolist())
for pol in PlacementPolicy:
    c, s = jax.jit(dist_hash_join(mesh, pol))(bk, bv, pk)
    assert int(c) == len(jd.probe_keys), (pol, int(c))
    assert abs(float(s) - ref_sum) / ref_sum < 1e-4, pol
print("ENGINE_OK")
"""


@pytest.mark.parametrize("dataset", ["moving_cluster", "zipf"])
def test_all_policies_same_answers(dataset):
    out = run_with_devices(ENGINE_TEST.format(dataset=dataset))
    assert "ENGINE_OK" in out
