"""Distributed placement-policy engine: all four policies produce the same
query answers (on an 8-device subprocess mesh) — the paper's thesis that
placement changes performance, never results."""
import pytest

from conftest import run_with_devices

ENGINE_TEST = """
import numpy as np, jax, jax.numpy as jnp
from repro.core.config import PlacementPolicy
from repro.analytics.engine import dist_count, dist_median, dist_hash_join
from repro.analytics.datasets import moving_cluster, zipf, blanas_join

mesh = jax.make_mesh((8,), ("data",))
G, N = 64, 8192
ds = {dataset}(N, G, seed=5)
keys = jnp.asarray(ds.keys); vals = jnp.asarray(ds.vals)
ref = np.bincount(ds.keys, minlength=G).astype(np.float32)

# W1/W2/W3 are all logical plans lowered through the planner's distributed
# backend: every policy returns the replicated natural-order result
for pol in PlacementPolicy:
    for auto in ((False, True) if pol == PlacementPolicy.FIRST_TOUCH
                 else (False,)):
        out = np.asarray(
            jax.jit(dist_count(mesh, pol, G, auto_rebalance=auto))(keys))
        got = out[:G]
        assert np.abs(got - ref).max() == 0, (pol, auto,
                                              np.abs(got - ref).max())

# auto-rebalance must also survive a group domain NOT divisible by the
# mesh (the tiled collectives need internal padding)
G2 = 100
keys2 = jnp.asarray((ds.keys % G2).astype(np.int32))
ref2 = np.bincount(np.asarray(keys2), minlength=G2).astype(np.float32)
out2 = np.asarray(jax.jit(dist_count(
    mesh, PlacementPolicy.FIRST_TOUCH, G2, auto_rebalance=True))(keys2))
assert out2.shape[0] == G2 and np.abs(out2 - ref2).max() == 0

med_ref = np.full(G, np.nan, np.float32)
for g in range(G):
    v = np.sort(ds.vals[ds.keys == g])
    if len(v):
        med_ref[g] = (v[(len(v)-1)//2] + v[len(v)//2]) / 2
for pol in (PlacementPolicy.FIRST_TOUCH, PlacementPolicy.INTERLEAVE):
    got = np.asarray(jax.jit(dist_median(mesh, pol, G))(keys, vals))
    assert np.nanmax(np.abs(got - med_ref)) < 1e-5, pol

jd = blanas_join(1024, 8192, seed=6)
bk, bv, pk = map(jnp.asarray, (jd.build_keys, jd.build_vals, jd.probe_keys))
lookup = dict(zip(jd.build_keys.tolist(), jd.build_vals.tolist()))
ref_sum = sum(lookup[k] for k in jd.probe_keys.tolist())
for pol in PlacementPolicy:
    c, s = jax.jit(dist_hash_join(mesh, pol))(bk, bv, pk)
    assert int(c) == len(jd.probe_keys), (pol, int(c))
    assert abs(float(s) - ref_sum) / ref_sum < 1e-4, pol
print("ENGINE_OK")
"""


@pytest.mark.parametrize("dataset", ["moving_cluster", "zipf"])
def test_all_policies_same_answers(dataset):
    out = run_with_devices(ENGINE_TEST.format(dataset=dataset))
    assert "ENGINE_OK" in out


NON_POW2_REBALANCE_TEST = """
import numpy as np, jax, jax.numpy as jnp
from repro.core.config import PlacementPolicy
from repro.analytics.engine import dist_count

# n=6: float32(x/6) summed 6 times is NOT x (a count of 7 came back
# 6.9999995 when the rebalance divided before its reduce-scatter); the
# migration must stay exact for integer counts on any mesh size
mesh = jax.make_mesh((6,), ("data",))
G, N = 100, 6000
keys = jnp.asarray(np.random.RandomState(0).randint(0, G, N).astype(np.int32))
ref = np.bincount(np.asarray(keys), minlength=G).astype(np.float32)
out = np.asarray(jax.jit(dist_count(
    mesh, PlacementPolicy.FIRST_TOUCH, G, auto_rebalance=True))(keys))
assert out.shape[0] == G and np.abs(out - ref).max() == 0, \\
    np.abs(out - ref).max()
print("NON_POW2_OK")
"""


def test_auto_rebalance_exact_on_non_pow2_mesh():
    out = run_with_devices(NON_POW2_REBALANCE_TEST, n_devices=6)
    assert "NON_POW2_OK" in out
