"""RG-LRU linear scan + RWKV6 WKV kernels vs oracles (shape sweeps)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.rglru_scan import linear_scan
from repro.kernels.rglru_scan.ref import (linear_scan_ref,
                                          linear_scan_sequential)
from repro.kernels.rwkv6_scan import wkv6, wkv6_step
from repro.kernels.rwkv6_scan.ref import wkv6_ref


@pytest.mark.parametrize("shape", [(1, 8, 4), (2, 64, 24), (3, 128, 16),
                                   (1, 100, 7)])  # odd sizes too
def test_linear_scan_modes_agree(rng, shape):
    B, S, D = shape
    a = jnp.asarray(rng.uniform(0.3, 0.999, shape), jnp.float32)
    b = jnp.asarray(rng.randn(*shape), jnp.float32)
    seq = linear_scan_sequential(a, b)
    np.testing.assert_allclose(np.asarray(linear_scan_ref(a, b)),
                               np.asarray(seq), atol=1e-5)
    np.testing.assert_allclose(np.asarray(linear_scan(a, b, "interpret")),
                               np.asarray(seq), atol=1e-5)


def test_linear_scan_gradients(rng):
    B, S, D = 2, 32, 8
    a = jnp.asarray(rng.uniform(0.5, 0.99, (B, S, D)), jnp.float32)
    b = jnp.asarray(rng.randn(B, S, D), jnp.float32)

    def f_op(a, b):
        return (linear_scan(a, b, "ref") ** 2).sum()

    def f_ref(a, b):
        return (linear_scan_sequential(a, b) ** 2).sum()

    g_op = jax.grad(f_op, argnums=(0, 1))(a, b)
    g_ref = jax.grad(f_ref, argnums=(0, 1))(a, b)
    for x, y in zip(g_op, g_ref):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-4,
                                   rtol=1e-4)


@pytest.mark.parametrize("shape", [(1, 16, 1, 8), (2, 32, 3, 16),
                                   (1, 64, 2, 32)])
def test_wkv6_interpret_matches_ref(rng, shape):
    B, S, H, N = shape
    r = jnp.asarray(rng.randn(B, S, H, N) * 0.5, jnp.float32)
    k = jnp.asarray(rng.randn(B, S, H, N) * 0.5, jnp.float32)
    v = jnp.asarray(rng.randn(B, S, H, N) * 0.5, jnp.float32)
    w = jnp.asarray(rng.uniform(0.6, 0.99, shape), jnp.float32)
    u = jnp.asarray(rng.randn(H, N) * 0.5, jnp.float32)
    y_ref, s_ref = wkv6_ref(r, k, v, w, u)
    y_itp, s_itp = wkv6(r, k, v, w, u, "interpret")
    np.testing.assert_allclose(np.asarray(y_itp), np.asarray(y_ref),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_itp), np.asarray(s_ref),
                               atol=1e-5)


def test_wkv6_step_matches_scan(rng):
    """Step-by-step decode reproduces the full scan."""
    B, S, H, N = 2, 12, 2, 8
    r = jnp.asarray(rng.randn(B, S, H, N) * 0.5, jnp.float32)
    k = jnp.asarray(rng.randn(B, S, H, N) * 0.5, jnp.float32)
    v = jnp.asarray(rng.randn(B, S, H, N) * 0.5, jnp.float32)
    w = jnp.asarray(rng.uniform(0.6, 0.99, (B, S, H, N)), jnp.float32)
    u = jnp.asarray(rng.randn(H, N) * 0.5, jnp.float32)
    y_ref, s_ref = wkv6_ref(r, k, v, w, u)
    state = jnp.zeros((B, H, N, N), jnp.float32)
    ys = []
    for t in range(S):
        y, state = wkv6_step(r[:, t], k[:, t], v[:, t], w[:, t], u, state)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_ref),
                               atol=1e-5)
