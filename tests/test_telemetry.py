"""Acceptance tests for the execution-telemetry subsystem (ISSUE 7).

Four layers, matching the telemetry module's contract:

  * recording — both executors emit per-node observed stats behind the
    ``"_stats"`` reserved key, the dispatch handle strips them from the
    caller-visible result and folds them into the global StatsRegistry;
    with telemetry disabled the jit is untracked and nothing is recorded
    (the flag is part of the plan-cache key, so both variants coexist);
  * explain_analyze — golden-snapshotted est-vs-obs tree for q3 on a
    4-shard mesh (observed row counts are exact integers of a fixed
    dataset, so the rendered string is deterministic);
  * conservation — the recorded moved/alive/overflow counters equal a
    numpy recomputation of the routing under ``dist_route="modulo"``
    (owner = key % n, home shard = global row // per-shard rows);
  * adaptive re-planning — a deliberately mis-priced CostProfile makes
    the static cost model pick a broadcast join; ONE recorded execution
    detects the drift and the next plan-cache hit re-lowers with the
    observed alive rows, flipping the Decision to partitioned — with
    results bit-identical to the fault-free run, and ``refresh_profile``
    pulling ``dist_route_factor`` back off the mis-priced value.

Distributed pieces run in ``run_with_devices`` subprocesses (the parent
process must keep its real single device for the smoke tests).
"""
import os
import re

import numpy as np
import pytest

from repro.analytics import plan as L
from repro.analytics import planner, telemetry

from conftest import run_with_devices

FIXDIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "fixtures")


@pytest.fixture(autouse=True)
def _default_profile():
    prev = planner.current_cost_profile()
    planner.set_cost_profile(None)
    telemetry.registry().clear()
    yield
    planner.set_cost_profile(prev)
    telemetry.disable_telemetry()


def _local_tables(rng):
    n = 512
    return {"fact": {"k": rng.randint(0, 9, n).astype(np.int32),
                     "v": rng.randn(n).astype(np.float32),
                     "d": rng.randint(0, 100, n).astype(np.int32)}}


# ---------------------------------------------------------------------------
# recording (local executor, in-process)
# ---------------------------------------------------------------------------
def test_local_recording_registers_and_strips_stats():
    rng = np.random.RandomState(11)
    tables = _local_tables(rng)
    p = L.LogicalPlan(
        L.scan("fact").filter(L.col("d") < 40)
        .aggregate("k", 9, c=("count", "v"), m=("max", "v")), ("c", "m"))
    ctx = planner.ExecutionContext(executor="cost")

    plain = planner.compile_plan(p, tables, ctx)
    ref = plain(tables)
    with telemetry.recording() as reg:
        cp = planner.compile_plan(p, tables, ctx)
        out = cp(tables)

    assert cp.record and not plain.record
    assert cp.cache_key != plain.cache_key     # record flag is in the key
    assert "_stats" not in out
    for k in ("c", "m"):
        assert np.array_equal(np.asarray(ref[k]), np.asarray(out[k]))

    ps = reg.get(cp.cache_key)
    assert ps is not None and ps.executions == 1
    # the grouped aggregate reported its occupied groups exactly
    alive = tables["fact"]["d"] < 40
    occupied = len(np.unique(tables["fact"]["k"][alive]))
    aggs = [ns for ns in ps.nodes.values() if ns.kind == "aggregate"]
    assert [ns.last["groups_occupied"] for ns in aggs] == [occupied]
    # nothing was recorded for the untracked handle
    assert reg.get(plain.cache_key) is None


def test_disabled_telemetry_records_nothing():
    rng = np.random.RandomState(12)
    tables = _local_tables(rng)
    p = L.LogicalPlan(L.scan("fact").aggregate("k", 9, s=("sum", "v")),
                      ("s",))
    cp = planner.compile_plan(p, tables, planner.ExecutionContext())
    cp(tables)
    assert not cp.record
    assert telemetry.registry().summary()["executions"] == 0


def test_explain_analyze_local_annotates():
    rng = np.random.RandomState(13)
    tables = _local_tables(rng)
    p = L.LogicalPlan(L.scan("fact").aggregate("k", 9, c=("count", "v")),
                      ("c",))
    text = planner.explain_analyze(p, tables)
    assert "[obs groups_occupied=" in text
    assert "est groups_occupied~9" in text
    assert not telemetry.telemetry_enabled()   # flag restored


# ---------------------------------------------------------------------------
# explain_analyze golden (4-shard mesh)
# ---------------------------------------------------------------------------
# REGEN: run the code below with XLA_FLAGS=--xla_force_host_platform_
# device_count=4, replace the header's wall=<N>ms token with wall=<WALL>,
# and write stdout to tests/fixtures/explain_analyze_q3.txt ONLY when a
# lowering/telemetry change is intentional.
EXPLAIN_CODE = """
import numpy as np, jax
from jax.sharding import Mesh
from repro.analytics import telemetry
import repro.analytics.planner as planner
from repro.analytics.planner import ExecutionContext
from repro.analytics.tpch import LOGICAL_QUERIES, generate
from repro.core.config import PlacementPolicy

planner.set_cost_profile(None)
tables = generate(scale=0.004, seed=1).as_jax()
mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
ctx = ExecutionContext(executor="cost", mesh=mesh,
                       policy=PlacementPolicy.INTERLEAVE,
                       dist_join="partitioned")
print(telemetry.explain_analyze(LOGICAL_QUERIES["q3"], tables, ctx))
"""


def test_explain_analyze_matches_golden():
    got = run_with_devices(EXPLAIN_CODE, n_devices=4).strip("\n")
    # wall-clock is the one nondeterministic token; the fixture stores the
    # placeholder form.
    got = re.sub(r"wall=[0-9.]+ms", "wall=<WALL>", got)
    with open(os.path.join(FIXDIR, "explain_analyze_q3.txt")) as f:
        want = f.read().strip("\n")
    assert got == want, (
        "explain_analyze drifted from the golden snapshot; if intentional, "
        "regenerate tests/fixtures/explain_analyze_q3.txt (see REGEN note)"
        f"\n--- got ---\n{got}")


# ---------------------------------------------------------------------------
# stats conservation vs numpy (modulo routing is recomputable exactly)
# ---------------------------------------------------------------------------
CONSERVATION_CODE = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.analytics import plan as L, planner, telemetry
import repro.analytics.physical as PH
from repro.core.config import PlacementPolicy

n, N, D, G = 4, 512, 64, 9
rng = np.random.RandomState(3)
key1 = rng.randint(0, G, N).astype(np.int32)
fk = rng.randint(0, D + 16, N).astype(np.int32)   # ~1 in 5 misses
d = rng.randint(0, 100, N).astype(np.int32)
v = rng.randn(N).astype(np.float32)
tables = {
    "fact": {"key1": jnp.asarray(key1), "fk": jnp.asarray(fk),
             "d": jnp.asarray(d), "v": jnp.asarray(v)},
    "dim": {"pk": jnp.asarray(np.arange(D, dtype=np.int32)),
            "dv": jnp.asarray(rng.rand(D).astype(np.float32))},
}
p = L.LogicalPlan(
    L.scan("fact").filter(L.col("d") < 50)
    .join(L.scan("dim"), "fk", "pk", {"dv": "dv"})
    .aggregate("key1", G, c=("count", "v"), x=("max", "v")), ("c", "x"))
mesh = Mesh(np.array(jax.devices()[:n]), ("data",))
ctx = planner.ExecutionContext(executor="cost", mesh=mesh,
                               policy=PlacementPolicy.INTERLEAVE,
                               dist_join="partitioned", dist_route="modulo")
planner.set_cost_profile(None)
with telemetry.recording() as reg:
    cp = planner.compile_plan(p, tables, ctx)
    cp(tables)
ps = reg.get(cp.cache_key)
assert ps is not None and ps.executions == 1

# numpy ground truth: block row sharding (in_specs=P(axis)), modulo owner
alive = d < 50
home = np.arange(N) // (N // n)
exp = {
    "fk": {"alive_in": int(alive.sum()),
           "moved": int((alive & (fk % n != home)).sum())},
    "pk": {"alive_in": D,
           "moved": int((np.arange(D) % n != np.arange(D) // (D // n)).sum())},
}
nodes = ps.node_list()
seen = set()
for i, ns in ps.nodes.items():
    node = nodes[i]
    if isinstance(node, PH.Exchange) and node.key in exp:
        want = exp[node.key]
        assert ns.last["alive_in"] == want["alive_in"], (node.key, ns.last)
        assert ns.last["moved"] == want["moved"], (node.key, ns.last)
        # conservation: routing loses nothing when nothing overflowed
        assert ns.last["overflow"] == 0
        assert ns.last["alive_out"] == ns.last["alive_in"]
        seen.add(node.key)
    if isinstance(node, PH.PJoin) and node.dist is not None:
        matched = int((alive & (fk < D)).sum())
        assert ns.last["probe_alive"] == int(alive.sum())
        assert ns.last["build_alive"] == D
        assert ns.last["out_alive"] == matched
    if isinstance(node, PH.PAggregate) and node.key is not None:
        occ = len(np.unique(key1[alive & (fk < D)]))
        assert ns.last["groups_occupied"] == occ, ns.last
assert seen == {"fk", "pk"}, seen
print("CONSERVATION_OK")
"""


def test_recorded_stats_match_numpy_recomputation():
    out = run_with_devices(CONSERVATION_CODE, n_devices=4)
    assert "CONSERVATION_OK" in out


# ---------------------------------------------------------------------------
# adaptive re-planning (the ISSUE acceptance scenario)
# ---------------------------------------------------------------------------
REPLAN_CODE = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.analytics import plan as L, planner, telemetry
import repro.analytics.physical as PH
from repro.core.config import PlacementPolicy

# Sized so the wire-cost model sits between the two strategies:
#   broadcast     = 3 * build_rows            = 1728
#   partitioned   = 0.75 * f * (probe + build)
# fault-free f=1.5  -> 1512  < 1728: partitioned
# mis-priced f=3.0  -> 3024  > 1728: broadcast (the wrong call — the
# probe filter keeps only ~10% of rows, which static costing cannot see)
rng = np.random.RandomState(7)
N, D = 768, 576
tables = {
    "fact": {"fk": jnp.asarray(rng.randint(0, D, N).astype(np.int32)),
             "fv": jnp.asarray(rng.rand(N).astype(np.float32))},
    "dim": {"pk": jnp.asarray(np.arange(D, dtype=np.int32)),
            "dv": jnp.asarray(rng.rand(D).astype(np.float32))},
}
j = (L.scan("fact").filter(L.col("fv") < 0.1)
     .join(L.scan("dim"), "fk", "pk", {"dv": "dv"}))
p = L.LogicalPlan(j.aggregate("fk", D, c=("count", "fv"),
                              m=("median", "dv"), x=("max", "fv")),
                  ("c", "m", "x"))
mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
ctx = planner.ExecutionContext(executor="cost", mesh=mesh,
                               policy=PlacementPolicy.INTERLEAVE)

# fault-free run: the default profile picks partitioned statically
planner.set_cost_profile(None)
cp_good = planner.compile_plan(p, tables, ctx)
assert "dist=partitioned" in PH.describe(cp_good.physical)
ref = cp_good(tables)

# mis-priced profile: routing priced 2x too high -> broadcast
planner.set_cost_profile(planner.CostProfile(dist_route_factor=3.0))
telemetry.registry().clear()
with telemetry.recording() as reg:
    cp1 = planner.compile_plan(p, tables, ctx)
    assert "dist=broadcast" in PH.describe(cp1.physical)
    out1 = cp1(tables)                       # records ~10% probe alive
    assert reg.should_replan(cp1.cache_key)
    cp2 = planner.compile_plan(p, tables, ctx)   # cache HIT -> replan
    assert "dist=partitioned" in PH.describe(cp2.physical), \\
        PH.describe(cp2.physical)
    out2 = cp2(tables)

# the replanned tree IS the fault-free tree (same Decision, same est
# bookkeeping: only the cost comparison consumed the observed rows)
assert cp2.physical == cp_good.physical
assert reg.summary()["replans"] == 1
# bit-identical results across broadcast, replanned, and fault-free runs
for k in ("c", "m", "x"):
    a, b, c = (np.asarray(ref[k]), np.asarray(out1[k]), np.asarray(out2[k]))
    assert np.array_equal(a, b, equal_nan=True), k
    assert np.array_equal(a, c, equal_nan=True), k
# and the drifting profile entry is pulled back toward observed traffic
prof = telemetry.refresh_profile()
assert prof.source == "telemetry"
assert prof.dist_route_factor < 3.0 / telemetry.DRIFT_BAND, \\
    prof.dist_route_factor
print("REPLAN_OK replans=%d factor=%s"
      % (reg.summary()["replans"], prof.dist_route_factor))
"""


def test_mispriced_profile_triggers_replan_flip():
    out = run_with_devices(REPLAN_CODE, n_devices=4)
    assert "REPLAN_OK replans=1" in out


# ---------------------------------------------------------------------------
# serving integration: ServiceStats surfaces the registry counters
# ---------------------------------------------------------------------------
def test_service_stats_surface_telemetry():
    from repro.analytics.service import AnalyticsService, ServiceConfig
    from repro.analytics.tpch import generate, run_query, submit_query

    data = generate(scale=0.004, seed=1)
    ctx = planner.ExecutionContext(executor="cost")
    ref = run_query("q3", data, context=ctx)
    with telemetry.recording():
        with AnalyticsService(ServiceConfig(n_pools=1,
                                            workers_per_pool=1)) as svc:
            rid = submit_query(svc, "q3", data, context=ctx)
            got = svc.drain()[rid].value
            st = svc.stats()
    assert st.plans_tracked >= 1
    assert st.telemetry_executions >= 1
    assert st.replans == 0          # nothing to flip on a local plan
    # tracked serving stays bit-identical to the serial untracked run
    assert set(got) == set(ref)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(ref[k]), err_msg=k)
    # and with telemetry off the service reports zeroed counters
    telemetry.registry().clear()
    with AnalyticsService(ServiceConfig(n_pools=1,
                                        workers_per_pool=1)) as svc:
        submit_query(svc, "q6", data, context=ctx)
        svc.drain()
        st2 = svc.stats()
    assert st2.plans_tracked == 0 and st2.telemetry_executions == 0
