"""Concurrent query-serving subsystem (analytics/service/).

Covers: served-vs-serial BIT-IDENTICAL parity on all five TPC-H queries
for every ThreadPlacement (locally) and ThreadPlacement x PlacementPolicy
(on a subprocess mesh), morsel-boundary correctness when n_rows is not
divisible by the morsel size, batcher key-grouping and dedup dispatch,
work-steal counter consistency, admission backpressure + deadlines, a
seeded deterministic throughput smoke test, and thread-safety of the
shared plan cache under concurrent run_query traffic.
"""
import threading
import time

import numpy as np
import pytest

from conftest import run_with_devices

from repro.analytics import planner
from repro.analytics.engine import merge_morsel_partials, morsel_slices
from repro.analytics.planner import (ExecutionContext, configure_plan_cache,
                                     plan_cache_info)
from repro.analytics.service import (AnalyticsService, QueryBatcher,
                                     ServiceConfig, ThreadPlacement)
from repro.analytics.service.queue import QueryRequest
from repro.analytics.service.scheduler import MorselScheduler
from repro.analytics.tpch import (LOGICAL_QUERIES, generate, run_query,
                                  submit_query)


@pytest.fixture(scope="module")
def data():
    return generate(scale=0.004, seed=1)


@pytest.fixture(autouse=True)
def _restore_planner_config():
    yield
    configure_plan_cache(planner.DEFAULT_PLAN_CACHE_ENTRIES)
    planner.set_cost_profile(None)


def _assert_bit_identical(got, ref, label):
    assert set(got) == set(ref), label
    for k in ref:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(ref[k]),
                                      err_msg=f"{label}/{k}")


# ---------------------------------------------------------------------------
# served results == serial run_query, bit for bit (whole-plan dispatch)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("placement", list(ThreadPlacement))
def test_served_bit_identical_all_queries(data, placement):
    ctx = ExecutionContext(executor="cost")
    refs = {n: run_query(n, data, context=ctx) for n in LOGICAL_QUERIES}
    with AnalyticsService(ServiceConfig(n_pools=2, workers_per_pool=2,
                                        placement=placement)) as svc:
        rids = {n: submit_query(svc, n, data, context=ctx)
                for n in LOGICAL_QUERIES}
        results = svc.drain()
        st = svc.stats()
    assert st.completed == len(LOGICAL_QUERIES)
    for name, rid in rids.items():
        _assert_bit_identical(results[rid].value, refs[name],
                              f"{name}/{placement.value}")


def test_submit_query_defaults_match_run_query(data):
    """submit_query and run_query share defaults: calling both bare must
    compare bit-identical (they resolve to the same plan-cache entry)."""
    ref = run_query("q6", data)
    with AnalyticsService(ServiceConfig(n_pools=1,
                                        workers_per_pool=1)) as svc:
        rid = submit_query(svc, "q6", data)
        got = svc.drain()[rid].value
    _assert_bit_identical(got, ref, "defaults")


DIST_SERVE_TEST = """
import numpy as np, jax
from repro.analytics.planner import ExecutionContext
from repro.analytics.service import AnalyticsService, ServiceConfig, ThreadPlacement
from repro.analytics.tpch import LOGICAL_QUERIES, generate, run_query, submit_query
from repro.core.config import PlacementPolicy

mesh = jax.make_mesh((4,), ("data",))
data = generate(scale=0.004, seed=1)
for pol in (PlacementPolicy.FIRST_TOUCH, PlacementPolicy.INTERLEAVE):
    ctx = ExecutionContext(executor="cost", mesh=mesh, policy=pol,
                           capacity_factor=4.0)
    refs = {n: run_query(n, data, context=ctx) for n in LOGICAL_QUERIES}
    for placement in ThreadPlacement:
        with AnalyticsService(ServiceConfig(n_pools=2, workers_per_pool=2,
                                            placement=placement)) as svc:
            rids = {n: submit_query(svc, n, data, context=ctx)
                    for n in LOGICAL_QUERIES}
            results = svc.drain()
        for name, rid in rids.items():
            got, ref = results[rid].value, refs[name]
            assert set(got) == set(ref), (name, pol, placement)
            for k in ref:
                np.testing.assert_array_equal(
                    np.asarray(got[k]), np.asarray(ref[k]),
                    err_msg=f"{name}/{pol}/{placement}/{k}")
print("DIST_SERVE_OK")
"""


def test_served_bit_identical_under_placement_policies():
    """ThreadPlacement x PlacementPolicy grid on a real shard_map mesh:
    the served result must be bit-identical to serial run_query under the
    SAME context for every combination."""
    out = run_with_devices(DIST_SERVE_TEST, n_devices=4, timeout=900)
    assert "DIST_SERVE_OK" in out


# ---------------------------------------------------------------------------
# morsel-driven execution
# ---------------------------------------------------------------------------
def test_morsel_slices_boundaries():
    assert morsel_slices(10, None) == [(0, 10)]
    assert morsel_slices(10, 100) == [(0, 10)]
    assert morsel_slices(10, 4) == [(0, 4), (4, 8), (8, 10)]
    assert morsel_slices(12, 4) == [(0, 4), (4, 8), (8, 12)]
    with pytest.raises(ValueError):
        morsel_slices(10, 0)
    with pytest.raises(ValueError):
        merge_morsel_partials([])


@pytest.mark.parametrize("name", ["q1", "q6"])
def test_morsel_boundary_correctness(data, name):
    """n_rows NOT divisible by morsel size: the tail morsel must carry the
    remainder, counts must be exact, sums allclose to the serial plan."""
    n_li = data.tables["lineitem"]["l_orderkey"].shape[0]
    morsel = 997
    assert n_li % morsel != 0
    ref = run_query(name, data, executor="xla")
    with AnalyticsService(ServiceConfig(
            n_pools=2, workers_per_pool=2, morsel_rows=morsel,
            placement=ThreadPlacement.SPARSE)) as svc:
        rid = submit_query(svc, name, data, executor="xla")
        got = svc.drain()[rid].value
        st = svc.stats()
    expect_morsels = -(-n_li // morsel)
    assert st.morsels == expect_morsels
    assert set(got) == set(ref)
    for k in ref:
        if k in ("_count", "count_order", "_overflow"):
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(ref[k]),
                                          err_msg=f"{name}/{k}")
        else:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(ref[k]),
                                       atol=1e-2, rtol=1e-5,
                                       err_msg=f"{name}/{k}")


def test_split_probe_plans_serve_bit_identical(data):
    """Join pipelines (q3, q5, q18) become SPLIT-PROBE tasks when
    morsel_rows is set: each probe side fans out into per-pool morsels
    (the exact count: ceil(probe_rows / morsel_rows) per query) and the
    served result stays bit-identical to serial run_query — the merge is
    a morsel-order row concat, never a float re-ordering."""
    ctx = ExecutionContext(executor="cost")
    refs = {n: run_query(n, data, context=ctx) for n in ("q3", "q5", "q18")}
    with AnalyticsService(ServiceConfig(n_pools=2, workers_per_pool=1,
                                        morsel_rows=1000)) as svc:
        rids = {n: submit_query(svc, n, data, context=ctx) for n in refs}
        results = svc.drain()
        st = svc.stats()
    n_li = data.tables["lineitem"]["l_orderkey"].shape[0]
    n_ord = data.tables["orders"]["o_orderkey"].shape[0]
    # q3 and q5 probe lineitem; q18's on-path probe is orders
    expect = 2 * -(-n_li // 1000) + -(-n_ord // 1000)
    assert st.morsels == expect
    for name, rid in rids.items():
        _assert_bit_identical(results[rid].value, refs[name], name)


def test_sub_threshold_probes_serve_whole(data):
    """Below the profile's morsel_split_rows the planner declines to
    split: the same joins dispatch as ONE whole-plan morsel each (the
    cost model's call, not a capability limit) and stay bit-identical."""
    import dataclasses
    planner.set_cost_profile(dataclasses.replace(
        planner.current_cost_profile(), morsel_split_rows=1 << 30))
    ctx = ExecutionContext(executor="cost")
    refs = {n: run_query(n, data, context=ctx) for n in ("q3", "q5", "q18")}
    with AnalyticsService(ServiceConfig(n_pools=2, workers_per_pool=1,
                                        morsel_rows=1000)) as svc:
        rids = {n: submit_query(svc, n, data, context=ctx) for n in refs}
        results = svc.drain()
        st = svc.stats()
    assert st.morsels == len(refs)       # one whole-plan morsel each
    for name, rid in rids.items():
        _assert_bit_identical(results[rid].value, refs[name], name)


# ---------------------------------------------------------------------------
# batcher: plan-cache-key grouping and dedup dispatch
# ---------------------------------------------------------------------------
def test_batcher_key_grouping(data):
    tables = data.as_jax()
    ctx_a = ExecutionContext(executor="cost")
    ctx_b = ExecutionContext(executor="xla")
    rebuilt = {t: dict(cols) for t, cols in tables.items()}
    reqs = [
        QueryRequest(0, LOGICAL_QUERIES["q1"], tables, ctx_a),
        QueryRequest(1, LOGICAL_QUERIES["q1"], tables, ctx_a),   # dedup peer
        QueryRequest(2, LOGICAL_QUERIES["q1"], tables, ctx_b),   # other ctx
        QueryRequest(3, LOGICAL_QUERIES["q3"], tables, ctx_a),   # other plan
        QueryRequest(4, LOGICAL_QUERIES["q1"], rebuilt, ctx_a),  # other data
    ]
    b = QueryBatcher()
    groups = b.group(reqs)
    assert len(groups) == 3
    # q1/ctx_a formed ONE batch with both tables identities inside
    q1a = [g for g in groups if g.requests[0].req_id == 0][0]
    assert sorted(r.req_id for r in q1a.requests) == [0, 1, 4]
    assert sorted(len(s) for s in q1a.shares) == [1, 2]
    st = b.stats()
    assert st.batches == 3
    assert st.batched_queries == 3       # only q1a had peers (reqs 0,1,4)
    # 4 shares total across the 3 batches (q1a splits into 2 table shares);
    # dispatch/dedup outcomes are counted by the service at submit time
    assert sum(len(g.shares) for g in groups) == 4


def test_batched_service_dedups_hot_path(data):
    """32x the same plan-cache-hot query = ONE dispatch fanned out; the
    >=1.5x QPS acceptance criterion follows mechanically (the benchmark
    measures it; here we pin the dispatch accounting)."""
    ctx = ExecutionContext(executor="cost")
    ref = run_query("q1", data, context=ctx)
    with AnalyticsService(ServiceConfig(n_pools=2,
                                        workers_per_pool=2)) as svc:
        rids = [submit_query(svc, "q1", data, context=ctx)
                for _ in range(32)]
        results = svc.drain()
        st = svc.stats()
    assert st.completed == 32
    assert st.dispatches == 1
    assert st.dedup_hits == 31
    for rid in rids:
        assert results[rid].batch_size == 32
        _assert_bit_identical(results[rid].value, ref, "q1-hot")


# ---------------------------------------------------------------------------
# work stealing
# ---------------------------------------------------------------------------
def test_work_steal_counters(data):
    """DENSE packs every morsel of the task onto one pool; a second
    single-worker pool can only obtain work by stealing. Invariants: all
    morsels execute exactly once, and non-home executions == steals."""
    tables = data.as_jax()
    sched = MorselScheduler(n_pools=2, workers_per_pool=1,
                            placement=ThreadPlacement.DENSE,
                            morsel_rows=500, started=False)
    task = sched.build_task(LOGICAL_QUERIES["q1"], tables,
                            ExecutionContext(executor="xla"))
    assert len(task.morsels) == 48       # 24000 rows / 500
    sched.submit(task)                   # staged before any worker runs
    homes = [m.home_pool for m in task.morsels]
    assert len(set(homes)) == 1          # DENSE: one pool owns everything
    sched.start()
    got = task.wait(timeout=120)
    st = sched.stats()
    sched.close()
    assert sum(st.executed_per_pool) == st.morsels_dispatched == 48
    non_home = st.executed_per_pool[1 - homes[0]]
    assert st.steals == non_home         # every non-home execution = a steal
    assert st.steals >= 1                # the idle pool did steal
    ref = run_query("q1", data, executor="xla")
    for k in ref:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   atol=1e-2, rtol=1e-5, err_msg=k)


def test_sparse_distributes_whole_plan_tasks(data):
    """Whole-plan tasks have a single morsel (seq 0): SPARSE must still
    stripe successive tasks across pools (per-task rotating base), not pin
    them all to pool 0 with stealing papering over the starvation."""
    tables = data.as_jax()
    sched = MorselScheduler(n_pools=2, workers_per_pool=1,
                            placement=ThreadPlacement.SPARSE, steal=False,
                            started=False)
    ctx = ExecutionContext(executor="xla")
    tasks = [sched.build_task(LOGICAL_QUERIES["q6"], tables, ctx)
             for _ in range(6)]
    for t in tasks:
        sched.submit(t)
    assert {t.morsels[0].home_pool for t in tasks} == {0, 1}
    sched.start()
    for t in tasks:
        assert t.wait(timeout=120) is not None
    st = sched.stats()
    sched.close()
    assert all(e == 3 for e in st.executed_per_pool)
    assert st.steals == 0                # no stealing needed, none counted


# ---------------------------------------------------------------------------
# admission: backpressure + deadlines
# ---------------------------------------------------------------------------
def test_backpressure_and_deadlines(data):
    ctx = ExecutionContext(executor="cost")
    run_query("q1", data, context=ctx)           # warm the plan cache
    with AnalyticsService(ServiceConfig(queue_depth=2, n_pools=1,
                                        workers_per_pool=1)) as svc:
        r0 = submit_query(svc, "q1", data, context=ctx)
        r1 = submit_query(svc, "q1", data, context=ctx, deadline_s=-1.0)
        r2 = submit_query(svc, "q1", data, context=ctx)
        assert r0 is not None and r1 is not None
        assert r2 is None                        # bounded queue pushed back
        results = svc.drain()
        st = svc.stats()
    assert st.rejected == 1 and st.expired == 1 and st.completed == 1
    assert results[r0].value is not None
    assert results[r1].expired and results[r1].value is None


def test_failed_dispatch_is_isolated(data):
    """A malformed query must fail alone: co-submitted clients still get
    their results and the failure is attributed on the bad request."""
    from repro.analytics.plan import LogicalPlan, scan
    bad_plan = LogicalPlan(
        scan("lineitem").aggregate("no_such_column", 4,
                                   s=("sum", "l_quantity")))
    ctx = ExecutionContext(executor="cost")
    ref = run_query("q1", data, context=ctx)
    with AnalyticsService(ServiceConfig(n_pools=2,
                                        workers_per_pool=2)) as svc:
        good = submit_query(svc, "q1", data, context=ctx)
        bad = svc.submit(bad_plan, data.as_jax(), context=ctx)
        results = svc.drain()
        st = svc.stats()
    assert st.completed == 1 and st.failed == 1
    _assert_bit_identical(results[good].value, ref, "good-alongside-bad")
    assert results[bad].value is None
    assert results[bad].error and "no_such_column" in results[bad].error

    # the EAGER failure path: with morsel_rows set, a plan naming a table
    # its mapping lacks raises at build_task (morsel decompose), before
    # any worker runs — must also be isolated to its own share
    missing = LogicalPlan(
        scan("no_such_table").aggregate("x", 2, s=("sum", "x")))
    with AnalyticsService(ServiceConfig(n_pools=1, workers_per_pool=1,
                                        morsel_rows=1000)) as svc:
        good = submit_query(svc, "q1", data, executor="xla")
        bad1 = svc.submit(missing, data.as_jax())
        bad2 = svc.submit(missing, data.as_jax())   # dedup peer that fails
        results = svc.drain()
        st = svc.stats()
    assert st.completed == 1 and st.failed == 2
    assert results[good].value is not None
    for bad in (bad1, bad2):
        assert results[bad].value is None and results[bad].error
    # a share that never dispatched must not count dispatches or dedup hits
    assert st.dispatches == 1 and st.dedup_hits == 0


# ---------------------------------------------------------------------------
# seeded deterministic throughput smoke
# ---------------------------------------------------------------------------
def test_throughput_smoke(data):
    names = [("q1", "q3", "q6")[i % 3] for i in range(18)]
    ctx = ExecutionContext(executor="cost")
    for n in set(names):
        run_query(n, data, context=ctx)          # hot path only
    with AnalyticsService(ServiceConfig(n_pools=2, workers_per_pool=2,
                                        morsel_rows=4000)) as svc:
        rids = [submit_query(svc, n, data, context=ctx) for n in names]
        results = svc.drain()
        st = svc.stats()
    assert st.completed == len(names) == st.admitted
    assert all(results[r].value is not None for r in rids)
    assert st.dispatches == 3                    # one per distinct query
    assert st.dedup_hits == len(names) - 3
    assert st.qps > 0
    assert st.latency_p99_ms >= st.latency_p50_ms >= 0
    assert st.queue_wait_p99_ms >= st.queue_wait_p50_ms >= 0
    # qps denominates over time spent serving: idling afterwards (a
    # long-lived service between bursts) must not decay the reported rate
    time.sleep(0.2)
    assert svc.stats().qps == pytest.approx(st.qps)


# ---------------------------------------------------------------------------
# shared plan cache under concurrent traffic
# ---------------------------------------------------------------------------
def test_plan_cache_thread_safe_under_concurrency(data):
    """Hammer a 4-entry cache (forced evictions) from 8 threads; unlocked
    this raced move_to_end/popitem into KeyErrors and dropped counter
    increments. Counters must balance exactly: every lookup is one hit or
    one miss."""
    planner.clear_plan_cache()
    configure_plan_cache(4)
    names = sorted(LOGICAL_QUERIES)
    errors = []
    before = plan_cache_info()
    calls_per_thread = 12

    def hammer(seed):
        try:
            for i in range(calls_per_thread):
                name = names[(seed + i) % len(names)]
                ex = ("xla", "cost")[(seed + i) % 2]
                run_query(name, data, executor=ex)
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(s,)) for s in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    info = plan_cache_info()
    lookups = (info.hits - before.hits) + (info.misses - before.misses)
    assert lookups == 8 * calls_per_thread
    assert info.currsize <= 4


# ---------------------------------------------------------------------------
# priority classes, weighted fairness, overload shedding
# ---------------------------------------------------------------------------
def test_priority_and_weighted_fair_dequeue():
    """Strict priority across classes; weighted-fair round-robin across
    clients within a class (a weight-2 client gets two slots per turn)."""
    from repro.analytics.service import AdmissionQueue
    q = AdmissionQueue(max_depth=64, client_weights={1: 2})
    rid = 0
    for prio, cid, n in [(0, 0, 3), (2, 0, 2), (1, 0, 4), (1, 1, 4)]:
        for _ in range(n):
            assert q.offer(QueryRequest(rid, None, {}, None,
                                        client_id=cid, priority=prio))
            rid += 1
    live, shed = q.take_batch(13)
    assert not shed
    order = [(r.priority, r.client_id) for r in live]
    # class 2 first, then class 1 interleaved 1:2 by weight, class 0 last
    assert order[:2] == [(2, 0), (2, 0)]
    assert order[-3:] == [(0, 0)] * 3
    mid = order[2:10]                            # the class-1 segment
    assert mid.count((1, 0)) == 4 and mid.count((1, 1)) == 4
    # weight 2 => client 1 takes two consecutive slots per turn
    assert mid[:3] in ([(1, 0), (1, 1), (1, 1)], [(1, 1), (1, 1), (1, 0)])
    st = q.stats()
    assert st.admitted == st.dequeued + st.expired + st.shed_overload \
        + st.depth


def test_overload_shedding_lowest_priority_first():
    from repro.analytics.service import AdmissionQueue
    q = AdmissionQueue(max_depth=8, shed_watermark=4)
    for rid in range(4):
        assert q.offer(QueryRequest(rid, None, {}, None, priority=0))
    # a high-priority arrival past the watermark evicts a class-0 victim
    assert q.offer(QueryRequest(100, None, {}, None, priority=2))
    victims = q.pop_overload_shed()
    assert [v.req_id for v in victims] == [3]    # newest of the flooder
    # an arrival that is itself lowest-class gets backpressure, not a slot
    assert not q.offer(QueryRequest(101, None, {}, None, priority=0))
    st = q.stats()
    assert st.shed_overload == 1 and st.rejected_full == 1
    assert st.admitted == st.dequeued + st.expired + st.shed_overload \
        + st.depth


def test_service_overload_sheds_and_reports(data):
    """Past the watermark, low-priority queued work is evicted for
    high-priority arrivals — and still gets a terminal (shed) result."""
    ctx = ExecutionContext(executor="cost")
    run_query("q1", data, context=ctx)
    run_query("q6", data, context=ctx)
    cfg = ServiceConfig(n_pools=1, workers_per_pool=1, queue_depth=8,
                        shed_watermark=4)
    with AnalyticsService(cfg) as svc:
        low = [submit_query(svc, "q6", data, context=ctx, priority=0,
                            client_id=0) for _ in range(4)]
        high = [submit_query(svc, "q1", data, context=ctx, priority=2,
                             client_id=1) for _ in range(2)]
        results = svc.drain()
        st = svc.stats()
    assert all(r is not None for r in low + high)
    shed = [r for r in low if results[r].shed]
    assert len(shed) == 2 and st.shed == 2
    assert all(results[r].value is not None for r in high)
    assert st.completed == 4
    assert st.per_class[0].shed == 2 and st.per_class[2].completed == 2
    assert st.admitted == st.completed + st.failed + st.expired + st.shed


def test_admission_queue_concurrent_conservation():
    """Hammer offer/take_batch/shed_expired from concurrent threads: every
    admitted request must come out exactly once (dequeued, expired, or
    overload-shed) and the stats must conserve exactly — no drops, no
    double-counts, no torn snapshots."""
    from repro.analytics.service import AdmissionQueue
    q = AdmissionQueue(max_depth=32, shed_watermark=32)
    n_producers, per_producer = 4, 300
    offered_ok = [0] * n_producers
    taken, stop = [], threading.Event()
    take_lock = threading.Lock()

    def produce(pid):
        now = time.monotonic()
        for i in range(per_producer):
            # ~1/5 requests arrive already expired; priorities cycle
            dl = (now - 1.0) if i % 5 == 0 else None
            req = QueryRequest(pid * 100000 + i, None, {}, None,
                               deadline_s=dl, client_id=pid,
                               priority=i % 3)
            while not q.offer(req):           # bounded: spin on pushback
                time.sleep(0.0002)
            offered_ok[pid] += 1

    def consume():
        while not (stop.is_set() and len(q) == 0):
            live, expired = q.take_batch(7)
            swept = q.shed_expired()
            victims = q.pop_overload_shed()
            with take_lock:
                taken.extend(live + expired + swept + victims)
            if not (live or expired or swept or victims):
                time.sleep(0.0002)

    producers = [threading.Thread(target=produce, args=(p,))
                 for p in range(n_producers)]
    consumers = [threading.Thread(target=consume) for _ in range(3)]
    for t in producers + consumers:
        t.start()
    for t in producers:
        t.join()
    stop.set()
    for t in consumers:
        t.join()
    st = q.stats()
    assert sum(offered_ok) == st.admitted == n_producers * per_producer
    # exact conservation: admitted == taken out (by any path) + remaining
    assert st.admitted == len(taken) + st.depth and st.depth == 0
    assert len({r.req_id for r in taken}) == len(taken)  # exactly once
    assert st.admitted == st.dequeued + st.expired + st.shed_overload \
        + st.depth
    per_cls = st.by_class
    for p, c in per_cls.items():
        assert c["admitted"] == c["dequeued"] + c["expired"] + c["shed"], p


# ---------------------------------------------------------------------------
# drain deadline staleness + worker-leak reporting
# ---------------------------------------------------------------------------
def test_drain_sheds_requests_that_expire_mid_drain(data):
    """A request whose deadline passes while an EARLIER round is being
    served must be shed (counted expired), never dispatched late."""
    from repro.analytics.service import ServiceFaultInjector
    ctx = ExecutionContext(executor="cost")
    run_query("q6", data, context=ctx)
    run_query("q1", data, context=ctx)
    faults = ServiceFaultInjector(straggle_pool=(0, 0.4))  # slow round 1
    cfg = ServiceConfig(n_pools=1, workers_per_pool=1, max_batch=1,
                        faults=faults, retry=None)
    with AnalyticsService(cfg) as svc:
        r1 = submit_query(svc, "q6", data, context=ctx)
        r2 = submit_query(svc, "q1", data, context=ctx, deadline_s=0.1)
        results = svc.drain()
        st = svc.stats()
    assert results[r1].value is not None
    assert results[r2].expired and results[r2].value is None
    assert st.expired == 1
    assert st.dispatches == 1                    # r2 never reached a pool


def test_close_reports_unjoined_workers(data):
    """close() must name workers it could not join instead of silently
    leaking them; AnalyticsService.close() raises WorkerLeakError."""
    from repro.analytics.service import ServiceFaultInjector, WorkerLeakError
    ctx = ExecutionContext(executor="cost")
    run_query("q6", data, context=ctx)
    faults = ServiceFaultInjector(straggle_pool=(0, 1.5))
    cfg = ServiceConfig(n_pools=1, workers_per_pool=1, faults=faults,
                        retry=None, close_timeout_s=0.1)
    svc = AnalyticsService(cfg)
    rid = submit_query(svc, "q6", data, context=ctx)
    t = threading.Thread(target=svc.drain, daemon=True)
    t.start()
    time.sleep(0.3)                  # worker is now mid-straggle
    with pytest.raises(WorkerLeakError) as ei:
        svc.close()
    assert "pool0" in str(ei.value) and ei.value.unjoined
    t.join(timeout=30)
    assert rid is not None


# ---------------------------------------------------------------------------
# always-on serving: background drain loop + adaptive batching window
# ---------------------------------------------------------------------------
def test_adaptive_batch_window_grows_and_shrinks():
    from repro.analytics.service import AdaptiveBatchWindow
    w = AdaptiveBatchWindow(1, 16)
    assert w.window == 1
    assert w.observe(8) == 2 and w.observe(8) == 4
    assert w.observe(100) == 8 and w.observe(100) == 16
    assert w.observe(100) == 16                  # clamped at max
    assert w.observe(3) == 16                    # backlog <= window: hold
    assert w.observe(0) == 8 and w.observe(0) == 4
    for _ in range(8):
        w.observe(0)
    assert w.window == 1                         # clamped at min
    with pytest.raises(ValueError):
        AdaptiveBatchWindow(0, 4)


def test_always_on_serve_loop(data):
    """start() serves admissions in the background: results arrive via
    result()/drain() without an explicit drain round per burst, and the
    served values stay bit-identical to serial."""
    ctx = ExecutionContext(executor="cost")
    refs = {n: run_query(n, data, context=ctx) for n in LOGICAL_QUERIES}
    cfg = ServiceConfig(n_pools=2, workers_per_pool=2, min_batch=1,
                        max_batch=8)
    with AnalyticsService(cfg) as svc:
        svc.start()
        assert svc.serving
        first = submit_query(svc, "q6", data, context=ctx)
        res = svc.result(first, timeout=60.0)
        assert res is not None and res.error is None
        _assert_bit_identical(res.value, refs["q6"], "loop/first")
        # a burst while the loop is live: drain() waits for quiescence
        rids = {n: submit_query(svc, n, data, context=ctx)
                for n in LOGICAL_QUERIES}
        results = svc.drain(timeout=120.0)
        svc.stop()
        assert not svc.serving
        st = svc.stats()
    for name, rid in rids.items():
        _assert_bit_identical(results[rid].value, refs[name], f"loop/{name}")
    assert st.completed == len(LOGICAL_QUERIES) + 1
    assert st.admitted == st.completed + st.failed + st.expired + st.shed


def test_stop_drains_backlog(data):
    """stop() (default drain=True) serves everything already admitted
    before the loop exits — no request is left without a result."""
    ctx = ExecutionContext(executor="cost")
    run_query("q6", data, context=ctx)
    with AnalyticsService(ServiceConfig(n_pools=1,
                                        workers_per_pool=1)) as svc:
        svc.start()
        rids = [submit_query(svc, "q6", data, context=ctx)
                for _ in range(6)]
        svc.stop()
        results = svc.take_results()
        st = svc.stats()
    assert sorted(results) == sorted(rids)
    assert st.completed == len(rids)


def test_per_class_slo_attainment(data):
    ctx = ExecutionContext(executor="cost")
    run_query("q6", data, context=ctx)
    with AnalyticsService(ServiceConfig(n_pools=1,
                                        workers_per_pool=1)) as svc:
        met = [submit_query(svc, "q6", data, context=ctx, priority=2,
                            deadline_s=120.0) for _ in range(3)]
        missed = submit_query(svc, "q6", data, context=ctx, priority=0,
                              deadline_s=-1.0)   # expired on arrival
        results = svc.drain()
        st = svc.stats()
    assert all(results[r].value is not None for r in met)
    assert results[missed].expired
    assert st.per_class[2].deadline_total == 3
    assert st.per_class[2].slo_attainment == 1.0
    assert st.per_class[0].deadline_total == 1
    assert st.per_class[0].slo_attainment == 0.0
    assert st.per_class[0].expired == 1
