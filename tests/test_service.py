"""Concurrent query-serving subsystem (analytics/service/).

Covers: served-vs-serial BIT-IDENTICAL parity on all five TPC-H queries
for every ThreadPlacement (locally) and ThreadPlacement x PlacementPolicy
(on a subprocess mesh), morsel-boundary correctness when n_rows is not
divisible by the morsel size, batcher key-grouping and dedup dispatch,
work-steal counter consistency, admission backpressure + deadlines, a
seeded deterministic throughput smoke test, and thread-safety of the
shared plan cache under concurrent run_query traffic.
"""
import threading
import time

import numpy as np
import pytest

from conftest import run_with_devices

from repro.analytics import planner
from repro.analytics.engine import merge_morsel_partials, morsel_slices
from repro.analytics.planner import (ExecutionContext, configure_plan_cache,
                                     plan_cache_info)
from repro.analytics.service import (AnalyticsService, QueryBatcher,
                                     ServiceConfig, ThreadPlacement)
from repro.analytics.service.queue import QueryRequest
from repro.analytics.service.scheduler import MorselScheduler
from repro.analytics.tpch import (LOGICAL_QUERIES, generate, run_query,
                                  submit_query)


@pytest.fixture(scope="module")
def data():
    return generate(scale=0.004, seed=1)


@pytest.fixture(autouse=True)
def _restore_planner_config():
    yield
    configure_plan_cache(planner.DEFAULT_PLAN_CACHE_ENTRIES)
    planner.set_cost_profile(None)


def _assert_bit_identical(got, ref, label):
    assert set(got) == set(ref), label
    for k in ref:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(ref[k]),
                                      err_msg=f"{label}/{k}")


# ---------------------------------------------------------------------------
# served results == serial run_query, bit for bit (whole-plan dispatch)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("placement", list(ThreadPlacement))
def test_served_bit_identical_all_queries(data, placement):
    ctx = ExecutionContext(executor="cost")
    refs = {n: run_query(n, data, context=ctx) for n in LOGICAL_QUERIES}
    with AnalyticsService(ServiceConfig(n_pools=2, workers_per_pool=2,
                                        placement=placement)) as svc:
        rids = {n: submit_query(svc, n, data, context=ctx)
                for n in LOGICAL_QUERIES}
        results = svc.drain()
        st = svc.stats()
    assert st.completed == len(LOGICAL_QUERIES)
    for name, rid in rids.items():
        _assert_bit_identical(results[rid].value, refs[name],
                              f"{name}/{placement.value}")


def test_submit_query_defaults_match_run_query(data):
    """submit_query and run_query share defaults: calling both bare must
    compare bit-identical (they resolve to the same plan-cache entry)."""
    ref = run_query("q6", data)
    with AnalyticsService(ServiceConfig(n_pools=1,
                                        workers_per_pool=1)) as svc:
        rid = submit_query(svc, "q6", data)
        got = svc.drain()[rid].value
    _assert_bit_identical(got, ref, "defaults")


DIST_SERVE_TEST = """
import numpy as np, jax
from repro.analytics.planner import ExecutionContext
from repro.analytics.service import AnalyticsService, ServiceConfig, ThreadPlacement
from repro.analytics.tpch import LOGICAL_QUERIES, generate, run_query, submit_query
from repro.core.config import PlacementPolicy

mesh = jax.make_mesh((4,), ("data",))
data = generate(scale=0.004, seed=1)
for pol in (PlacementPolicy.FIRST_TOUCH, PlacementPolicy.INTERLEAVE):
    ctx = ExecutionContext(executor="cost", mesh=mesh, policy=pol,
                           capacity_factor=4.0)
    refs = {n: run_query(n, data, context=ctx) for n in LOGICAL_QUERIES}
    for placement in ThreadPlacement:
        with AnalyticsService(ServiceConfig(n_pools=2, workers_per_pool=2,
                                            placement=placement)) as svc:
            rids = {n: submit_query(svc, n, data, context=ctx)
                    for n in LOGICAL_QUERIES}
            results = svc.drain()
        for name, rid in rids.items():
            got, ref = results[rid].value, refs[name]
            assert set(got) == set(ref), (name, pol, placement)
            for k in ref:
                np.testing.assert_array_equal(
                    np.asarray(got[k]), np.asarray(ref[k]),
                    err_msg=f"{name}/{pol}/{placement}/{k}")
print("DIST_SERVE_OK")
"""


def test_served_bit_identical_under_placement_policies():
    """ThreadPlacement x PlacementPolicy grid on a real shard_map mesh:
    the served result must be bit-identical to serial run_query under the
    SAME context for every combination."""
    out = run_with_devices(DIST_SERVE_TEST, n_devices=4, timeout=900)
    assert "DIST_SERVE_OK" in out


# ---------------------------------------------------------------------------
# morsel-driven execution
# ---------------------------------------------------------------------------
def test_morsel_slices_boundaries():
    assert morsel_slices(10, None) == [(0, 10)]
    assert morsel_slices(10, 100) == [(0, 10)]
    assert morsel_slices(10, 4) == [(0, 4), (4, 8), (8, 10)]
    assert morsel_slices(12, 4) == [(0, 4), (4, 8), (8, 12)]
    with pytest.raises(ValueError):
        morsel_slices(10, 0)
    with pytest.raises(ValueError):
        merge_morsel_partials([])


@pytest.mark.parametrize("name", ["q1", "q6"])
def test_morsel_boundary_correctness(data, name):
    """n_rows NOT divisible by morsel size: the tail morsel must carry the
    remainder, counts must be exact, sums allclose to the serial plan."""
    n_li = data.tables["lineitem"]["l_orderkey"].shape[0]
    morsel = 997
    assert n_li % morsel != 0
    ref = run_query(name, data, executor="xla")
    with AnalyticsService(ServiceConfig(
            n_pools=2, workers_per_pool=2, morsel_rows=morsel,
            placement=ThreadPlacement.SPARSE)) as svc:
        rid = submit_query(svc, name, data, executor="xla")
        got = svc.drain()[rid].value
        st = svc.stats()
    expect_morsels = -(-n_li // morsel)
    assert st.morsels == expect_morsels
    assert set(got) == set(ref)
    for k in ref:
        if k in ("_count", "count_order", "_overflow"):
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(ref[k]),
                                          err_msg=f"{name}/{k}")
        else:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(ref[k]),
                                       atol=1e-2, rtol=1e-5,
                                       err_msg=f"{name}/{k}")


def test_non_decomposable_plans_serve_whole(data):
    """Joins/TopK (q3, q5, q18) must NOT be morsel-split — they execute as
    one whole-plan morsel and stay bit-identical even with morsel_rows
    set."""
    ctx = ExecutionContext(executor="cost")
    refs = {n: run_query(n, data, context=ctx) for n in ("q3", "q5", "q18")}
    with AnalyticsService(ServiceConfig(n_pools=2, workers_per_pool=1,
                                        morsel_rows=1000)) as svc:
        rids = {n: submit_query(svc, n, data, context=ctx) for n in refs}
        results = svc.drain()
        st = svc.stats()
    assert st.morsels == len(refs)       # one whole-plan morsel each
    for name, rid in rids.items():
        _assert_bit_identical(results[rid].value, refs[name], name)


# ---------------------------------------------------------------------------
# batcher: plan-cache-key grouping and dedup dispatch
# ---------------------------------------------------------------------------
def test_batcher_key_grouping(data):
    tables = data.as_jax()
    ctx_a = ExecutionContext(executor="cost")
    ctx_b = ExecutionContext(executor="xla")
    rebuilt = {t: dict(cols) for t, cols in tables.items()}
    reqs = [
        QueryRequest(0, LOGICAL_QUERIES["q1"], tables, ctx_a),
        QueryRequest(1, LOGICAL_QUERIES["q1"], tables, ctx_a),   # dedup peer
        QueryRequest(2, LOGICAL_QUERIES["q1"], tables, ctx_b),   # other ctx
        QueryRequest(3, LOGICAL_QUERIES["q3"], tables, ctx_a),   # other plan
        QueryRequest(4, LOGICAL_QUERIES["q1"], rebuilt, ctx_a),  # other data
    ]
    b = QueryBatcher()
    groups = b.group(reqs)
    assert len(groups) == 3
    # q1/ctx_a formed ONE batch with both tables identities inside
    q1a = [g for g in groups if g.requests[0].req_id == 0][0]
    assert sorted(r.req_id for r in q1a.requests) == [0, 1, 4]
    assert sorted(len(s) for s in q1a.shares) == [1, 2]
    st = b.stats()
    assert st.batches == 3
    assert st.batched_queries == 3       # only q1a had peers (reqs 0,1,4)
    # 4 shares total across the 3 batches (q1a splits into 2 table shares);
    # dispatch/dedup outcomes are counted by the service at submit time
    assert sum(len(g.shares) for g in groups) == 4


def test_batched_service_dedups_hot_path(data):
    """32x the same plan-cache-hot query = ONE dispatch fanned out; the
    >=1.5x QPS acceptance criterion follows mechanically (the benchmark
    measures it; here we pin the dispatch accounting)."""
    ctx = ExecutionContext(executor="cost")
    ref = run_query("q1", data, context=ctx)
    with AnalyticsService(ServiceConfig(n_pools=2,
                                        workers_per_pool=2)) as svc:
        rids = [submit_query(svc, "q1", data, context=ctx)
                for _ in range(32)]
        results = svc.drain()
        st = svc.stats()
    assert st.completed == 32
    assert st.dispatches == 1
    assert st.dedup_hits == 31
    for rid in rids:
        assert results[rid].batch_size == 32
        _assert_bit_identical(results[rid].value, ref, "q1-hot")


# ---------------------------------------------------------------------------
# work stealing
# ---------------------------------------------------------------------------
def test_work_steal_counters(data):
    """DENSE packs every morsel of the task onto one pool; a second
    single-worker pool can only obtain work by stealing. Invariants: all
    morsels execute exactly once, and non-home executions == steals."""
    tables = data.as_jax()
    sched = MorselScheduler(n_pools=2, workers_per_pool=1,
                            placement=ThreadPlacement.DENSE,
                            morsel_rows=500, started=False)
    task = sched.build_task(LOGICAL_QUERIES["q1"], tables,
                            ExecutionContext(executor="xla"))
    assert len(task.morsels) == 48       # 24000 rows / 500
    sched.submit(task)                   # staged before any worker runs
    homes = [m.home_pool for m in task.morsels]
    assert len(set(homes)) == 1          # DENSE: one pool owns everything
    sched.start()
    got = task.wait(timeout=120)
    st = sched.stats()
    sched.close()
    assert sum(st.executed_per_pool) == st.morsels_dispatched == 48
    non_home = st.executed_per_pool[1 - homes[0]]
    assert st.steals == non_home         # every non-home execution = a steal
    assert st.steals >= 1                # the idle pool did steal
    ref = run_query("q1", data, executor="xla")
    for k in ref:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   atol=1e-2, rtol=1e-5, err_msg=k)


def test_sparse_distributes_whole_plan_tasks(data):
    """Whole-plan tasks have a single morsel (seq 0): SPARSE must still
    stripe successive tasks across pools (per-task rotating base), not pin
    them all to pool 0 with stealing papering over the starvation."""
    tables = data.as_jax()
    sched = MorselScheduler(n_pools=2, workers_per_pool=1,
                            placement=ThreadPlacement.SPARSE, steal=False,
                            started=False)
    ctx = ExecutionContext(executor="xla")
    tasks = [sched.build_task(LOGICAL_QUERIES["q6"], tables, ctx)
             for _ in range(6)]
    for t in tasks:
        sched.submit(t)
    assert {t.morsels[0].home_pool for t in tasks} == {0, 1}
    sched.start()
    for t in tasks:
        assert t.wait(timeout=120) is not None
    st = sched.stats()
    sched.close()
    assert all(e == 3 for e in st.executed_per_pool)
    assert st.steals == 0                # no stealing needed, none counted


# ---------------------------------------------------------------------------
# admission: backpressure + deadlines
# ---------------------------------------------------------------------------
def test_backpressure_and_deadlines(data):
    ctx = ExecutionContext(executor="cost")
    run_query("q1", data, context=ctx)           # warm the plan cache
    with AnalyticsService(ServiceConfig(queue_depth=2, n_pools=1,
                                        workers_per_pool=1)) as svc:
        r0 = submit_query(svc, "q1", data, context=ctx)
        r1 = submit_query(svc, "q1", data, context=ctx, deadline_s=-1.0)
        r2 = submit_query(svc, "q1", data, context=ctx)
        assert r0 is not None and r1 is not None
        assert r2 is None                        # bounded queue pushed back
        results = svc.drain()
        st = svc.stats()
    assert st.rejected == 1 and st.expired == 1 and st.completed == 1
    assert results[r0].value is not None
    assert results[r1].expired and results[r1].value is None


def test_failed_dispatch_is_isolated(data):
    """A malformed query must fail alone: co-submitted clients still get
    their results and the failure is attributed on the bad request."""
    from repro.analytics.plan import LogicalPlan, scan
    bad_plan = LogicalPlan(
        scan("lineitem").aggregate("no_such_column", 4,
                                   s=("sum", "l_quantity")))
    ctx = ExecutionContext(executor="cost")
    ref = run_query("q1", data, context=ctx)
    with AnalyticsService(ServiceConfig(n_pools=2,
                                        workers_per_pool=2)) as svc:
        good = submit_query(svc, "q1", data, context=ctx)
        bad = svc.submit(bad_plan, data.as_jax(), context=ctx)
        results = svc.drain()
        st = svc.stats()
    assert st.completed == 1 and st.failed == 1
    _assert_bit_identical(results[good].value, ref, "good-alongside-bad")
    assert results[bad].value is None
    assert results[bad].error and "no_such_column" in results[bad].error

    # the EAGER failure path: with morsel_rows set, a plan naming a table
    # its mapping lacks raises at build_task (morsel decompose), before
    # any worker runs — must also be isolated to its own share
    missing = LogicalPlan(
        scan("no_such_table").aggregate("x", 2, s=("sum", "x")))
    with AnalyticsService(ServiceConfig(n_pools=1, workers_per_pool=1,
                                        morsel_rows=1000)) as svc:
        good = submit_query(svc, "q1", data, executor="xla")
        bad1 = svc.submit(missing, data.as_jax())
        bad2 = svc.submit(missing, data.as_jax())   # dedup peer that fails
        results = svc.drain()
        st = svc.stats()
    assert st.completed == 1 and st.failed == 2
    assert results[good].value is not None
    for bad in (bad1, bad2):
        assert results[bad].value is None and results[bad].error
    # a share that never dispatched must not count dispatches or dedup hits
    assert st.dispatches == 1 and st.dedup_hits == 0


# ---------------------------------------------------------------------------
# seeded deterministic throughput smoke
# ---------------------------------------------------------------------------
def test_throughput_smoke(data):
    names = [("q1", "q3", "q6")[i % 3] for i in range(18)]
    ctx = ExecutionContext(executor="cost")
    for n in set(names):
        run_query(n, data, context=ctx)          # hot path only
    with AnalyticsService(ServiceConfig(n_pools=2, workers_per_pool=2,
                                        morsel_rows=4000)) as svc:
        rids = [submit_query(svc, n, data, context=ctx) for n in names]
        results = svc.drain()
        st = svc.stats()
    assert st.completed == len(names) == st.admitted
    assert all(results[r].value is not None for r in rids)
    assert st.dispatches == 3                    # one per distinct query
    assert st.dedup_hits == len(names) - 3
    assert st.qps > 0
    assert st.latency_p99_ms >= st.latency_p50_ms >= 0
    assert st.queue_wait_p99_ms >= st.queue_wait_p50_ms >= 0
    # qps denominates over time spent serving: idling afterwards (a
    # long-lived service between bursts) must not decay the reported rate
    time.sleep(0.2)
    assert svc.stats().qps == pytest.approx(st.qps)


# ---------------------------------------------------------------------------
# shared plan cache under concurrent traffic
# ---------------------------------------------------------------------------
def test_plan_cache_thread_safe_under_concurrency(data):
    """Hammer a 4-entry cache (forced evictions) from 8 threads; unlocked
    this raced move_to_end/popitem into KeyErrors and dropped counter
    increments. Counters must balance exactly: every lookup is one hit or
    one miss."""
    planner.clear_plan_cache()
    configure_plan_cache(4)
    names = sorted(LOGICAL_QUERIES)
    errors = []
    before = plan_cache_info()
    calls_per_thread = 12

    def hammer(seed):
        try:
            for i in range(calls_per_thread):
                name = names[(seed + i) % len(names)]
                ex = ("xla", "cost")[(seed + i) % 2]
                run_query(name, data, executor=ex)
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(s,)) for s in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    info = plan_cache_info()
    lookups = (info.hits - before.hits) + (info.misses - before.misses)
    assert lookups == 8 * calls_per_thread
    assert info.currsize <= 4
