"""Degrade gracefully when hypothesis is not installed.

The test container bakes in jax/numpy/pytest only (see requirements-dev.txt
for the full dev set). Importing ``given``/``settings``/``st`` from here
instead of ``hypothesis`` keeps every non-property test in a module
collectable and running everywhere: with hypothesis present the real API is
re-exported; without it, ``@given`` turns its test into an individual skip
and strategy expressions evaluate to inert placeholders.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategy:
        """Absorbs any strategy construction (st.integers(0, 5), st.data(),
        st.lists(st.integers()).map(...)) — never executed, only built at
        decoration time of tests that are skipped anyway."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _Strategy()
