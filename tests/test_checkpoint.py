"""Checkpointing: roundtrip, atomicity, async, retention, FT restore."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.optim import adamw
from repro.core.config import TrainConfig


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 5, t)
    assert latest_step(str(tmp_path)) == 5
    back = restore(str(tmp_path), 5, t)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), t, back)


def test_namedtuple_state_roundtrip(tmp_path):
    params = {"w": jnp.ones((3, 3))}
    state = adamw.init(params, TrainConfig())
    save(str(tmp_path), 1, (params, state))
    params2, state2 = restore(str(tmp_path), 1, (params, state))
    assert isinstance(state2, adamw.AdamWState)
    np.testing.assert_array_equal(np.asarray(state.mu["w"]),
                                  np.asarray(state2.mu["w"]))


def test_uncommitted_ignored(tmp_path):
    t = _tree()
    save(str(tmp_path), 1, t)
    # fake a torn write
    torn = tmp_path / "step_00000002"
    torn.mkdir()
    (torn / "index.json").write_text("{}")
    assert latest_step(str(tmp_path)) == 1


def test_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save_async(s, t)
    mgr.wait()
    mgr._gc()
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert len(kept) == 2
    assert latest_step(str(tmp_path)) == 4


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore(str(tmp_path), 9, _tree())
