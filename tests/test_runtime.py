"""Runtime: FT train loop (restart drill), straggler detection, elastic
mesh math, serving loop under page pressure."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.reduced import REDUCED
from repro.core.config import LM_SHAPES, RunConfig, TrainConfig
from repro.core.params import init_params
from repro.models.lm import LMModel
from repro.runtime import (ContinuousBatcher, FailureInjector, Request,
                           StragglerDetector, elastic_mesh_shape, train)
from repro.runtime.ft import surviving_devices


@pytest.fixture(scope="module")
def small_model():
    arch = REDUCED["qwen2-0.5b"]
    return arch, LMModel(arch, tp=1, remat="none")


def test_train_loss_decreases(small_model):
    arch, model = small_model
    cfg = RunConfig(arch=arch, shape=LM_SHAPES["train_4k"],
                    train=TrainConfig(learning_rate=3e-3, warmup_steps=2))
    res = train(model, cfg, n_steps=12, batch=4, seq=16)
    assert res.steps_run == 12
    assert res.final_loss < res.losses[0]


def test_checkpoint_restart_resumes(small_model, tmp_path):
    arch, model = small_model
    cfg = RunConfig(arch=arch, shape=LM_SHAPES["train_4k"],
                    train=TrainConfig(warmup_steps=2))
    res = train(model, cfg, n_steps=8, batch=2, seq=16,
                ckpt_dir=str(tmp_path), ckpt_every=2,
                injector=FailureInjector(fail_at_steps=[5]))
    assert res.restarts == 1
    assert res.steps_run == 8            # completed despite the failure


def test_grad_accum_equivalence(small_model):
    """accum=2 over the same data ~ accum=1 (same total batch)."""
    arch, model = small_model
    base = dict(arch=arch, shape=LM_SHAPES["train_4k"])
    r1 = train(model, RunConfig(train=TrainConfig(warmup_steps=2,
                                                  accum_steps=1), **base),
               n_steps=3, batch=4, seq=16)
    r2 = train(model, RunConfig(train=TrainConfig(warmup_steps=2,
                                                  accum_steps=2), **base),
               n_steps=3, batch=4, seq=16)
    assert abs(r1.losses[0] - r2.losses[0]) < 1e-2


def test_straggler_detector():
    det = StragglerDetector(n_hosts=4, warmup=2, threshold=1.4)
    for _ in range(5):
        for h in range(4):
            det.record(h, 1.0 if h != 2 else 3.0)
    out = det.stragglers()
    assert [s.host for s in out] == [2]
    shares = det.data_shares()
    assert shares[2] < shares[0]          # slow host gets less data
    assert abs(shares.sum() - 1.0) < 1e-9


def test_elastic_mesh_shape():
    assert elastic_mesh_shape(256, 16) == (16, 16)
    assert elastic_mesh_shape(240, 16) == (15, 16)  # one host of 16 lost
    with pytest.raises(ValueError):
        elastic_mesh_shape(8, 16)
    assert len(surviving_devices(list(range(256)), 16)) == 240


def test_serve_completion_and_pressure(small_model):
    arch, model = small_model
    params = init_params(model.schema(), jax.random.PRNGKey(0), jnp.float32)
    b = ContinuousBatcher(model, params, wave_slots=4, max_len=64,
                          page_tokens=8, n_pages=64)
    for i in range(8):
        b.submit(Request(req_id=i, prompt_len=4, max_new_tokens=5))
    stats = b.run(max_steps=200)
    assert stats.completed == 8
    assert stats.tokens_out == 40
    # page pressure: still completes, but with stalls
    b2 = ContinuousBatcher(model, params, wave_slots=4, max_len=64,
                           page_tokens=8, n_pages=3)
    for i in range(4):
        b2.submit(Request(req_id=100 + i, prompt_len=4, max_new_tokens=4))
    s2 = b2.run(max_steps=400)
    assert s2.completed == 4
    assert s2.admission_stalls > 0
