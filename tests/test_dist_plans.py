"""Distributed join/median lowerings through the planner.

Two regression nets for the retirement of the bespoke W1/W3 shard_map
plans (PR 4):

1. FIXTURE PARITY — tests/fixtures/w1w3_retired_plans.npz pins the outputs
   of the deleted hand-written plans (captured on this backend before
   deletion). The planner-lowered dist_median / dist_hash_join must
   reproduce them BIT-IDENTICALLY under every placement policy: the new
   lowerings mirror the retired plans' data movement (same routing
   capacities, same sort/selection ops, same reduction order), so even the
   float checksums match exactly.

2. STRATEGY PARITY — partitioned-join == broadcast-join == local-join on
   every TPC-H join query under both placement policies: the distributed
   join strategy (like the placement policy) may change cost, never
   answers, and routing capacity overflow must stay zero (surfaced, never
   silent) on these uniform keys.
"""
import os

import pytest

from conftest import run_with_devices

FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "fixtures", "w1w3_retired_plans.npz")

FIXTURE_TEST = """
import numpy as np, jax, jax.numpy as jnp
from repro.core.config import PlacementPolicy
from repro.analytics.engine import dist_median, dist_hash_join
from repro.analytics.datasets import moving_cluster, zipf, blanas_join

fx = np.load({fixtures!r})
mesh = jax.make_mesh((8,), ("data",))
G, N, n = 64, 8192, 8

def expand_interleave(out):
    # the RETIRED interleave plan emitted shard-major layout (shard s held
    # groups g % n == s); the planner lowering republishes natural order
    full = np.zeros(G, np.float32)
    per = out.reshape(n, G // n)
    for s in range(n):
        full[np.arange(G)[np.arange(G) % n == s]] = per[s]
    return full

for dsname, ds in (("mc", moving_cluster(N, G, seed=5)),
                   ("zipf", zipf(N, G, seed=5))):
    keys, vals = jnp.asarray(ds.keys), jnp.asarray(ds.vals)
    for pol in PlacementPolicy:
        new = np.asarray(jax.jit(dist_median(mesh, pol, G))(keys, vals))
        old = fx[f"w1_{{dsname}}_{{pol.value}}"]
        if pol == PlacementPolicy.INTERLEAVE:
            old = expand_interleave(old)
        assert np.array_equal(new, old, equal_nan=True), \\
            ("w1", dsname, pol, np.nanmax(np.abs(new - old)))

jd = blanas_join(1024, 8192, seed=6)
bk, bv, pk = map(jnp.asarray, (jd.build_keys, jd.build_vals, jd.probe_keys))
for pol in PlacementPolicy:
    c, s = jax.jit(dist_hash_join(mesh, pol))(bk, bv, pk)
    assert int(np.asarray(c)) == int(fx[f"w3_count_{{pol.value}}"]), pol
    assert float(np.asarray(s)) == float(fx[f"w3_checksum_{{pol.value}}"]), \\
        ("w3 checksum", pol, float(np.asarray(s)))
print("FIXTURE_PARITY_OK")
"""


def test_retired_plan_fixture_parity():
    out = run_with_devices(FIXTURE_TEST.format(fixtures=FIXTURES),
                           timeout=600)
    assert "FIXTURE_PARITY_OK" in out


STRATEGY_TEST = """
import numpy as np, jax
from repro.core.config import PlacementPolicy
from repro.analytics.tpch import generate, run_query
from repro.analytics.planner import ExecutionContext

mesh = jax.make_mesh((8,), ("data",))
data = generate(scale=0.004, seed=1)
for name in ("q3", "q5", "q18"):
    ref = run_query(name, data, executor="xla")
    for pol in (PlacementPolicy.FIRST_TOUCH, PlacementPolicy.INTERLEAVE):
        for dj in ("broadcast", "partitioned"):
            ctx = ExecutionContext(executor="xla", mesh=mesh, policy=pol,
                                   capacity_factor=4.0, dist_join=dj)
            got = run_query(name, data, context=ctx)
            assert set(got) == set(ref), (name, pol, dj)
            for k in ref:
                if k == "_overflow":
                    assert int(np.asarray(got[k])) == 0, (name, pol, dj)
                    continue
                np.testing.assert_allclose(
                    np.asarray(got[k]), np.asarray(ref[k]),
                    atol=1e-2, rtol=1e-4, err_msg=f"{name}/{pol}/{dj}/{k}")
print("STRATEGY_PARITY_OK")
"""


def test_partitioned_equals_broadcast_equals_local():
    out = run_with_devices(STRATEGY_TEST, timeout=900)
    assert "STRATEGY_PARITY_OK" in out
