"""Paged KV-cache manager: page accounting, THP-knob fragmentation."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import AllocatorKind
from repro.memory.paged_kv import PagedKVManager, gather_sequence


def test_page_accounting():
    mgr = PagedKVManager(n_pages=32, page_tokens=16, page_bytes=4096)
    mgr.add_sequence(0)
    assert mgr.append_tokens(0, 17)          # needs 2 pages
    st = mgr.sequences[0]
    assert len(st.pages) == 2
    assert mgr.append_tokens(0, 15)          # exactly fills page 2
    assert len(st.pages) == 2
    assert mgr.append_tokens(0, 1)           # spills to page 3
    assert len(st.pages) == 3
    mgr.release_sequence(0)
    assert mgr.allocator_stats.live_reserved == 0


def test_capacity_exhaustion_and_reuse():
    mgr = PagedKVManager(n_pages=4, page_tokens=8, page_bytes=4096)
    mgr.add_sequence(0)
    assert mgr.append_tokens(0, 32)          # all 4 pages
    mgr.add_sequence(1)
    assert not mgr.append_tokens(1, 8)       # exhausted
    mgr.release_sequence(0)
    assert mgr.append_tokens(1, 8)           # reuse after release


@pytest.mark.parametrize("page_tokens,expect_more_frag",
                         [(64, True), (8, False)])
def test_thp_fragmentation_tradeoff(page_tokens, expect_more_frag):
    """Paper 3.4.1: big pages waste memory on short sequences."""
    mgr = PagedKVManager(n_pages=256, page_tokens=page_tokens,
                         page_bytes=4096)
    for i in range(16):
        mgr.add_sequence(i)
        assert mgr.append_tokens(i, 9)       # short sequences
    frag = mgr.fragmentation_ratio()
    if expect_more_frag:
        assert frag > 4.0                    # 64-token pages for 9 tokens
    else:
        assert frag < 2.0


def test_gather_sequence():
    pool = jnp.arange(8 * 4 * 2, dtype=jnp.float32).reshape(8, 4, 2)
    table = jnp.asarray([3, 1, -1, -1], jnp.int32)
    out = np.asarray(gather_sequence(pool, table, jnp.asarray(6)))
    np.testing.assert_allclose(out[:4], np.asarray(pool[3]))
    np.testing.assert_allclose(out[4:6], np.asarray(pool[1][:2]))
    assert (out[6:] == 0).all()


def test_page_ids_within_pool():
    """Page ids must index the device pool even with size-class rounding."""
    mgr = PagedKVManager(n_pages=64, page_tokens=16, page_bytes=100)  # odd
    for i in range(8):
        mgr.add_sequence(i)
        assert mgr.append_tokens(i, 64)
        assert all(0 <= p < 64 for p in mgr.sequences[i].pages)
