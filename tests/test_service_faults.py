"""Chaos grid for the fault-tolerant serving tier (analytics/service/).

Seeded fault scenarios — transient/persistent build failure, wait poison,
mid-round pool kill, straggling pool — each exercised on BOTH dispatch
modes (whole-plan and morsel-split), plus a seeded chaos storm. The
invariants under every scenario:

  * every submitted request gets EXACTLY ONE terminal QueryResult
    (value, expired, shed, or error) — nothing dropped, nothing doubled;
  * surviving results are bit-identical to a fault-free run of the same
    dispatch mode (whole-plan == serial run_query by construction;
    morsel-split == its own deterministic morsel-order merge);
  * stats conserve: admitted == completed + failed + expired + shed;
  * the injector's observability counters record exactly what fired, and
    a replay with the same seed fires the same faults.
"""
import numpy as np
import pytest

from repro.analytics.planner import ExecutionContext
from repro.analytics.service import (AnalyticsService, RetryPolicy,
                                     ServiceConfig, ServiceFaultInjector,
                                     ThreadPlacement)
from repro.analytics.tpch import LOGICAL_QUERIES, generate, run_query, \
    submit_query

# dispatch modes: whole-plan (bit-identical to serial) and morsel-split
# (deterministic morsel-order merge; 997 does not divide the row count)
MODES = {"whole": None, "morsel": 997}


@pytest.fixture(scope="module")
def data():
    return generate(scale=0.004, seed=1)


@pytest.fixture(scope="module")
def refs(data):
    """Fault-free references per mode. Whole-plan compares against serial
    run_query; morsel-split against a clean served morsel run (the morsel
    merge is deterministic but a different float order than serial).
    Also warms the process-global plan cache so faulted runs measure
    service time, not compile time."""
    ctx = ExecutionContext(executor="xla")
    out = {"whole": {n: run_query(n, data, context=ctx)
                     for n in LOGICAL_QUERIES}}
    with AnalyticsService(ServiceConfig(
            n_pools=2, workers_per_pool=2, morsel_rows=MODES["morsel"],
            placement=ThreadPlacement.SPARSE)) as svc:
        rids = {n: submit_query(svc, n, data, context=ctx)
                for n in LOGICAL_QUERIES}
        results = svc.drain()
    out["morsel"] = {n: results[rid].value for n, rid in rids.items()}
    return out


def _config(mode, faults, **kw):
    kw.setdefault("n_pools", 2)
    kw.setdefault("workers_per_pool", 2)
    kw.setdefault("placement", ThreadPlacement.SPARSE)
    kw.setdefault("retry", RetryPolicy(max_attempts=3, base_backoff_s=0.005,
                                       max_backoff_s=0.05))
    return ServiceConfig(morsel_rows=MODES[mode], faults=faults, **kw)


def _ctx():
    return ExecutionContext(executor="xla")


def _assert_identical(got, ref, label):
    assert got is not None, f"{label}: no value"
    assert set(got) == set(ref), label
    for k in ref:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(ref[k]),
                                      err_msg=f"{label}/{k}")


def _assert_conserved(st):
    assert st.admitted == (st.completed + st.failed + st.expired + st.shed), \
        st.describe()


# ---------------------------------------------------------------------------
# build failures: transient (retried to success) and persistent (terminal)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", list(MODES))
def test_transient_build_failure_is_retried(data, refs, mode):
    faults = ServiceFaultInjector(seed=3, build_fail_at={0})
    with AnalyticsService(_config(mode, faults)) as svc:
        rid = submit_query(svc, "q6", data, context=_ctx())
        res = svc.drain()[rid]
        st = svc.stats()
    _assert_identical(res.value, refs[mode]["q6"], f"{mode}/transient")
    assert res.error is None and res.attempts == 2
    assert faults.builds_failed == 1
    assert st.retries == 1 and st.failed == 0 and st.completed == 1
    _assert_conserved(st)


@pytest.mark.parametrize("mode", list(MODES))
def test_persistent_build_failure_is_isolated(data, refs, mode):
    """A dispatch whose build fails on EVERY attempt goes terminal with an
    error after max_attempts — and must not take the round's other
    requests down with it."""
    faults = ServiceFaultInjector(seed=3, build_fail_at={0, 1, 2})
    with AnalyticsService(_config(mode, faults)) as svc:
        bad = submit_query(svc, "q6", data, context=_ctx())
        good = submit_query(svc, "q1", data, context=_ctx())
        results = svc.drain()
        st = svc.stats()
    assert results[bad].value is None
    assert "InjectedServiceFault" in results[bad].error
    assert results[bad].attempts == 3
    _assert_identical(results[good].value, refs[mode]["q1"],
                      f"{mode}/survivor")
    assert faults.builds_failed == 3
    assert st.failed == 1 and st.completed == 1 and st.retries == 2
    _assert_conserved(st)


# ---------------------------------------------------------------------------
# wait poison: the dispatch dies INSIDE the executor; retry re-dispatches
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", list(MODES))
def test_poisoned_wait_is_retried(data, refs, mode):
    faults = ServiceFaultInjector(seed=3, poison_wait_at={0})
    with AnalyticsService(_config(mode, faults)) as svc:
        rid = submit_query(svc, "q6", data, context=_ctx())
        res = svc.drain()[rid]
        st = svc.stats()
    _assert_identical(res.value, refs[mode]["q6"], f"{mode}/poison")
    assert res.attempts == 2
    assert faults.waits_poisoned == 1
    assert st.retries == 1 and st.failed == 0
    # the poisoned dispatch WAS submitted, so two dispatches total
    assert st.dispatches == 2
    _assert_conserved(st)


# ---------------------------------------------------------------------------
# pool kill mid-round: keep serving on the surviving pool
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", list(MODES))
def test_pool_kill_mid_round_keeps_serving(data, refs, mode):
    faults = ServiceFaultInjector(seed=3, kill_pool_at=(0, 1))
    with AnalyticsService(_config(mode, faults)) as svc:
        rids = {n: submit_query(svc, n, data, context=_ctx())
                for n in LOGICAL_QUERIES}
        results = svc.drain()
        # the shrunk pool set keeps admitting and serving NEW work too
        late = submit_query(svc, "q6", data, context=_ctx())
        results.update(svc.drain())
        st = svc.stats()
    assert faults.pools_killed == 1
    assert st.dead_pools == (1,)
    assert 1 in st.quarantined_pools
    for name, rid in rids.items():
        _assert_identical(results[rid].value, refs[mode][name],
                          f"{mode}/kill/{name}")
    _assert_identical(results[late].value, refs[mode]["q6"],
                      f"{mode}/kill/late")
    assert st.completed == len(LOGICAL_QUERIES) + 1 and st.failed == 0
    _assert_conserved(st)


# ---------------------------------------------------------------------------
# straggler: EWMA quarantine of a pool that went slow
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", list(MODES))
def test_straggler_pool_is_quarantined(data, refs, mode):
    """Pool 1 sleeps 80ms per work unit; the EWMA sweep (peer-median
    comparison, ft.py's StragglerDetector idiom) must quarantine it
    mid-drain and finish the backlog on pool 0 — results unchanged.
    Stealing is disabled: an idle fast pool would otherwise steal the
    straggler's backlog before it accumulates warmup samples (stealing
    MASKS stragglers; this test pins the quarantine path specifically)."""
    faults = ServiceFaultInjector(seed=3, straggle_pool=(1, 0.08))
    cfg = _config(mode, faults, batching=False, straggler_warmup=2,
                  straggler_threshold=4.0, workers_per_pool=1, steal=False)
    n_reqs = 14
    with AnalyticsService(cfg) as svc:
        rids = [submit_query(svc, "q6", data, context=_ctx())
                for _ in range(n_reqs)]
        results = svc.drain()
        st = svc.stats()
    assert 1 in st.quarantined_pools, st.describe()
    assert st.dead_pools == ()              # straggler is slow, not dead
    for i, rid in enumerate(rids):
        _assert_identical(results[rid].value, refs[mode]["q6"],
                          f"{mode}/straggle/{i}")
    assert st.completed == n_reqs and st.failed == 0
    _assert_conserved(st)


# ---------------------------------------------------------------------------
# seeded chaos storm: rates instead of schedules, replayable
# ---------------------------------------------------------------------------
def _storm(mode, data, seed):
    faults = ServiceFaultInjector(seed=seed, build_fail_rate=0.15,
                                  poison_rate=0.10)
    cfg = _config(mode, faults,
                  retry=RetryPolicy(max_attempts=4, base_backoff_s=0.002,
                                    max_backoff_s=0.02))
    names = list(LOGICAL_QUERIES) * 5         # 25 requests
    with AnalyticsService(cfg) as svc:
        rids = [submit_query(svc, n, data, context=_ctx(),
                             client_id=i % 3, priority=1 + i % 2)
                for i, n in enumerate(names)]
        results = svc.drain()
        st = svc.stats()
    return names, rids, results, st, faults


@pytest.mark.parametrize("mode", list(MODES))
def test_chaos_storm_exactly_one_terminal_result(data, refs, mode):
    names, rids, results, st, faults = _storm(mode, data, seed=11)
    # exactly one terminal result per admitted request
    assert sorted(results) == sorted(rids)
    for name, rid in zip(names, rids):
        res = results[rid]
        states = [res.value is not None, res.error is not None,
                  res.expired, res.shed]
        assert sum(states) == 1, f"rid {rid}: not exactly-one terminal"
        if res.value is not None:
            _assert_identical(res.value, refs[mode][name],
                              f"{mode}/storm/{name}")
    assert st.completed + st.failed == len(rids)
    assert faults.builds_failed + faults.waits_poisoned > 0  # storm did storm
    _assert_conserved(st)


def test_chaos_storm_replays_deterministically(data):
    """Same seed + same submission sequence => the same faults fire and
    every request consumes the same number of attempts."""
    runs = [_storm("whole", data, seed=11) for _ in range(2)]
    (_, rids_a, res_a, _, f_a), (_, rids_b, res_b, _, f_b) = runs
    assert (f_a.builds_failed, f_a.waits_poisoned) == \
        (f_b.builds_failed, f_b.waits_poisoned)
    assert [res_a[r].attempts for r in rids_a] == \
        [res_b[r].attempts for r in rids_b]
    assert [res_a[r].error is None for r in rids_a] == \
        [res_b[r].error is None for r in rids_b]
