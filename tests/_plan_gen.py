"""Random logical-plan generator for the parity fuzz harness.

Standalone on purpose: no pytest / hypothesis / conftest imports, so the
distributed fuzz subprocess (which sees only src/ on PYTHONPATH plus this
directory) can import it and regenerate the SAME plans from the same seeds
that the in-process harness uses.

Plans are small but structurally diverse: Scan -> optional Filter ->
optional Project -> optional Join (with a dimension table, taking columns)
-> optional Attach (a per-key1 COUNT aggregate gathered back through the
dense key column, q18's HAVING idiom — the attach filter thresholds a
COUNT so the selected rows are bit-identical across every executor and
placement) -> Aggregate over a grouped key, a join-taken key, or the
global group, with 1..4 aggregates drawn from every op the IR supports —
including the holistic ``median`` and arbitrary-rank ``quantile:R`` —
-> optional TopK over a COUNT output (count values are bit-exact across
all lowerings, so the top-k selection and its indices are too). Every
generated plan is valid by construction (and re-checked via plan.validate
in the harness).

``context_capacity_factor`` fuzzes the routing/partition capacity factor
per seed — tight-but-safe values for the distributed grids, plus a
deliberately overflowing kernel-join configuration for the local grid so
the residual re-probe path is exercised (it must repair to exactness and
report zero overflow).
"""
import numpy as np

from repro.analytics import plan as L

N_ROWS = 768          # divisible by the 4-device fuzz mesh
G1 = 13               # fact group-key domain (not mesh-divisible: exercises
                      # the padded INTERLEAVE slot math)
D = 48                # dimension rows (dense PK)
DK = 7                # dimension group-key domain

AGG_OPS = ("sum", "avg", "count", "max", "min", "median", "quantile:0.25",
           "quantile:0.9", "distinct")

# tight-but-safe routing capacities for the 4-shard distributed grid: the
# generated keys are uniform, so per-owner shares stay well under the
# 128-row capacity tile even at 1.5 (overflow across this sweep must be 0)
DIST_CAPACITY_FACTORS = (1.5, 2.5, 4.0)


def context_capacity_factor(seed: int) -> float:
    """Deterministic per-seed capacity factor for the distributed grid."""
    return DIST_CAPACITY_FACTORS[seed % len(DIST_CAPACITY_FACTORS)]


# morsel-forced grid (PR 10): per-seed probe morsel sizes small enough
# that the 768-row fact table splits into >= 3 morsels, varied so the
# scheduler's backlog/steal paths see different shapes per seed
MORSEL_ROWS_CHOICES = (96, 160, 256)


def context_morsel_rows(seed: int) -> int:
    """Deterministic per-seed morsel size for the split-probe grid."""
    return MORSEL_ROWS_CHOICES[seed % len(MORSEL_ROWS_CHOICES)]


DIST_TOPK_MODES = ("replicated", "candidates")


def context_dist_topk(seed: int) -> str:
    """Deterministic per-seed FORCED distributed-TopK lowering: the fuzz
    runs BOTH forced modes for parity and uses this to alternate which
    one gets the telemetry-tracked wire-accounting pass."""
    return DIST_TOPK_MODES[seed % len(DIST_TOPK_MODES)]


def make_tables(seed: int = 0):
    """Deterministic base tables: a fact table and a joinable dimension.

    ~1 in 7 fact foreign keys miss the dimension (exercises the join-miss
    mask), and values span negative/positive so min/max/median see both
    signs."""
    rng = np.random.RandomState(1_000_003 + seed)
    fact = {
        "key1": rng.randint(0, G1, N_ROWS).astype(np.int32),
        "fk": rng.randint(0, D + D // 6, N_ROWS).astype(np.int32),
        "v1": (rng.randn(N_ROWS) * 10).astype(np.float32),
        "v2": rng.rand(N_ROWS).astype(np.float32),
        "d": rng.randint(0, 100, N_ROWS).astype(np.int32),
    }
    dim = {
        "pk": np.arange(D, dtype=np.int32),
        "dk": rng.randint(0, DK, D).astype(np.int32),
        "dv": rng.rand(D).astype(np.float32),
    }
    return {"fact": fact, "dim": dim}


def make_plan(seed: int) -> L.LogicalPlan:
    """One deterministic random plan per seed (outputs=None: everything)."""
    rng = np.random.RandomState(seed)
    node = L.scan("fact")
    projected = False
    if rng.rand() < 0.7:
        thresh = float(rng.randint(10, 90))
        preds = (L.col("d") < thresh, L.col("d") >= thresh,
                 L.col("v1") > 0.0,
                 (L.col("d") < thresh) & (L.col("v2") > 0.25))
        node = node.filter(preds[rng.randint(len(preds))])
    if rng.rand() < 0.6:
        exprs = (L.col("v1") * (1 - L.col("v2")),
                 L.col("v1") + L.col("v2") * 2.0,
                 abs(L.col("v1")) - L.col("v2"),
                 -L.col("v2"))
        node = node.project(_p=exprs[rng.randint(len(exprs))])
        projected = True
    joined = rng.rand() < 0.5
    if joined:
        node = node.join(L.scan("dim"), "fk", "pk",
                         {"_dv": "dv", "_dk": "dk"})
        r = rng.rand()
        if r < 0.3:
            # predicate on a TAKEN column: needs the joined rows, so the
            # partitioned lowering must NOT push it below the Exchange
            node = node.filter(L.col("_dv") <= 0.8)
        elif r < 0.55:
            # predicate on a PROBE-side column only: under a distributed
            # partitioned join the Filter-below-Exchange peephole pushes
            # it below the probe routing — these seeds pin the rewrite's
            # bit-exactness across every executor and placement
            node = node.filter(L.col("d") >= float(rng.randint(5, 40)))
    attached = rng.rand() < 0.35
    if attached:
        # q18's HAVING idiom: gather a per-key1 COUNT back into the rows
        # and threshold it — counts are bit-exact under every lowering, so
        # the resulting selection mask is too
        src = L.scan("fact").aggregate("key1", G1, att=("count", "d"))
        node = node.attach(src, "key1", {"_att": "att"})
        if rng.rand() < 0.6:
            node = node.filter(L.col("_att") > float(rng.randint(40, 70)))
    keys = [("key1", G1), (None, 1)]
    if joined:
        keys.append(("_dk", DK))
    key, n_groups = keys[rng.randint(len(keys))]
    cols = ["v1", "v2"] + (["_p"] if projected else []) \
        + (["_dv"] if joined else []) + (["_att"] if attached else [])
    aggs = {}
    for i in range(int(rng.randint(1, 5))):
        aggs[f"a{i}"] = (AGG_OPS[rng.randint(len(AGG_OPS))],
                         cols[rng.randint(len(cols))])
    if (not any(op in ("median",) or op.startswith("quantile:")
                for op, _ in aggs.values()) and rng.rand() < 0.5):
        aggs["amed"] = ("median", cols[rng.randint(len(cols))])
    root = node.aggregate(key, n_groups, **aggs)
    if key is not None and rng.rand() < 0.35:
        # TopK rides a COUNT output: count values are bit-identical across
        # executors/policies, so the selection (and tie-breaks, which
        # lax.top_k resolves by index) is deterministic everywhere
        aggs["acnt"] = ("count", cols[0])
        root = node.aggregate(key, n_groups, **aggs)
        root = root.top_k("acnt", min(int(rng.randint(3, 9)), n_groups),
                          "top_idx")
    return L.LogicalPlan(root, None)


def _root_aggregate(plan: L.LogicalPlan) -> L.Aggregate:
    node = plan.root
    while isinstance(node, L.TopK):
        node = node.child
    return node


def plan_agg_ops(plan: L.LogicalPlan):
    """{output_name: op} of the plan's Aggregate (for exactness tiers) —
    found below any TopK wrapper. TopK index outputs are integer-exact by
    construction; the harness treats ``top_idx`` specially."""
    return {name: op for name, (op, _c) in _root_aggregate(plan).aggs}


def plan_has_join(plan: L.LogicalPlan) -> bool:
    return any(isinstance(n, L.Join) for n in L.walk(plan.root))


EXACT_OPS = ("count", "max", "min", "median", "distinct")


def exact_output(key: str, ops) -> bool:
    """ONE copy of the exactness tier shared by the in-process and
    subprocess grids: counts, TopK indices, and every order statistic
    (max/min/median/quantile) select or count actual values, so they must
    be BIT-IDENTICAL across all lowerings; everything else (sums/avgs)
    compares to tolerances because reduction order is part of the float
    result, not of the relational answer."""
    op = ops.get(key)
    return (key in ("_count", "top_idx") or op in EXACT_OPS
            or (op is not None and op.startswith("quantile:")))
