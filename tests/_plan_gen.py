"""Random logical-plan generator for the parity fuzz harness.

Standalone on purpose: no pytest / hypothesis / conftest imports, so the
distributed fuzz subprocess (which sees only src/ on PYTHONPATH plus this
directory) can import it and regenerate the SAME plans from the same seeds
that the in-process harness uses.

Plans are small but structurally diverse: Scan -> optional Filter ->
optional Project -> optional Join (with a dimension table, taking columns)
-> Aggregate over a grouped key, a join-taken key, or the global group,
with 1..4 aggregates drawn from every op the IR supports — including the
holistic ``median``. Every generated plan is valid by construction (and
re-checked via plan.validate in the harness).
"""
import numpy as np

from repro.analytics import plan as L

N_ROWS = 768          # divisible by the 4-device fuzz mesh
G1 = 13               # fact group-key domain (not mesh-divisible: exercises
                      # the padded INTERLEAVE slot math)
D = 48                # dimension rows (dense PK)
DK = 7                # dimension group-key domain

AGG_OPS = ("sum", "avg", "count", "max", "min", "median")


def make_tables(seed: int = 0):
    """Deterministic base tables: a fact table and a joinable dimension.

    ~1 in 7 fact foreign keys miss the dimension (exercises the join-miss
    mask), and values span negative/positive so min/max/median see both
    signs."""
    rng = np.random.RandomState(1_000_003 + seed)
    fact = {
        "key1": rng.randint(0, G1, N_ROWS).astype(np.int32),
        "fk": rng.randint(0, D + D // 6, N_ROWS).astype(np.int32),
        "v1": (rng.randn(N_ROWS) * 10).astype(np.float32),
        "v2": rng.rand(N_ROWS).astype(np.float32),
        "d": rng.randint(0, 100, N_ROWS).astype(np.int32),
    }
    dim = {
        "pk": np.arange(D, dtype=np.int32),
        "dk": rng.randint(0, DK, D).astype(np.int32),
        "dv": rng.rand(D).astype(np.float32),
    }
    return {"fact": fact, "dim": dim}


def make_plan(seed: int) -> L.LogicalPlan:
    """One deterministic random plan per seed (outputs=None: everything)."""
    rng = np.random.RandomState(seed)
    node = L.scan("fact")
    projected = False
    if rng.rand() < 0.7:
        thresh = float(rng.randint(10, 90))
        preds = (L.col("d") < thresh, L.col("d") >= thresh,
                 L.col("v1") > 0.0,
                 (L.col("d") < thresh) & (L.col("v2") > 0.25))
        node = node.filter(preds[rng.randint(len(preds))])
    if rng.rand() < 0.6:
        exprs = (L.col("v1") * (1 - L.col("v2")),
                 L.col("v1") + L.col("v2") * 2.0,
                 abs(L.col("v1")) - L.col("v2"),
                 -L.col("v2"))
        node = node.project(_p=exprs[rng.randint(len(exprs))])
        projected = True
    joined = rng.rand() < 0.5
    if joined:
        node = node.join(L.scan("dim"), "fk", "pk",
                         {"_dv": "dv", "_dk": "dk"})
        if rng.rand() < 0.3:
            node = node.filter(L.col("_dv") <= 0.8)
    keys = [("key1", G1), (None, 1)]
    if joined:
        keys.append(("_dk", DK))
    key, n_groups = keys[rng.randint(len(keys))]
    cols = ["v1", "v2"] + (["_p"] if projected else []) \
        + (["_dv"] if joined else [])
    aggs = {}
    for i in range(int(rng.randint(1, 5))):
        aggs[f"a{i}"] = (AGG_OPS[rng.randint(len(AGG_OPS))],
                         cols[rng.randint(len(cols))])
    if not any(op == "median" for op, _ in aggs.values()) and rng.rand() < 0.5:
        aggs["amed"] = ("median", cols[rng.randint(len(cols))])
    return L.LogicalPlan(node.aggregate(key, n_groups, **aggs), None)


def plan_agg_ops(plan: L.LogicalPlan):
    """{output_name: op} of the root Aggregate (for exactness tiers)."""
    return {name: op for name, (op, _c) in plan.root.aggs}
