"""End-to-end system behaviour: the paper's headline claims in miniature,
plus a sharded train-step compile on a multi-device subprocess mesh."""
import numpy as np
import pytest

from conftest import run_with_devices


def test_placement_policies_change_cost_not_answers():
    """Paper thesis end-to-end: on one query, all policies agree on the
    answer while their communication plans differ (checked via compiled
    HLO collective mix)."""
    out = run_with_devices("""
import numpy as np, jax, jax.numpy as jnp, re
from repro.core.config import PlacementPolicy
from repro.analytics.engine import dist_count
from repro.analytics.datasets import zipf

mesh = jax.make_mesh((8,), ("data",))
G = 64
ds = zipf(8192, G, seed=11)
keys = jnp.asarray(ds.keys)
plans = {}
for pol in PlacementPolicy:
    fn = jax.jit(dist_count(mesh, pol, G))
    hlo = fn.lower(keys).compile().as_text()
    plans[pol.value] = {
        "all-reduce": hlo.count(" all-reduce("),
        "all-to-all": hlo.count(" all-to-all("),
        "all-gather": hlo.count(" all-gather("),
        "reduce-scatter": hlo.count(" reduce-scatter("),
    }
# FIRST_TOUCH merges with an all-reduce; INTERLEAVE routes with all-to-all;
# LOCAL_ALLOC reduce-scatters; PREFERRED gathers.
assert plans["first_touch"]["all-reduce"] >= 1
assert plans["interleave"]["all-to-all"] >= 1
assert plans["local_alloc"]["reduce-scatter"] >= 1
assert plans["preferred"]["all-gather"] >= 1
print("PLANS_DIFFER_OK")
""")
    assert "PLANS_DIFFER_OK" in out


def test_sharded_train_step_compiles_and_runs():
    """Reduced model, real 8-device mesh: jit train step with param/opt
    shardings executes and loss decreases."""
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.reduced import REDUCED
from repro.core.config import RunConfig, LM_SHAPES, TrainConfig, ShardingConfig
from repro.core.params import init_params
from repro.launch.sharding_plan import param_shardings, opt_state_shardings, batch_specs
from repro.models.lm import LMModel
from repro.optim import adamw
from repro.runtime.train_loop import make_train_step
from repro.data.pipeline import synth_batch

mesh = jax.make_mesh((4, 2), ("data", "model"))
arch = REDUCED["qwen3-1.7b"]
cfg = RunConfig(arch=arch, shape=LM_SHAPES["train_4k"],
                train=TrainConfig(learning_rate=1e-3, warmup_steps=1))
model = LMModel(arch, tp=2, sequence_parallel=True, remat="block")
params = init_params(model.schema(), jax.random.PRNGKey(0), jnp.bfloat16)
opt = adamw.init(params, cfg.train)
pshard = param_shardings(model, cfg, mesh)
params = jax.device_put(params, pshard)
oshard = opt_state_shardings(model, cfg, mesh, params, opt)
opt = jax.device_put(opt, oshard)
step = jax.jit(make_train_step(model, cfg),
               in_shardings=(pshard, oshard, None, None),
               out_shardings=(pshard, oshard, None),
               donate_argnums=(0, 1))
losses = []
# overfit one fixed batch: guaranteed monotone-ish descent (no data noise)
b = {k: jnp.asarray(v) for k, v in
     synth_batch(arch, 8, 16, step=0, seed=0).items()}
with mesh:
    for i in range(8):
        params, opt, m = step(params, opt, b, jnp.asarray(i))
        losses.append(float(m["loss"]))
assert all(np.isfinite(losses)), losses
assert losses[-1] < losses[0], losses
print("SHARDED_TRAIN_OK", losses[0], losses[-1])
""", timeout=600)
    assert "SHARDED_TRAIN_OK" in out


def test_elastic_restart_reshards():
    """Kill 'hosts', rebuild a smaller mesh, restore the checkpoint onto it
    — training continues with identical semantics."""
    out = run_with_devices("""
import tempfile, jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save, restore, latest_step
from repro.runtime.ft import elastic_mesh_shape, surviving_devices

tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
mesh1 = jax.make_mesh((4, 2), ("data", "model"))
sh1 = NamedSharding(mesh1, P("data", "model"))
placed = jax.device_put(tree, {"w": sh1})
with tempfile.TemporaryDirectory() as d:
    save(d, 3, placed)
    # lose 2 devices -> largest mesh with model_parallel=2 is (3, 2)
    devs = surviving_devices(jax.devices(), 2)
    shape = elastic_mesh_shape(len(devs), 2)
    assert shape == (3, 2)
    from jax.sharding import Mesh
    mesh2 = Mesh(np.array(devs).reshape(3, 2), ("data", "model"))
    # 8 rows don't divide 3 -> restore replicated on the new mesh
    sh2 = NamedSharding(mesh2, P(None, "model"))
    back = restore(d, 3, tree, {"w": sh2})
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out
