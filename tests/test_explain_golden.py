"""Golden snapshots of the physical-plan explain output.

``explain_physical`` renders the lowered tree — Exchange kinds with
estimated moved rows, Compact points, resolved join/aggregate strategies
— from shape metadata alone, so for fixed table shapes the string is
deterministic. These snapshots pin the physical plans of three
representative queries (lowered for a 4-shard mesh, no devices needed):

  q3  route-once — the INTERLEAVE aggregate on the join key runs with
      merge=placed, no record Exchange (the partitioned join already
      co-located every group's rows);
  q5  chained partitioned joins with occupancy-aware Compact between
      hops, plus aggregate push-down (partials exchange, moved~n_groups);
  qm  holistic medians routed (med=route) next to pushed-down
      distributive companions.

Any change to the lowering or rewrite rules shows up as a readable tree
diff here — regenerate with the snippet in REGEN below ONLY when the
change is intentional. Wired into scripts/ci.sh as a named gate.
"""
import os

import pytest

from repro.analytics import planner
from repro.analytics.planner import ExecutionContext, explain_physical
from repro.analytics.tpch import LOGICAL_QUERIES, generate
from repro.core.config import PlacementPolicy

FIXDIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "fixtures")

# REGEN: for name, ctx in CONTEXTS.items():
#     open(f"tests/fixtures/explain_{name}.txt", "w").write(
#         explain_physical(LOGICAL_QUERIES[name], tables, ctx,
#                          n_shards=4) + "\n")
CONTEXTS = {
    "q3": ExecutionContext(executor="cost",
                           policy=PlacementPolicy.INTERLEAVE,
                           dist_join="partitioned"),
    "q5": ExecutionContext(executor="cost",
                           policy=PlacementPolicy.INTERLEAVE,
                           dist_join="partitioned"),
    "qm": ExecutionContext(executor="cost",
                           policy=PlacementPolicy.INTERLEAVE),
}


@pytest.fixture(autouse=True)
def _default_profile():
    """The rendered layouts depend on the active cost profile: pin the
    hand-set defaults for the snapshot comparison."""
    prev = planner.current_cost_profile()
    planner.set_cost_profile(None)
    yield
    planner.set_cost_profile(prev)


@pytest.fixture(scope="module")
def tables():
    return generate(scale=0.004, seed=1).as_jax()


@pytest.mark.parametrize("name", sorted(CONTEXTS))
def test_explain_physical_matches_golden(tables, name):
    got = explain_physical(LOGICAL_QUERIES[name], tables, CONTEXTS[name],
                           n_shards=4)
    with open(os.path.join(FIXDIR, f"explain_{name}.txt")) as f:
        want = f.read().rstrip("\n")
    assert got == want, (
        f"physical plan for {name} drifted from the golden snapshot;\n"
        f"if intentional, regenerate tests/fixtures/explain_{name}.txt "
        f"(see REGEN note in this file)\n--- got ---\n{got}")


def test_explain_physical_is_stable_across_runs(tables):
    """Two independent lowerings render identical strings (no dict-order,
    id(), or RNG dependence in the renderer)."""
    for name, ctx in CONTEXTS.items():
        a = explain_physical(LOGICAL_QUERIES[name], tables, ctx, n_shards=4)
        b = explain_physical(LOGICAL_QUERIES[name], tables, ctx, n_shards=4)
        assert a == b, name
