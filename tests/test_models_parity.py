"""Decode-vs-forward parity: stepping token-by-token through the cache must
reproduce the full-sequence forward logits. This pins down the KV-cache
update, rope offsets, ring buffers, the MLA absorbed decode (vs the
expanded train path), and the recurrent state updates."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.reduced import REDUCED
from repro.core.params import init_params
from repro.models.lm import LMModel

KEY = jax.random.PRNGKey(1)
B, S = 2, 12

PARITY_ARCHS = ["qwen2-0.5b", "qwen3-1.7b", "granite-3-8b", "yi-34b",
                "deepseek-v3", "rwkv6-7b", "phi3.5-moe", "recurrentgemma-2b"]


@pytest.mark.parametrize("name", PARITY_ARCHS)
def test_decode_matches_forward(name):
    arch = REDUCED[name]
    model = LMModel(arch, tp=1, remat="none", cache_dtype=jnp.float32)
    params = init_params(model.schema(), KEY, jnp.float32)
    rng = np.random.RandomState(7)
    tokens = jnp.asarray(rng.randint(1, arch.vocab_size, (B, S)), jnp.int32)

    full_logits, _, _ = model.forward(
        params, {"tokens": tokens, "labels": tokens})

    cache = model.init_cache(B, S + 4, fill_len=0)
    step_logits = []
    for t in range(S):
        logits, cache = model.decode_step(params, cache,
                                          {"tokens": tokens[:, t:t + 1]})
        step_logits.append(logits[:, 0])
    got = jnp.stack(step_logits, axis=1)

    # MTP heads only affect training loss; logits must still agree.
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=2e-3, rtol=2e-3)


def test_local_attention_ring_buffer():
    """Hybrid arch: decode far past the window must equal a forward pass
    (window masking == ring buffer of the last `window` tokens)."""
    arch = REDUCED["recurrentgemma-2b"]
    model = LMModel(arch, tp=1, remat="none", cache_dtype=jnp.float32)
    params = init_params(model.schema(), KEY, jnp.float32)
    rng = np.random.RandomState(9)
    S_long = arch.hybrid.window * 2 + 3   # decode beyond the window
    tokens = jnp.asarray(rng.randint(1, arch.vocab_size, (B, S_long)),
                         jnp.int32)
    full_logits, _, _ = model.forward(
        params, {"tokens": tokens, "labels": tokens})
    cache = model.init_cache(B, S_long + 1, fill_len=0)
    logits = None
    for t in range(S_long):
        logits, cache = model.decode_step(params, cache,
                                          {"tokens": tokens[:, t:t + 1]})
    np.testing.assert_allclose(np.asarray(logits[:, 0], np.float32),
                               np.asarray(full_logits[:, -1], np.float32),
                               atol=2e-3, rtol=2e-3)
