"""Request-scoped tracing (analytics/tracing.py) + its serving-path hooks.

Three layers of coverage:

  * Tracer unit behaviour — begin/end handles, retrospective spans,
    bounded ring + drop accounting, flight-recorder snapshots, and the
    two exports (Chrome trace-event JSON, deterministic text timeline
    golden-snapshotted in tests/fixtures/trace_timeline.txt);
  * the zero-cost-when-disabled and cache-key contracts — an untraced
    service round allocates NO spans, and flipping the tracing flag must
    NOT change the plan-cache key (only telemetry's ``record`` re-jits);
  * the hammer: a traced chaos round (steals + retries + injected
    faults, morsel-split over two pools) after which every span is
    closed, spans with parents nest inside them, every completed
    request's phase attribution sums to <= its wall latency, and every
    fired fault left a flight-recorder dump.
"""
import json
import os

import pytest

from repro.analytics import tracing
from repro.analytics.planner import ExecutionContext, compile_plan, \
    plan_cache_info
from repro.analytics.service import (AnalyticsService, RetryPolicy,
                                     ServiceConfig, ServiceFaultInjector,
                                     ThreadPlacement)
from repro.analytics.service.service import PHASES
from repro.analytics.tpch import LOGICAL_QUERIES, generate, submit_query
from repro.analytics.tracing import Span, Trace, Tracer

FIXDIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "fixtures")


@pytest.fixture(scope="module")
def data():
    return generate(scale=0.004, seed=1)


@pytest.fixture(autouse=True)
def _tracing_off():
    """Every test starts and ends with the global flag off and the
    process tracer empty (mirrors telemetry's flag hygiene)."""
    tracing.disable_tracing()
    tracing.tracer().clear()
    yield
    tracing.disable_tracing()
    tracing.tracer().clear()


# ---------------------------------------------------------------------------
# tracer unit behaviour
# ---------------------------------------------------------------------------
def test_begin_end_closes_and_nests():
    tr = Tracer()
    outer = tr.begin("plan.execute", "plan", trace_id=3, pid="plan")
    assert [o.span_id for o in tr.open_spans()] == [outer]
    inner = tr.begin("merge.partials", "scheduler", trace_id=3,
                     parent_id=outer)
    s_in = tr.end(inner, rows=10)
    s_out = tr.end(outer)
    assert tr.open_spans() == []
    assert s_in.parent_id == outer and s_out.span_id == outer
    assert dict(s_in.args)["rows"] == 10
    assert s_out.t0 <= s_in.t0 and s_in.t1 <= s_out.t1
    # double-end is a no-op, not an error
    assert tr.end(outer) is None


def test_ring_is_bounded_and_counts_drops():
    tr = Tracer(max_spans=4)
    for i in range(6):
        tr.instant("morsel.steal", "scheduler", seq=i)
    assert tr.created == 6 and tr.dropped == 2
    assert [dict(s.args)["seq"] for s in tr.spans()] == [2, 3, 4, 5]


def test_flight_dump_snapshots_window_and_open_spans():
    tr = Tracer(flight_window=2)
    for i in range(4):
        tr.add_complete("morsel.run", "scheduler", 10.0 + i, 10.5 + i,
                        seq=i)
    sid = tr.begin("dispatch.build", "service", trace_id=9)
    dump = tr.flight_dump("fault.build_fail", ordinal=1)
    assert dump.reason == "fault.build_fail" and dump.args["ordinal"] == 1
    # window tail (2 finished) + the still-open span, rendered open-ended
    assert len(dump.spans) == 3
    assert [dict(s.args)["seq"] for s in dump.spans[:2]] == [2, 3]
    assert dict(dump.spans[-1].args)["open"] is True
    assert tr.flight.dumps()[-1] is dump
    tr.end(sid)


def test_chrome_trace_structure_roundtrips():
    tr = Tracer()
    tr.add_complete("queue.wait", "queue", 5.0, 5.002, trace_id=1)
    tr.add_complete("morsel.run", "scheduler", 5.002, 5.004, trace_id=1,
                    pid="pool0", tid="pool0-w1")
    tr.instant("morsel.steal", "scheduler", trace_id=1, pid="pool1")
    doc = json.loads(json.dumps(tr.trace().to_chrome_trace()))
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    # 3 process lanes + 3 thread lanes named
    assert {e["name"] for e in meta} == {"process_name", "thread_name"}
    assert {e["args"]["name"] for e in meta
            if e["name"] == "process_name"} == {"service", "pool0", "pool1"}
    xs = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"queue.wait", "morsel.run"}
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in xs)
    assert all(e["args"]["trace_id"] == 1 for e in xs)
    inst = [e for e in evs if e["ph"] == "i"]
    assert len(inst) == 1 and inst[0]["s"] == "t"


def test_timeline_matches_golden():
    spans = [
        Span("queue.wait", "queue", 100.000, 0.004, trace_id=7,
             pid="service", tid="main", args=(("cls", 1),)),
        Span("batch.group", "batcher", 100.004, 0.001, pid="service",
             tid="main", args=(("requests", 2),)),
        Span("dispatch.build", "service", 100.005, 0.006, trace_id=7,
             pid="service", tid="main"),
        Span("morsel.run", "scheduler", 100.011, 0.010, trace_id=7,
             pid="pool0", tid="pool0-w0", args=(("seq", 0),)),
        Span("morsel.steal", "scheduler", 100.013, 0.0, trace_id=7,
             pid="pool1", tid="pool1-w0", args=(("victim", 0),)),
        Span("morsel.run", "scheduler", 100.013, 0.009, trace_id=7,
             pid="pool1", tid="pool1-w0", args=(("seq", 1),)),
        Span("merge.partials", "scheduler", 100.022, 0.002, trace_id=7,
             pid="service", tid="drain"),
        Span("result.deliver", "service", 100.024, 0.001, trace_id=7,
             pid="service", tid="drain"),
    ]
    got = Trace(spans).render_timeline(width=40)
    with open(os.path.join(FIXDIR, "trace_timeline.txt")) as f:
        want = f.read().strip("\n")
    assert got == want, f"timeline drifted\n--- got ---\n{got}"


def test_tracing_context_manager_restores_flag():
    assert not tracing.tracing_enabled()
    with tracing.tracing() as tr:
        assert tracing.tracing_enabled() and tr is tracing.tracer()
    assert not tracing.tracing_enabled()


# ---------------------------------------------------------------------------
# contracts: zero-cost when disabled; flag NOT in the plan-cache key
# ---------------------------------------------------------------------------
def _cfg(faults=None, **kw):
    kw.setdefault("n_pools", 2)
    kw.setdefault("workers_per_pool", 2)
    kw.setdefault("morsel_rows", 997)
    kw.setdefault("placement", ThreadPlacement.SPARSE)
    kw.setdefault("retry", RetryPolicy(max_attempts=4, base_backoff_s=0.002,
                                       max_backoff_s=0.02))
    return ServiceConfig(faults=faults, **kw)


def _ctx():
    return ExecutionContext(executor="xla")


def test_disabled_tracing_allocates_nothing(data):
    """The satellite-6 contract: a full served round with tracing off
    must not allocate a single span (every hook is behind ONE flag
    read)."""
    before = tracing.tracer().created
    with AnalyticsService(_cfg()) as svc:
        rids = [submit_query(svc, n, data, context=_ctx())
                for n in LOGICAL_QUERIES]
        results = svc.drain()
    assert all(results[r].value is not None for r in rids)
    assert tracing.tracer().created == before
    # latency attribution is NOT gated on tracing — it is arithmetic over
    # stamps the service keeps anyway (same family as latency_s)
    assert all(results[r].phases is not None for r in rids)


def test_tracing_flag_not_in_plan_cache_key(data):
    """Flipping tracing must hit the same cache entry: plan.execute is a
    host-side span around an unchanged executable (only telemetry's
    ``record`` flag adds traced ops and re-jits)."""
    tables = data.as_jax()
    plan = LOGICAL_QUERIES["q6"]
    off = compile_plan(plan, tables, _ctx())
    h0 = plan_cache_info().hits
    tracing.enable_tracing()
    try:
        on = compile_plan(plan, tables, _ctx())
    finally:
        tracing.disable_tracing()
    assert on.cache_key == off.cache_key
    assert plan_cache_info().hits == h0 + 1   # hit, not a re-compile


# ---------------------------------------------------------------------------
# the hammer: traced chaos round — conservation under concurrency
# ---------------------------------------------------------------------------
def test_hammer_span_conservation_under_chaos(data):
    faults = ServiceFaultInjector(seed=11, build_fail_rate=0.15,
                                  poison_rate=0.10)
    names = list(LOGICAL_QUERIES) * 5          # 25 requests, 5 plans
    with tracing.tracing() as tr:
        with AnalyticsService(_cfg(faults)) as svc:
            rids = [submit_query(svc, n, data, context=_ctx(),
                                 client_id=i % 3, priority=1 + i % 2)
                    for i, n in enumerate(names)]
            results = svc.drain()
            st = svc.stats()
        spans = tr.spans()
        dumps = tr.flight.dumps()
        open_left = tr.open_spans()

    # every span closed
    assert open_left == []
    # the storm actually stormed (retries fired => backoff spans exist)
    assert faults.builds_failed + faults.waits_poisoned > 0
    assert any(s.name == "retry.backoff" for s in spans)
    # steals fired under morsel-split (two pools, shared backlog)
    assert any(s.name == "morsel.steal" for s in spans)
    # spans with parents nest inside them (time containment)
    by_id = {s.span_id: s for s in spans}
    for s in spans:
        if s.parent_id >= 0 and s.parent_id in by_id:
            p = by_id[s.parent_id]
            assert p.t0 <= s.t0 and s.t1 <= p.t1 + 1e-6
    # phase attribution: disjoint sub-intervals => sums <= wall
    completed = [results[r] for r in rids if results[r].value is not None]
    assert completed
    for res in completed:
        assert res.phases is not None
        assert set(res.phases) == set(PHASES)
        assert all(v >= 0.0 for v in res.phases.values())
        assert sum(res.phases.values()) <= res.latency_s + 1e-6, res
    # the stats() decomposition is populated and ordered p50 <= p99
    assert st.phase_p99_ms["execute"] > 0.0
    for ph in PHASES:
        assert st.phase_p50_ms[ph] <= st.phase_p99_ms[ph] + 1e-9
    # every fired fault produced a non-empty flight dump
    fired = faults.builds_failed + faults.waits_poisoned
    fault_dumps = [d for d in dumps if d.reason.startswith("fault.")]
    assert len(fault_dumps) == fired
    assert all(d.spans for d in fault_dumps)
    # request story: every completed request left queue.wait + deliver
    seen = {s.trace_id: set() for s in spans}
    for s in spans:
        seen[s.trace_id].add(s.name)
    for r in rids:
        if results[r].value is not None:
            assert "queue.wait" in seen.get(r, set())
            assert "result.deliver" in seen.get(r, set())
