"""Per-architecture smoke tests: reduced same-family config, one forward +
train step + decode step on CPU — output shapes + no NaNs (assignment
requirement (f))."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.configs.reduced import REDUCED
from repro.core.config import LM_SHAPES, RunConfig, TrainConfig
from repro.core.params import init_params
from repro.models.lm import LMModel
from repro.optim import adamw

B, S = 2, 16
KEY = jax.random.PRNGKey(0)


def _batch(arch):
    b = {}
    if arch.n_codebooks:
        b["embeds"] = jnp.full((B, S, arch.d_model), 0.1, jnp.float32)
        b["labels"] = jnp.ones((B, S, arch.n_codebooks), jnp.int32)
    elif arch.vlm:
        P = arch.n_patches
        b["tokens"] = jnp.ones((B, S - P), jnp.int32)
        b["patch_embeds"] = jnp.full((B, P, arch.d_model), 0.1, jnp.float32)
        pp = np.zeros((B, P, 3), np.int32)
        pp[:, :, 1] = np.arange(P)[None] // 4
        pp[:, :, 2] = np.arange(P)[None] % 4
        b["patch_pos"] = jnp.asarray(pp)
        b["labels"] = jnp.ones((B, S - P), jnp.int32)
    else:
        b["tokens"] = jnp.ones((B, S), jnp.int32)
        b["labels"] = jnp.ones((B, S), jnp.int32)
    return b


@pytest.fixture(scope="module")
def models():
    return {name: LMModel(arch, tp=1, remat="none")
            for name, arch in REDUCED.items()}


@pytest.fixture(scope="module")
def all_params(models):
    return {name: init_params(m.schema(), KEY, jnp.float32)
            for name, m in models.items()}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_and_loss(name, models, all_params):
    arch = REDUCED[name]
    model, params = models[name], all_params[name]
    loss, metrics = model.loss_fn(params, _batch(arch))
    assert jnp.isfinite(loss), f"{name}: loss not finite"
    assert float(loss) > 0
    logits, hidden, aux = model.forward(params, _batch(arch))
    assert logits.shape[0] == B
    assert bool(jnp.isfinite(logits).all()), f"{name}: NaN in logits"
    exp_vocab = model.padded.vocab_size
    assert logits.shape[-1] == exp_vocab


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step(name, models, all_params):
    arch = REDUCED[name]
    model, params = models[name], all_params[name]
    cfg = TrainConfig(warmup_steps=1)
    opt = adamw.init(params, cfg)

    def loss_fn(p):
        return model.loss_fn(p, _batch(arch))[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new_params, new_opt, metrics = adamw.update(
        grads, opt, params, jnp.asarray(1e-3), cfg)
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree.reduce(
        lambda acc, d: acc + float(d),
        jax.tree.map(lambda a, b: jnp.abs(a - b).sum(), params, new_params),
        0.0)
    assert moved > 0, f"{name}: update was a no-op"


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_decode_step(name, models, all_params):
    arch = REDUCED[name]
    model, params = models[name], all_params[name]
    cache = model.init_cache(B, 32, fill_len=3)
    if arch.n_codebooks:
        batch = {"codes": jnp.ones((B, 1, arch.n_codebooks), jnp.int32)}
    else:
        batch = {"tokens": jnp.ones((B, 1), jnp.int32)}
    logits, new_cache = model.decode_step(params, cache, batch)
    assert bool(jnp.isfinite(logits).all()), f"{name}: NaN in decode"
    assert int(new_cache["len"][0]) == 4
