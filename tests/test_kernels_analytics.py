"""Analytics kernels (radix histogram, hash aggregate, join probe) vs
oracles, including hypothesis property sweeps."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.hash_aggregate import hash_aggregate, hash_aggregate_multi
from repro.kernels.hash_aggregate.ref import (hash_aggregate_multi_ref,
                                              hash_aggregate_ref)
from repro.kernels.join_probe import join_probe
from repro.kernels.join_probe.ref import join_probe_ref
from repro.kernels.radix_partition import (block_histograms,
                                           padded_bin_counts,
                                           radix_partition)
from repro.kernels.radix_partition.ref import block_histograms_ref


@pytest.mark.parametrize("n_bins,shift,block",
                         [(16, 0, 256), (64, 4, 512), (256, 8, 1024)])
def test_histograms_interpret(rng, n_bins, shift, block):
    keys = jnp.asarray(rng.randint(0, 1 << 24, block * 4), jnp.int32)
    ref = block_histograms_ref(keys, n_bins=n_bins, shift=shift, block=block)
    got = block_histograms(keys, n_bins=n_bins, shift=shift, block=block,
                           mode="interpret")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert int(np.asarray(got).sum()) == block * 4  # conservation


@pytest.mark.parametrize("shift", [0, 8, 16])
def test_histograms_negative_key_parity(rng, shift):
    """ref vs Pallas(interpret) on NEGATIVE keys — including the engine's
    -1 routed-padding sentinel. Digit extraction must be the LOGICAL
    shift in both implementations: an arithmetic shift smears the sign
    bit into every digit position above it, so -1 would land in a
    different bin per backend whenever shift > 0."""
    n_bins, block = 64, 256
    keys = rng.randint(-(1 << 24), 1 << 24, block * 4).astype(np.int32)
    keys[::7] = -1                    # the routing layer's padding key
    keys = jnp.asarray(keys)
    ref = block_histograms_ref(keys, n_bins=n_bins, shift=shift,
                               block=block)
    got = block_histograms(keys, n_bins=n_bins, shift=shift, block=block,
                           mode="interpret")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # oracle: logical shift == unsigned view of the same bit pattern
    digits = (np.asarray(keys).view(np.uint32) >> shift) & (n_bins - 1)
    np.testing.assert_array_equal(np.asarray(ref).sum(0),
                                  np.bincount(digits, minlength=n_bins))


@pytest.mark.parametrize("mode", ["ref", "interpret"])
@pytest.mark.parametrize("n", [1, 255, 256, 257, 1000])
def test_padded_bin_counts_match_unpadded_oracle(rng, mode, n):
    """Block padding with the corrected sentinel bin is bit-exact against
    the unpadded bincount oracle at every misalignment (the engine's
    routed buffers are rarely block-aligned)."""
    for shift in (0, 8, 16):
        keys = rng.randint(-(1 << 24), 1 << 24, n).astype(np.int32)
        counts = padded_bin_counts(jnp.asarray(keys), n_bins=64,
                                   shift=shift, block=256, mode=mode)
        digits = (keys.view(np.uint32) >> shift) & 63
        np.testing.assert_array_equal(np.asarray(counts),
                                      np.bincount(digits, minlength=64))


def test_padded_bin_counts_empty():
    counts = padded_bin_counts(jnp.zeros((0,), jnp.int32), n_bins=16,
                               block=256, mode="ref")
    np.testing.assert_array_equal(np.asarray(counts), np.zeros(16))


def test_radix_partition_unaligned_matches_oracle(rng):
    """N % block != 0 no longer drops to the bincount fallback: the
    padded kernel histogram must reproduce the oracle starts bit-exactly
    and keep the stable digit ordering."""
    keys_np = rng.randint(0, 1 << 16, 1000).astype(np.int32)
    keys = jnp.asarray(keys_np)
    ko, _vo, starts = radix_partition(keys, keys.astype(jnp.float32),
                                      n_bins=16, block=256, mode="ref")
    counts = np.bincount(keys_np & 15, minlength=16)
    np.testing.assert_array_equal(np.asarray(starts),
                                  np.cumsum(counts) - counts)
    digits = np.asarray(ko) & 15
    assert (np.diff(digits) >= 0).all()


def test_radix_partition_orders_digits(rng):
    keys = jnp.asarray(rng.randint(0, 1 << 16, 2048), jnp.int32)
    ko, vo, starts = radix_partition(keys, keys.astype(jnp.float32),
                                     n_bins=16, block=512, mode="ref")
    digits = np.asarray(ko) & 15
    assert (np.diff(digits) >= 0).all()
    # starts consistent with counts
    counts = np.bincount(np.asarray(keys) & 15, minlength=16)
    np.testing.assert_array_equal(np.asarray(starts),
                                  np.cumsum(counts) - counts)


@pytest.mark.parametrize("P,T,bins,block", [(2, 512, 128, 256),
                                            (4, 1024, 512, 512),
                                            (1, 256, 256, 128)])
def test_hash_aggregate_interpret(rng, P, T, bins, block):
    ids = jnp.asarray(rng.randint(0, bins, (P, T)), jnp.int32)
    vals = jnp.asarray(rng.rand(P, T), jnp.float32)
    ref = hash_aggregate_ref(ids, vals, n_bins=bins)
    got = hash_aggregate(ids, vals, n_bins=bins, block=block,
                         mode="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("P,T,bins,C,block", [(2, 512, 128, 3, 256),
                                              (4, 1024, 256, 7, 512),
                                              (1, 256, 128, 1, 128)])
def test_hash_aggregate_multi_interpret(rng, P, T, bins, C, block):
    """Fused multi-aggregate kernel vs oracle, incl. the C=1 edge."""
    ids = jnp.asarray(rng.randint(0, bins, (P, T)), jnp.int32)
    vals = jnp.asarray(rng.randn(P, T, C), jnp.float32)
    ref = hash_aggregate_multi_ref(ids, vals, n_bins=bins)
    got = hash_aggregate_multi(ids, vals, n_bins=bins, block=block,
                               mode="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4)


def test_hash_aggregate_multi_matches_stacked_singles(rng):
    """The fused sweep equals C independent single-aggregate sweeps."""
    P, T, bins, C, block = 2, 768, 128, 4, 256
    ids = jnp.asarray(rng.randint(0, bins, (P, T)), jnp.int32)
    vals = jnp.asarray(rng.randn(P, T, C), jnp.float32)
    fused = hash_aggregate_multi(ids, vals, n_bins=bins, block=block,
                                 mode="interpret")
    for c in range(C):
        single = hash_aggregate(ids, vals[..., c], n_bins=bins, block=block,
                                mode="interpret")
        np.testing.assert_allclose(np.asarray(fused[..., c]),
                                   np.asarray(single), atol=1e-4)


def test_join_probe_interpret(rng):
    P, Bk, Pk = 3, 128, 512
    bk = jnp.asarray(np.stack([rng.permutation(4096)[:Bk]
                               for _ in range(P)]), jnp.int32)
    bv = jnp.asarray(rng.rand(P, Bk), jnp.float32)
    pk = jnp.asarray(rng.randint(0, 4096, (P, Pk)), jnp.int32)
    v_ref, f_ref = join_probe_ref(bk, bv, pk)
    v_got, f_got = join_probe(bk, bv, pk, block_p=128, mode="interpret")
    np.testing.assert_allclose(np.asarray(v_got), np.asarray(v_ref),
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(f_got), np.asarray(f_ref))


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_histogram_conservation_property(data):
    """Property: histogram counts always sum to N and match bincount."""
    n_blocks = data.draw(st.integers(1, 4))
    block = data.draw(st.sampled_from([128, 256]))
    bits = data.draw(st.sampled_from([4, 6, 8]))
    seed = data.draw(st.integers(0, 2**31 - 1))
    r = np.random.RandomState(seed)
    keys = r.randint(0, 1 << 20, n_blocks * block).astype(np.int32)
    hist = np.asarray(block_histograms_ref(jnp.asarray(keys),
                                           n_bins=1 << bits, shift=0,
                                           block=block))
    assert hist.sum() == len(keys)
    np.testing.assert_array_equal(
        hist.sum(0), np.bincount(keys & ((1 << bits) - 1),
                                 minlength=1 << bits))
