"""The explicit physical-plan layer: lowering, movement rewrites,
compaction, and their execution semantics.

Lowering is pure shape arithmetic (``lower(..., n_shards=4)`` needs no
devices), so the rewrite rules — aggregate push-down, route-once,
occupancy-aware Compact — are asserted directly on the physical trees;
one subprocess batch then executes the chained-partitioned-join and
push-down plans on a real 4-device mesh and pins parity + zero overflow.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from conftest import run_with_devices

from repro.analytics import physical as PH
from repro.analytics import plan as L
from repro.analytics import planner
from repro.analytics.engine import compact_routed_rows, routing_capacity
from repro.analytics.planner import ExecutionContext, compile_plan, lower
from repro.core.config import PlacementPolicy

ROWS = {"fact": 1 << 14, "d1": 1 << 11, "d2": 1 << 11}
IL = dict(executor="xla", policy=PlacementPolicy.INTERLEAVE)


def _group_plan(G: int) -> L.LogicalPlan:
    return L.LogicalPlan(
        L.scan("fact").aggregate("k", G, s=("sum", "v"), c=("count", "v")),
        None)


def _chain_plan(n_joins: int) -> L.LogicalPlan:
    node = L.scan("fact")
    for i in (1, 2)[:n_joins]:
        node = node.join(L.scan(f"d{i}"), f"k{i}", f"pk{i}",
                         {f"_v{i}": f"v{i}"})
    return L.LogicalPlan(node.aggregate(None, 1, c=("count", "_v1")), None)


# ---------------------------------------------------------------------------
# lowering basics
# ---------------------------------------------------------------------------
def test_local_lowering_has_no_movement_nodes():
    phys = lower(_chain_plan(2), ExecutionContext(), ROWS)
    kinds = {type(n).__name__ for n in PH.walk(phys.root)}
    assert "Exchange" not in kinds and "Compact" not in kinds
    assert phys.n_shards == 1
    joins = [n for n in PH.walk(phys.root) if isinstance(n, PH.PJoin)]
    assert all(j.dist is None and j.strategy in ("sorted", "kernel")
               for j in joins)


def test_compiled_plan_exposes_physical_tree():
    planner.clear_plan_cache()
    tables = {"fact": {"k": np.zeros(64, np.int32),
                       "v": np.zeros(64, np.float32)}}
    cp = compile_plan(_group_plan(8), tables, ExecutionContext())
    assert isinstance(cp.physical, PH.PhysicalPlan)
    # the physical plan is the plan-cache VALUE: a second compile returns
    # the same lowered tree without re-lowering
    cp2 = compile_plan(_group_plan(8), tables, ExecutionContext())
    assert cp2.physical is cp.physical


# ---------------------------------------------------------------------------
# rewrite rule 1: aggregate push-down
# ---------------------------------------------------------------------------
def test_pushdown_splits_aggregate_below_exchange():
    phys = lower(_group_plan(64), ExecutionContext(**IL), ROWS, n_shards=4)
    root = phys.root
    assert root.merge == "pushdown"
    assert isinstance(root.child, PH.Exchange)
    assert isinstance(root.child.child, PH.PPartialAggregate)
    # moved rows shrink from ~per-shard records to ~n_groups
    on = PH.moved_rows(root)
    off = PH.moved_rows(lower(_group_plan(64),
                              ExecutionContext(agg_pushdown=False, **IL),
                              ROWS, n_shards=4).root)
    assert on == 64 * 3 // 4 and off > 64 * 10


def test_pushdown_declined_when_groups_exceed_rows():
    big = ROWS["fact"] * 2            # more groups than per-shard rows
    phys = lower(_group_plan(big), ExecutionContext(**IL), ROWS, n_shards=4)
    assert phys.root.merge == "owner"
    assert isinstance(phys.root.child, PH.Exchange)
    assert phys.root.child.key == "k"
    forced = lower(_group_plan(big), ExecutionContext(agg_pushdown=True,
                                                      **IL),
                   ROWS, n_shards=4)
    assert forced.root.merge == "pushdown"


def test_explain_reports_fewer_moved_rows_with_pushdown():
    tables = {"fact": {"k": np.zeros(ROWS["fact"], np.int32),
                       "v": np.zeros(ROWS["fact"], np.float32)}}

    def moved(ctx):
        return sum(c[0][1] for c in
                   [d.costs for d in planner.explain(_group_plan(64),
                                                     tables, ctx)
                    if d.node == "Exchange"])

    import jax
    mesh = jax.make_mesh((1,), ("data",))
    on = moved(ExecutionContext(mesh=mesh, **IL))
    off = moved(ExecutionContext(mesh=mesh, agg_pushdown=False, **IL))
    # n=1 zeroes wire estimates for record routing too, so lower for a
    # 4-shard tree through explain_physical instead for the headline
    on4 = PH.moved_rows(lower(_group_plan(64), ExecutionContext(**IL),
                              ROWS, n_shards=4).root)
    off4 = PH.moved_rows(lower(_group_plan(64),
                               ExecutionContext(agg_pushdown=False, **IL),
                               ROWS, n_shards=4).root)
    assert on4 < off4
    assert on <= off


# ---------------------------------------------------------------------------
# rewrite rule 2: route-once
# ---------------------------------------------------------------------------
def test_route_once_elides_aggregate_record_exchange():
    jp = L.scan("fact").join(L.scan("d1"), "k1", "pk1", {"_v": "v1"})
    p = L.LogicalPlan(jp.aggregate("k1", ROWS["d1"], s=("sum", "_v")), None)
    phys = lower(p, ExecutionContext(dist_join="partitioned", **IL),
                 ROWS, n_shards=4)
    assert phys.root.merge == "placed"
    # only the two join-side routings remain: records moved ONCE
    ex = PH.exchanges(phys.root)
    assert len(ex) == 2 and {e.key for e in ex} == {"k1", "pk1"}
    # disabled, the aggregate routes the records again
    off = lower(p, ExecutionContext(dist_join="partitioned",
                                    route_once=False, **IL),
                ROWS, n_shards=4)
    assert off.root.merge in ("owner", "pushdown")


def test_route_once_elides_probe_rerouting_on_same_key():
    node = L.scan("fact").join(L.scan("d1"), "k1", "pk1", {"_v1": "v1"})
    node = node.join(L.scan("d2"), "k1", "pk2", {"_v2": "v2"})
    p = L.LogicalPlan(node.aggregate(None, 1, c=("count", "_v2")), None)
    phys = lower(p, ExecutionContext(dist_join="partitioned", **IL),
                 ROWS, n_shards=4)
    outer = phys.root.child
    assert isinstance(outer, PH.PJoin) and outer.dist == "partitioned"
    # probe side is the inner join DIRECTLY — already placed by k1
    assert isinstance(outer.probe, PH.PJoin)
    assert isinstance(outer.build, PH.Exchange)


def test_structurally_identical_build_exchanges_dedup():
    d = L.scan("d1")
    node = L.scan("fact").join(d, "k1", "pk1", {"_a": "v1"})
    node = node.join(d, "k2", "pk1", {"_b": "v1"})
    p = L.LogicalPlan(node.aggregate(None, 1, c=("count", "_b")), None)
    phys = lower(p, ExecutionContext(dist_join="partitioned", **IL),
                 ROWS, n_shards=4)
    build_ex = [n for n in PH.walk(phys.root)
                if isinstance(n, PH.Exchange) and n.key == "pk1"]
    assert len(build_ex) == 2 and build_ex[0] == build_ex[1]
    # walk_unique (the executor's memoization view) sees it once
    assert sum(1 for n in PH.walk_unique(phys.root)
               if isinstance(n, PH.Exchange) and n.key == "pk1") == 1


# ---------------------------------------------------------------------------
# rewrite rule 3: occupancy-aware Compact
# ---------------------------------------------------------------------------
def test_compact_bounds_chained_join_buffers():
    ctx = ExecutionContext(dist_join="partitioned", **IL)
    off_ctx = ExecutionContext(dist_join="partitioned", compact=False, **IL)
    n, cf = 4, ctx.capacity_factor
    est = (ROWS["fact"] + (-ROWS["fact"] % n)) // n

    def probe_buffers(plan):
        """Probe-side hash-Exchange buffer rows, inner join outward."""
        out = []
        node = plan.root.child            # the outermost PJoin
        while isinstance(node, PH.PJoin):
            side = node.probe
            if isinstance(side, PH.Exchange):
                out.append(side.rows)
                side = side.child
            if isinstance(side, PH.Compact):
                side = side.child
            node = side
        return list(reversed(out))

    with_c = probe_buffers(lower(_chain_plan(2), ctx, ROWS, n_shards=n))
    without = probe_buffers(lower(_chain_plan(2), off_ctx, ROWS,
                                  n_shards=n))
    # hop 1 identical (nothing to compact on a scan); hop 2 bounded by the
    # occupancy-aware budget instead of growing another capacity_factor
    assert with_c[0] == without[0]
    assert with_c[1] < without[1]
    bound = n * routing_capacity(PH.ceil128(planner.COMPACT_MARGIN * est),
                                 n, cf)
    assert with_c[1] <= bound
    # and a Compact node sits under the second routing
    compacts = [x for x in PH.walk(lower(_chain_plan(2), ctx, ROWS,
                                         n_shards=n).root)
                if isinstance(x, PH.Compact)]
    assert compacts and all(c.capacity < c.child.rows for c in compacts)


def test_compact_not_inserted_on_tight_buffers():
    # a scan is occupancy-tight: est == rows, nothing to reclaim
    phys = lower(_chain_plan(1), ExecutionContext(dist_join="partitioned",
                                                  **IL),
                 ROWS, n_shards=4)
    assert not any(isinstance(x, PH.Compact) for x in PH.walk(phys.root))


def test_compact_routed_rows_unit():
    cols = {"k": jnp.asarray(np.array([5, -1, 7, -1, 9, -1, 11, -1],
                                      np.int32)),
            "v": jnp.asarray(np.arange(8, dtype=np.float32))}
    w = jnp.asarray(np.array([1, 0, 1, 0, 1, 0, 1, 0], np.float32))
    kept, kw, ovf = compact_routed_rows(cols, w, 4)
    assert int(ovf) == 0
    # alive rows first, original relative order preserved
    np.testing.assert_array_equal(np.asarray(kept["k"]), [5, 7, 9, 11])
    np.testing.assert_array_equal(np.asarray(kept["v"]), [0, 2, 4, 6])
    np.testing.assert_array_equal(np.asarray(kw), [1, 1, 1, 1])
    # alive rows beyond capacity are COUNTED, never silently vanish
    _, _, ovf2 = compact_routed_rows(cols, w, 2)
    assert int(ovf2) == 2


# ---------------------------------------------------------------------------
# execution: the rewritten plans answer identically (4-device subprocess)
# ---------------------------------------------------------------------------
EXEC_TEST = """
import numpy as np, jax, jax.numpy as jnp
from repro.analytics import plan as L
from repro.analytics import physical as PH
from repro.analytics import planner
from repro.analytics.planner import ExecutionContext, compile_plan
from repro.core.config import PlacementPolicy

mesh = jax.make_mesh((4,), ("data",))
rng = np.random.RandomState(3)
N, D = 1 << 13, 1 << 10
tables = {
    "fact": {"k1": jnp.asarray(rng.randint(0, D, N).astype(np.int32)),
             "k2": jnp.asarray(rng.randint(0, D, N).astype(np.int32)),
             "g": jnp.asarray(rng.randint(0, 64, N).astype(np.int32)),
             "v": jnp.asarray(rng.rand(N).astype(np.float32))},
    "d1": {"pk1": jnp.asarray(rng.permutation(D).astype(np.int32)),
           "v1": jnp.asarray(rng.rand(D).astype(np.float32))},
    "d2": {"pk2": jnp.asarray(rng.permutation(D).astype(np.int32)),
           "v2": jnp.asarray(rng.rand(D).astype(np.float32))}}

node = L.scan("fact").join(L.scan("d1"), "k1", "pk1", {"_v1": "v1"})
node = node.join(L.scan("d2"), "k2", "pk2", {"_v2": "v2"})
chain = L.LogicalPlan(node.aggregate(
    "g", 64, c=("count", "_v2"), s=("sum", "_v2"), m=("max", "_v1")), None)

ref = planner.execute_plan(chain, tables, ExecutionContext(executor="xla"))
for compact in (None, False):
    ctx = ExecutionContext(executor="xla", mesh=mesh,
                           policy=PlacementPolicy.INTERLEAVE,
                           dist_join="partitioned", compact=compact)
    cp = compile_plan(chain, tables, ctx)
    has_compact = any(isinstance(x, PH.Compact)
                      for x in PH.walk(cp.physical.root))
    assert has_compact == (compact is None), compact
    got = cp(tables)
    assert int(np.asarray(got["_overflow"])) == 0, compact
    for k in ref:
        a, b = np.asarray(got[k]), np.asarray(ref[k])
        if k in ("c", "m", "_count"):
            assert np.array_equal(a, b, equal_nan=True), (compact, k)
        elif k != "_overflow":
            np.testing.assert_allclose(a, b, atol=1e-2, rtol=1e-4,
                                       err_msg=f"{compact}/{k}")

# push-down on/off answer identically (counts bit-equal) on a group-by
gp = L.LogicalPlan(L.scan("fact").aggregate(
    "g", 64, s=("sum", "v"), c=("count", "v")), None)
ref = planner.execute_plan(gp, tables, ExecutionContext(executor="xla"))
for pd in (True, False):
    ctx = ExecutionContext(executor="xla", mesh=mesh,
                           policy=PlacementPolicy.INTERLEAVE,
                           agg_pushdown=pd)
    cp = compile_plan(gp, tables, ctx)
    assert (cp.physical.root.merge == "pushdown") == pd
    got = cp(tables)
    assert int(np.asarray(got["_overflow"])) == 0
    assert np.array_equal(np.asarray(got["c"]), np.asarray(ref["c"]))
    np.testing.assert_allclose(np.asarray(got["s"]), np.asarray(ref["s"]),
                               atol=1e-2, rtol=1e-4)
print("PHYSICAL_EXEC_OK")
"""


def test_rewritten_plans_execute_identically():
    out = run_with_devices(EXEC_TEST, n_devices=4, timeout=900)
    assert "PHYSICAL_EXEC_OK" in out
