"""Generative parity harness: random logical plans, every executor, every
placement context — the regression net that locks in the PR-4 lowerings
and (PR 5) the explicit physical-plan layer they now compile through.

Plans come from tests/_plan_gen.py (deterministic per seed; hypothesis,
when installed, drives extra seeds through tests/_hypothesis_compat.py)
and since PR 5 include Attach (the q18 HAVING idiom) and TopK roots.
Each plan runs under executor in {xla, kernel, cost} locally — plus a
deliberately-overflowing kernel-join configuration whose residual
re-probe must repair to exactness — and under {FIRST_TOUCH, INTERLEAVE,
INTERLEAVE without aggregate push-down, INTERLEAVE with a forced
partitioned join} on a 4-device mesh (one subprocess batch) with the
routing capacity_factor fuzzed per seed; results are compared against
the local XLA reference:

  * counts, order statistics (max/min/median/quantile) and TopK indices
    must be BIT-IDENTICAL — they select or count actual values, and every
    lowering funnels through the same segment ops / sort-based selection;
  * sums/averages compare to tight tolerances: fused-kernel and per-shard
    reductions legitimately reassociate float additions, so bit-equality
    across those lowerings is not defined — reduction ORDER is part of the
    float result, not of the relational answer;
  * ``_overflow`` must be 0 everywhere (capacity overflow is a plan-sizing
    bug the harness must catch, never tolerate — including Compact
    overflow and the repaired-residual kernel join).

The local grid covers LOCAL_SEEDS plans x 3 executors; the distributed
batch re-generates DIST_SEEDS of the same plans inside the subprocess.
Together they satisfy the >= 50 generated-plans floor with margin.
"""
import numpy as np
import pytest

from conftest import run_with_devices
from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from _plan_gen import (exact_output, make_plan, make_tables, plan_agg_ops,
                       plan_has_join)

from repro.analytics import plan as L
from repro.analytics.planner import ExecutionContext, execute_plan

LOCAL_SEEDS = range(48)
DIST_SEEDS = range(16)


def _check_parity(got, ref, ops, tag):
    assert set(got) == set(ref), tag
    for k in ref:
        a, b = np.asarray(got[k]), np.asarray(ref[k])
        if k == "_overflow":
            assert int(a) == 0 and int(b) == 0, (tag, k, int(a))
        elif exact_output(k, ops):
            np.testing.assert_array_equal(a, b, err_msg=f"{tag}/{k}")
        else:
            np.testing.assert_allclose(a, b, atol=1e-2, rtol=1e-4,
                                       equal_nan=True,
                                       err_msg=f"{tag}/{k}")


def _run_local_seed(seed: int) -> None:
    plan = make_plan(seed)
    L.validate(plan)
    tables = make_tables()
    ops = plan_agg_ops(plan)
    ref = execute_plan(plan, tables, ExecutionContext(executor="xla"))
    for executor in ("kernel", "cost"):
        got = execute_plan(plan, tables,
                           ExecutionContext(executor=executor))
        _check_parity(got, ref, ops, f"seed={seed}/{executor}")
    if plan_has_join(plan):
        # deliberate kernel-join capacity overflow: the residual sorted
        # re-probe must repair every miss and report zero overflow
        ctx = ExecutionContext(executor="cost", join="kernel",
                               n_partitions=2, capacity_factor=0.25)
        got = execute_plan(plan, tables, ctx)
        _check_parity(got, ref, ops, f"seed={seed}/kernel-join-residual")


@pytest.mark.parametrize("chunk", range(8))
def test_fuzz_local_executor_parity(chunk):
    for seed in LOCAL_SEEDS:
        if seed % 8 == chunk:
            _run_local_seed(seed)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1000, max_value=100_000))
def test_fuzz_local_hypothesis_seeds(seed):
    """Extra seed space when hypothesis is installed (skips otherwise)."""
    _run_local_seed(seed)


DIST_FUZZ = """
import sys
sys.path.insert(0, {testdir!r})
import numpy as np, jax
from _plan_gen import (context_capacity_factor, exact_output, make_plan,
                       make_tables, plan_agg_ops, plan_has_join)
from repro.analytics.planner import ExecutionContext, execute_plan
from repro.core.config import PlacementPolicy

mesh = jax.make_mesh((4,), ("data",))
tables = make_tables()
for seed in {seeds!r}:
    plan = make_plan(seed)
    ops = plan_agg_ops(plan)
    ref = execute_plan(plan, tables, ExecutionContext(executor="xla"))
    cf = context_capacity_factor(seed)
    contexts = [("ft", ExecutionContext(executor="xla", mesh=mesh,
                                        policy=PlacementPolicy.FIRST_TOUCH,
                                        capacity_factor=cf)),
                ("il", ExecutionContext(executor="xla", mesh=mesh,
                                        policy=PlacementPolicy.INTERLEAVE,
                                        capacity_factor=cf)),
                ("il-nopd", ExecutionContext(
                    executor="xla", mesh=mesh,
                    policy=PlacementPolicy.INTERLEAVE,
                    capacity_factor=cf, agg_pushdown=False))]
    if plan_has_join(plan):
        contexts.append(
            ("il-part", ExecutionContext(executor="xla", mesh=mesh,
                                         policy=PlacementPolicy.INTERLEAVE,
                                         capacity_factor=cf,
                                         dist_join="partitioned")))
    for tag, ctx in contexts:
        got = execute_plan(plan, tables, ctx)
        assert set(got) == set(ref), (seed, tag)
        for k in ref:
            a, b = np.asarray(got[k]), np.asarray(ref[k])
            if k == "_overflow":
                assert int(a) == 0, (seed, tag, k, int(a))
            elif exact_output(k, ops):
                assert np.array_equal(a, b, equal_nan=True), (seed, tag, k)
            else:
                np.testing.assert_allclose(a, b, atol=1e-2, rtol=1e-4,
                                           err_msg=f"{{seed}}/{{tag}}/{{k}}")
print("DIST_FUZZ_OK")
"""


def test_fuzz_distributed_policy_parity():
    import os
    testdir = os.path.dirname(os.path.abspath(__file__))
    out = run_with_devices(
        DIST_FUZZ.format(testdir=testdir, seeds=list(DIST_SEEDS)),
        n_devices=4, timeout=900)
    assert "DIST_FUZZ_OK" in out
