"""Generative parity harness: random logical plans, every executor, every
placement context — the regression net that locks in the PR-4 lowerings
and (PR 5) the explicit physical-plan layer they now compile through.

Plans come from tests/_plan_gen.py (deterministic per seed; hypothesis,
when installed, drives extra seeds through tests/_hypothesis_compat.py)
and since PR 5 include Attach (the q18 HAVING idiom) and TopK roots.
Each plan runs under executor in {xla, kernel, cost} locally — plus a
deliberately-overflowing kernel-join configuration whose residual
re-probe must repair to exactness — and under {FIRST_TOUCH, INTERLEAVE,
INTERLEAVE without aggregate push-down, INTERLEAVE with a forced
partitioned join, and (PR 9) the partitioned join with the Exchange
routing layout FORCED to argsort and to radix} on a 4-device mesh (one
subprocess batch) with the routing capacity_factor fuzzed per seed;
results are compared against the local XLA reference:

  * counts, order statistics (max/min/median/quantile) and TopK indices
    must be BIT-IDENTICAL — they select or count actual values, and every
    lowering funnels through the same segment ops / sort-based selection;
  * sums/averages compare to tight tolerances: fused-kernel and per-shard
    reductions legitimately reassociate float additions, so bit-equality
    across those lowerings is not defined — reduction ORDER is part of the
    float result, not of the relational answer;
  * ``_overflow`` must be 0 everywhere (capacity overflow is a plan-sizing
    bug the harness must catch, never tolerate — including Compact
    overflow and the repaired-residual kernel join).

The local grid covers LOCAL_SEEDS plans x 3 executors; the distributed
batch re-generates DIST_SEEDS of the same plans inside the subprocess.
Together they satisfy the >= 50 generated-plans floor with margin.

Since PR 7 both grids also make a telemetry-tracked pass per plan: the
tracked run must return the exact same result set (the reserved
``"_stats"`` key never leaks), and the recorded counters must satisfy
stats conservation — hash routing conserves alive rows up to surfaced
overflow, broadcast wire traffic is exactly alive*(n-1), total recorded
overflow equals the plan's ``_overflow`` output, and the join/aggregate
alive counts agree bit-exactly across placements and with the local
reference (they are relational facts, independent of the lowering).
"""
import dataclasses

import numpy as np
import pytest

from conftest import run_with_devices
from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from _plan_gen import (MORSEL_ROWS_CHOICES, _root_aggregate,
                       context_morsel_rows, exact_output, make_plan,
                       make_tables, plan_agg_ops, plan_has_join)

from repro.analytics import plan as L
from repro.analytics import planner, telemetry
from repro.analytics.planner import ExecutionContext, execute_plan

LOCAL_SEEDS = range(48)
DIST_SEEDS = range(16)
MORSEL_SEEDS = range(24)


def _check_parity(got, ref, ops, tag):
    assert set(got) == set(ref), tag
    for k in ref:
        a, b = np.asarray(got[k]), np.asarray(ref[k])
        if k == "_overflow":
            assert int(a) == 0 and int(b) == 0, (tag, k, int(a))
        elif exact_output(k, ops):
            np.testing.assert_array_equal(a, b, err_msg=f"{tag}/{k}")
        else:
            np.testing.assert_allclose(a, b, atol=1e-2, rtol=1e-4,
                                       equal_nan=True,
                                       err_msg=f"{tag}/{k}")


def _run_local_seed(seed: int) -> None:
    plan = make_plan(seed)
    L.validate(plan)
    tables = make_tables()
    ops = plan_agg_ops(plan)
    ref = execute_plan(plan, tables, ExecutionContext(executor="xla"))
    for executor in ("kernel", "cost"):
        got = execute_plan(plan, tables,
                           ExecutionContext(executor=executor))
        _check_parity(got, ref, ops, f"seed={seed}/{executor}")
    if plan_has_join(plan):
        # deliberate kernel-join capacity overflow: the residual sorted
        # re-probe must repair every miss and report zero overflow
        ctx = ExecutionContext(executor="cost", join="kernel",
                               n_partitions=2, capacity_factor=0.25)
        got = execute_plan(plan, tables, ctx)
        _check_parity(got, ref, ops, f"seed={seed}/kernel-join-residual")
    # telemetry pass: a tracked run returns the SAME result set (so the
    # reserved "_stats" key never leaks to callers — _check_parity's
    # set-equality enforces it) and registers exact per-node counters
    with telemetry.recording() as reg:
        cp = planner.compile_plan(plan, tables,
                                  ExecutionContext(executor="cost"))
        tracked = cp(tables)
    _check_parity(tracked, ref, ops, f"seed={seed}/cost+telemetry")
    ps = reg.get(cp.cache_key)
    assert ps is not None and ps.executions == 1, seed
    occupied = [ns.last["groups_occupied"] for ns in ps.nodes.values()
                if "groups_occupied" in ns.last]
    assert all(v >= 0 for ns in ps.nodes.values()
               for v in ns.last.values()), seed
    has_topk = any(isinstance(n, L.TopK) for n in L.walk(plan.root))
    if not has_topk and _root_aggregate(plan).key is not None:
        # the grouped aggregate's occupied-group count is exact
        occ_ref = int(np.count_nonzero(np.asarray(ref["_count"]) > 0))
        assert occ_ref in occupied, (seed, occ_ref, occupied)


@pytest.mark.parametrize("chunk", range(8))
def test_fuzz_local_executor_parity(chunk):
    for seed in LOCAL_SEEDS:
        if seed % 8 == chunk:
            _run_local_seed(seed)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1000, max_value=100_000))
def test_fuzz_local_hypothesis_seeds(seed):
    """Extra seed space when hypothesis is installed (skips otherwise)."""
    _run_local_seed(seed)


def test_fuzz_morsel_scheduler_parity():
    """Morsel-forced grid (PR 10): the same generated plans dispatched
    through MorselScheduler with the split threshold shrunk below the
    768-row fact table, per-seed morsel sizes from _plan_gen.

    Join plans take the split-probe path (build side pool-replicated,
    probe morsels merged in morsel order) and must be BIT-IDENTICAL to
    the serial executor — the probe phase computes per-row values, so
    splitting it cannot reassociate any reduction. Join-free distributive
    plans take the legacy partial-sums path (tolerance tier for
    sums/avgs, its documented trade). Per-pool executed/steal counters
    must account for exactly the dispatched morsels."""
    import jax.numpy as jnp
    from repro.analytics.service.scheduler import (MorselScheduler,
                                                   ThreadPlacement)
    base = planner.current_cost_profile()
    planner.set_cost_profile(dataclasses.replace(base, morsel_split_rows=64))
    split_probe_seeds = 0
    try:
        raw = make_tables()
        tables = {t: {c: jnp.asarray(v) for c, v in cols.items()}
                  for t, cols in raw.items()}
        ctx = ExecutionContext(executor="cost", join="sorted")
        for mr in MORSEL_ROWS_CHOICES:
            seeds = [s for s in MORSEL_SEEDS if context_morsel_rows(s) == mr]
            with MorselScheduler(n_pools=2, workers_per_pool=2,
                                 morsel_rows=mr,
                                 placement=ThreadPlacement.SPARSE) as sched:
                for seed in seeds:
                    plan = make_plan(seed)
                    ops = plan_agg_ops(plan)
                    ref = execute_plan(plan, tables, ctx)
                    task = sched.build_task(plan, tables, ctx)
                    got = sched.submit(task).wait()
                    probe_split = task.split and plan_has_join(plan)
                    if probe_split:
                        split_probe_seeds += 1
                        assert len(task.morsels) >= 2, seed
                        assert set(got) == set(ref), seed
                        for k in ref:
                            np.testing.assert_array_equal(
                                np.asarray(got[k]), np.asarray(ref[k]),
                                err_msg=f"morsel seed={seed}/{k}")
                    else:
                        _check_parity(got, ref, ops,
                                      f"morsel seed={seed}")
                st = sched.stats()
                assert sum(st.executed_per_pool) == st.morsels_dispatched
                assert 0 <= st.steals <= st.morsels_dispatched
    finally:
        planner.set_cost_profile(base)
    # roughly half the generated plans join: the grid must actually have
    # exercised the split-probe path, not silently served everything whole
    assert split_probe_seeds >= len(MORSEL_SEEDS) // 4, split_probe_seeds


DIST_FUZZ = """
import sys
sys.path.insert(0, {testdir!r})
import numpy as np, jax
from _plan_gen import (_root_aggregate, context_capacity_factor,
                       context_dist_topk, exact_output, make_plan,
                       make_tables, plan_agg_ops, plan_has_join)
from repro.analytics import plan as L, planner, telemetry
import repro.analytics.physical as PH
from repro.analytics.planner import ExecutionContext, execute_plan
from repro.core.config import PlacementPolicy

mesh = jax.make_mesh((4,), ("data",))
tables = make_tables()

def check(got, ref, ops, seed, tag):
    assert set(got) == set(ref), (seed, tag)
    for k in ref:
        a, b = np.asarray(got[k]), np.asarray(ref[k])
        if k == "_overflow":
            assert int(a) == 0, (seed, tag, k, int(a))
        elif exact_output(k, ops):
            assert np.array_equal(a, b, equal_nan=True), (seed, tag, k)
        else:
            np.testing.assert_allclose(a, b, atol=1e-2, rtol=1e-4,
                                       err_msg="%s/%s/%s" % (seed, tag, k))

def conservation(reg, cp, tout, seed, tag):
    # stats-conservation invariants over one recorded execution: routing
    # conserves alive rows up to (surfaced) overflow, a broadcast's wire
    # traffic is exactly alive*(n-1), and every overflow counter the
    # executor accumulated is visible in the registry
    ps = reg.get(cp.cache_key)
    assert ps is not None and ps.executions == 1, (seed, tag)
    nodes = ps.node_list()
    ovf, joins, aggs = 0, [], []
    for i, ns in sorted(ps.nodes.items()):
        node = nodes[i]
        assert all(v >= 0 for v in ns.last.values()), (seed, tag, ns)
        if isinstance(node, PH.Exchange):
            o = ns.last.get("overflow", 0)
            ovf += o
            if node.kind == "hash":
                assert ns.last["alive_out"] == ns.last["alive_in"] - o, \\
                    (seed, tag, ns.last)
                assert ns.last["moved"] <= ns.last["alive_in"], \\
                    (seed, tag, ns.last)
            else:
                assert ns.last["moved"] == ns.last["alive_in"] * 3, \\
                    (seed, tag, ns.last)
        elif isinstance(node, PH.Compact):
            o = ns.last.get("overflow", 0)
            ovf += o
            assert ns.last["alive_out"] == ns.last["alive_in"] - o, \\
                (seed, tag, ns.last)
        elif isinstance(node, PH.PJoin) and node.dist is not None:
            assert ns.last["out_alive"] <= ns.last["probe_alive"], \\
                (seed, tag, ns.last)
            joins.append((ns.last["probe_alive"], ns.last["build_alive"],
                          ns.last["out_alive"]))
        elif isinstance(node, PH.PAggregate) and node.key is not None:
            assert ns.last["groups_occupied"] <= node.n_groups, \\
                (seed, tag, ns.last)
            aggs.append(ns.last["groups_occupied"])
    assert ovf == int(np.asarray(tout["_overflow"])) == 0, (seed, tag, ovf)
    return sorted(joins), sorted(aggs)

for seed in {seeds!r}:
    plan = make_plan(seed)
    ops = plan_agg_ops(plan)
    ref = execute_plan(plan, tables, ExecutionContext(executor="xla"))
    cf = context_capacity_factor(seed)
    has_topk = any(isinstance(n, L.TopK) for n in L.walk(plan.root))
    contexts = [("ft", ExecutionContext(executor="xla", mesh=mesh,
                                        policy=PlacementPolicy.FIRST_TOUCH,
                                        capacity_factor=cf)),
                ("il", ExecutionContext(executor="xla", mesh=mesh,
                                        policy=PlacementPolicy.INTERLEAVE,
                                        capacity_factor=cf)),
                ("il-nopd", ExecutionContext(
                    executor="xla", mesh=mesh,
                    policy=PlacementPolicy.INTERLEAVE,
                    capacity_factor=cf, agg_pushdown=False))]
    if plan_has_join(plan):
        contexts.append(
            ("il-part", ExecutionContext(executor="xla", mesh=mesh,
                                         policy=PlacementPolicy.INTERLEAVE,
                                         capacity_factor=cf,
                                         dist_join="partitioned")))
        # PR 9: the same partitioned plans with the Exchange layout pass
        # FORCED each way — parity must be bit-exact and the conservation
        # invariants must hold on BOTH routing paths (incl. any
        # Filter-below-Exchange rewrite the lowering applied)
        for impl in ("argsort", "radix"):
            contexts.append(
                ("il-part-" + impl,
                 ExecutionContext(executor="xla", mesh=mesh,
                                  policy=PlacementPolicy.INTERLEAVE,
                                  capacity_factor=cf,
                                  dist_join="partitioned",
                                  exchange_impl=impl)))
    recorded = []
    for tag, ctx in contexts:
        got = execute_plan(plan, tables, ctx)
        check(got, ref, ops, seed, tag)
        if tag in ("il", "il-part-argsort", "il-part-radix"):
            # tracked re-run: same results (check() proves "_stats" never
            # leaks), plus exact conservation of the recorded counters
            with telemetry.recording() as reg:
                cp = planner.compile_plan(plan, tables, ctx)
                tout = cp(tables)
            check(tout, ref, ops, seed, tag + "+rec")
            recorded.append(conservation(reg, cp, tout, seed, tag))
    # registry totals are exact across placements: occupied groups are
    # relational facts, independent of lowering. Join alive counts are
    # relational facts GIVEN one plan shape — the Filter-below-Exchange
    # rewrite moves a pushable filter across the join boundary in
    # partitioned lowerings (probe_alive is then observed post-filter) —
    # so they are compared only where the lowered shape matches: the two
    # forced-impl partitioned contexts, which may differ ONLY in the
    # routing layout pass, must agree bit-exactly
    for other in recorded[1:]:
        assert other[1] == recorded[0][1], (seed, recorded)
    if len(recorded) == 3:
        assert recorded[1] == recorded[2], (seed, recorded)
    if recorded and not has_topk and _root_aggregate(plan).key is not None:
        occ = int(np.count_nonzero(np.asarray(ref["_count"]) > 0))
        assert occ in recorded[0][1], (seed, occ, recorded[0])
    # PR 10: distributed-TopK lowerings forced BOTH ways must stay
    # bit-identical to the local reference (top_idx is exact: the
    # candidates path's tie-breaks reproduce replicated's
    # ascending-index rule by construction). The cost default above
    # already ran whichever one topk_costs picked; the per-seed tracked
    # pass pins the wire accounting of the chosen lowering.
    if has_topk:
        k = plan.root.k
        for mode in ("replicated", "candidates"):
            tctx = ExecutionContext(executor="xla", mesh=mesh,
                                    policy=PlacementPolicy.INTERLEAVE,
                                    capacity_factor=cf, dist_topk=mode)
            check(execute_plan(plan, tables, tctx), ref, ops, seed,
                  "tk-" + mode)
        mode = context_dist_topk(seed)
        tctx = ExecutionContext(executor="xla", mesh=mesh,
                                policy=PlacementPolicy.INTERLEAVE,
                                capacity_factor=cf, dist_topk=mode)
        with telemetry.recording() as reg:
            cp = planner.compile_plan(plan, tables, tctx)
            tout = cp(tables)
        check(tout, ref, ops, seed, "tk-" + mode + "+rec")
        ps = reg.get(cp.cache_key)
        nodes = ps.node_list()
        topks = [n for n in nodes if isinstance(n, PH.PTopK)]
        assert len(topks) == 1 and topks[0].dist == mode, (seed, mode)
        if mode == "candidates":
            # movement-free contract: only k rows per shard converge
            # through the gather — k * n_shards candidates total, and
            # the observed wire volume equals the estimate exactly
            ex = topks[0].child
            assert isinstance(ex, PH.Exchange) and ex.kind == "gather", ex
            assert ex.moved_rows == k * 3 <= k * 4, (seed, ex)
            ns = [s for i, s in ps.nodes.items() if nodes[i] is ex][0]
            assert ns.last["alive_in"] == k * 4, (seed, ns.last)
            assert ns.last["moved"] == k * 3 * 4, (seed, ns.last)
        else:
            # replicated selects on the merged table: no TopK Exchange
            assert not isinstance(topks[0].child, PH.Exchange), seed

# PR-9 empty-alive guard: a predicate no fact row satisfies (d is drawn
# from [0, 100)) kills every row on EVERY shard before the partitioned
# join routes them. Dead rows spread round-robin with weight 0, so both
# Exchange layout passes must deliver the all-empty answer with zero
# overflow — this pins the degenerate-shard clip guard in both paths.
from _plan_gen import G1
dead = L.LogicalPlan(
    L.scan("fact").filter(L.col("d") < 0.0)
    .join(L.scan("dim"), "fk", "pk", {{"_dv": "dv"}})
    .aggregate("key1", G1, s=("sum", "v1"), c=("count", "v1")), None)
dops = plan_agg_ops(dead)
dref = execute_plan(dead, tables, ExecutionContext(executor="xla"))
assert int(np.asarray(dref["c"]).sum()) == 0
for impl in ("argsort", "radix"):
    ctx = ExecutionContext(executor="xla", mesh=mesh,
                           policy=PlacementPolicy.INTERLEAVE,
                           dist_join="partitioned", exchange_impl=impl)
    check(execute_plan(dead, tables, ctx), dref, dops, "dead", impl)
print("DIST_FUZZ_OK")
"""


def test_fuzz_distributed_policy_parity():
    import os
    testdir = os.path.dirname(os.path.abspath(__file__))
    out = run_with_devices(
        DIST_FUZZ.format(testdir=testdir, seeds=list(DIST_SEEDS)),
        n_devices=4, timeout=900)
    assert "DIST_FUZZ_OK" in out
