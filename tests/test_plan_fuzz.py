"""Generative parity harness: random logical plans, every executor, every
placement context — the regression net that locks in the PR-4 lowerings.

Plans come from tests/_plan_gen.py (deterministic per seed; hypothesis,
when installed, drives extra seeds through tests/_hypothesis_compat.py).
Each plan runs under executor in {xla, kernel, cost} locally and under
{FIRST_TOUCH, INTERLEAVE} on a 4-device mesh (one subprocess batch), and
the results are compared against the local XLA reference:

  * counts and order statistics (max/min/median) must be BIT-IDENTICAL —
    they select or count actual values, and every lowering funnels through
    the same segment ops / segment_median selection;
  * sums/averages compare to tight tolerances: fused-kernel and per-shard
    reductions legitimately reassociate float additions, so bit-equality
    across those lowerings is not defined — reduction ORDER is part of the
    float result, not of the relational answer;
  * ``_overflow`` must be 0 everywhere (capacity overflow is a plan-sizing
    bug the harness must catch, never tolerate).

The local grid covers LOCAL_SEEDS plans x 3 executors; the distributed
batch re-generates DIST_SEEDS of the same plans inside the subprocess.
Together they satisfy the >= 50 generated-plans floor with margin.
"""
import numpy as np
import pytest

from conftest import run_with_devices
from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from _plan_gen import make_plan, make_tables, plan_agg_ops

from repro.analytics import plan as L
from repro.analytics.planner import ExecutionContext, execute_plan

LOCAL_SEEDS = range(48)
DIST_SEEDS = range(16)
EXACT_OPS = ("count", "max", "min", "median")


def _check_parity(got, ref, ops, tag):
    assert set(got) == set(ref), tag
    for k in ref:
        a, b = np.asarray(got[k]), np.asarray(ref[k])
        if k == "_overflow":
            assert int(a) == 0 and int(b) == 0, (tag, k, int(a))
        elif k == "_count" or ops.get(k) in EXACT_OPS:
            np.testing.assert_array_equal(a, b, err_msg=f"{tag}/{k}")
        else:
            np.testing.assert_allclose(a, b, atol=1e-2, rtol=1e-4,
                                       equal_nan=True,
                                       err_msg=f"{tag}/{k}")


def _run_local_seed(seed: int) -> None:
    plan = make_plan(seed)
    L.validate(plan)
    tables = make_tables()
    ops = plan_agg_ops(plan)
    ref = execute_plan(plan, tables, ExecutionContext(executor="xla"))
    for executor in ("kernel", "cost"):
        got = execute_plan(plan, tables,
                           ExecutionContext(executor=executor))
        _check_parity(got, ref, ops, f"seed={seed}/{executor}")


@pytest.mark.parametrize("chunk", range(8))
def test_fuzz_local_executor_parity(chunk):
    for seed in LOCAL_SEEDS:
        if seed % 8 == chunk:
            _run_local_seed(seed)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1000, max_value=100_000))
def test_fuzz_local_hypothesis_seeds(seed):
    """Extra seed space when hypothesis is installed (skips otherwise)."""
    _run_local_seed(seed)


DIST_FUZZ = """
import sys
sys.path.insert(0, {testdir!r})
import numpy as np, jax
from _plan_gen import make_plan, make_tables, plan_agg_ops
from repro.analytics.planner import ExecutionContext, execute_plan
from repro.core.config import PlacementPolicy

EXACT_OPS = ("count", "max", "min", "median")
mesh = jax.make_mesh((4,), ("data",))
tables = make_tables()
for seed in {seeds!r}:
    plan = make_plan(seed)
    ops = plan_agg_ops(plan)
    ref = execute_plan(plan, tables, ExecutionContext(executor="xla"))
    has_join = "_dk" in str(plan)
    contexts = [("ft", ExecutionContext(executor="xla", mesh=mesh,
                                        policy=PlacementPolicy.FIRST_TOUCH,
                                        capacity_factor=4.0)),
                ("il", ExecutionContext(executor="xla", mesh=mesh,
                                        policy=PlacementPolicy.INTERLEAVE,
                                        capacity_factor=4.0))]
    if has_join:
        contexts.append(
            ("il-part", ExecutionContext(executor="xla", mesh=mesh,
                                         policy=PlacementPolicy.INTERLEAVE,
                                         capacity_factor=4.0,
                                         dist_join="partitioned")))
    for tag, ctx in contexts:
        got = execute_plan(plan, tables, ctx)
        assert set(got) == set(ref), (seed, tag)
        for k in ref:
            a, b = np.asarray(got[k]), np.asarray(ref[k])
            if k == "_overflow":
                assert int(a) == 0, (seed, tag, k, int(a))
            elif k == "_count" or ops.get(k) in EXACT_OPS:
                assert np.array_equal(a, b, equal_nan=True), (seed, tag, k)
            else:
                np.testing.assert_allclose(a, b, atol=1e-2, rtol=1e-4,
                                           err_msg=f"{{seed}}/{{tag}}/{{k}}")
print("DIST_FUZZ_OK")
"""


def test_fuzz_distributed_policy_parity():
    import os
    testdir = os.path.dirname(os.path.abspath(__file__))
    out = run_with_devices(
        DIST_FUZZ.format(testdir=testdir, seeds=list(DIST_SEEDS)),
        n_devices=4, timeout=900)
    assert "DIST_FUZZ_OK" in out
