"""Flash-attention kernel: shape/dtype sweeps vs the pure-jnp oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import (attention_chunked,
                                               attention_chunked_with_lse,
                                               attention_naive)

CASES = [
    # (B, Sq, Skv, Hq, Hkv, D, causal, window)
    (1, 16, 16, 1, 1, 8, True, None),
    (2, 64, 64, 8, 2, 32, True, None),        # GQA
    (2, 64, 64, 8, 8, 16, True, None),        # MHA
    (1, 128, 128, 4, 1, 64, True, None),      # MQA
    (2, 64, 64, 4, 2, 32, True, 16),          # local window
    (1, 32, 64, 2, 2, 16, True, None),        # Sq != Skv (q_offset below)
    (2, 64, 64, 4, 4, 32, False, None),       # non-causal
]


def _mk(rng, B, Sq, Skv, Hq, Hkv, D, dtype):
    q = jnp.asarray(rng.randn(B, Sq, Hq, D), dtype)
    k = jnp.asarray(rng.randn(B, Skv, Hkv, D), dtype)
    v = jnp.asarray(rng.randn(B, Skv, Hkv, D), dtype)
    return q, k, v


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_interpret_matches_naive(rng, case, dtype):
    B, Sq, Skv, Hq, Hkv, D, causal, window = case
    q, k, v = _mk(rng, B, Sq, Skv, Hq, Hkv, D, dtype)
    off = Skv - Sq
    ref = attention_naive(q, k, v, causal=causal, window=window, q_offset=off)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          q_offset=off, mode="interpret")
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("case", CASES)
def test_chunked_matches_naive(rng, case):
    B, Sq, Skv, Hq, Hkv, D, causal, window = case
    q, k, v = _mk(rng, B, Sq, Skv, Hq, Hkv, D, jnp.float32)
    ref = attention_naive(q, k, v, causal=causal, window=window)
    got = attention_chunked(q, k, v, causal=causal, window=window,
                            block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-6)


def test_lse_consistency(rng):
    q, k, v = _mk(rng, 2, 32, 32, 4, 2, 16, jnp.float32)
    out, lse = attention_chunked_with_lse(q, k, v, block_q=8, block_k=8)
    ref = attention_naive(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)
    # lse must reproduce softmax denominators: recompute row 0 by hand
    s = (np.asarray(q[0, :, 0], np.float64) @
         np.asarray(k[0, :, 0], np.float64).T) * (16 ** -0.5)
    mask = np.tril(np.ones((32, 32), bool))
    s = np.where(mask, s, -1e30)
    lse_ref = np.log(np.exp(s - s.max(1, keepdims=True)).sum(1)) + s.max(1)
    np.testing.assert_allclose(np.asarray(lse)[0, :, 0], lse_ref, atol=1e-4)


@pytest.mark.parametrize("case", CASES[:5])
def test_manual_backward_matches_autodiff(rng, case):
    B, Sq, Skv, Hq, Hkv, D, causal, window = case
    q, k, v = _mk(rng, B, Sq, Skv, Hq, Hkv, D, jnp.float32)

    def f_op(q, k, v):
        return (flash_attention(q, k, v, causal=causal, window=window,
                                mode="ref") ** 2).sum()

    def f_ref(q, k, v):
        return (attention_naive(q, k, v, causal=causal, window=window)
                ** 2).sum()

    g_op = jax.grad(f_op, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_op, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=1e-3)


def test_decode_matches_full(rng):
    """Decode against a cache == last row of full causal attention."""
    from repro.kernels.flash_attention import decode_attention
    q, k, v = _mk(rng, 2, 24, 24, 4, 2, 16, jnp.float32)
    B, S = 2, 24
    full = attention_naive(q, k, v, causal=True)
    got = decode_attention(q[:, -1:], k, v,
                           jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(got)[:, 0], np.asarray(full)[:, -1],
                               atol=2e-6)
