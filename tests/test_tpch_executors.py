"""Tuned (kernel-backed) TPC-H executor vs the default XLA plan.

Both executor paths must produce the same results on every query — the
Fig 8/9 default-vs-tuned benchmark is only meaningful if the two plans are
semantically identical. Also covers the cached pkfk_join build index and
the plan cache keying.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.analytics.columnar import Table, group_aggregate, pkfk_join
from repro.analytics.tpch import (DATE1, QUERIES, clear_plan_cache, generate,
                                  plan_cache_size, run_query)


@pytest.fixture(scope="module")
def data():
    return generate(scale=0.004, seed=1)


@pytest.mark.parametrize("name", sorted(QUERIES))
def test_executor_parity(data, name):
    ref = run_query(name, data, executor="xla")
    got = run_query(name, data, executor="kernel")
    assert set(got) == set(ref)
    for k in ref:
        if k == "_overflow":
            continue
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   atol=1e-3, rtol=1e-4,
                                   err_msg=f"{name}/{k}")
    if "_overflow" in got:
        assert int(np.asarray(got["_overflow"])) == 0


def test_group_aggregate_kernel_matches_xla_all_ops(rng):
    """Every agg op, masked rows, both kernel regimes (dense/partitioned)."""
    for n_groups in (37, 6000):   # below / above DENSE_GROUP_LIMIT
        n = 10_000
        t = Table({
            "k": jnp.asarray(rng.randint(0, n_groups, n), jnp.int32),
            "v": jnp.asarray(rng.randn(n) * 100, jnp.float32),
            "u": jnp.asarray(rng.rand(n), jnp.float32),
        }).filter(jnp.asarray(rng.rand(n) < 0.7))
        aggs = {"s": ("sum", "v"), "a": ("avg", "v"), "c": ("count", "v"),
                "s2": ("sum", "u"), "mx": ("max", "v"), "mn": ("min", "v")}
        ref = group_aggregate(t, "k", n_groups, aggs, executor="xla")
        got = group_aggregate(t, "k", n_groups, aggs, executor="kernel")
        assert int(np.asarray(got["_overflow"])) == 0
        for k in ref:
            if k == "_overflow":
                continue
            np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                       atol=1e-3, rtol=1e-4,
                                       err_msg=f"G={n_groups}/{k}")


def test_group_aggregate_kernel_counts_overflow(rng):
    """Skewed keys beyond partition capacity are counted, never dropped."""
    n, n_groups = 20_000, 6000
    keys = jnp.zeros(n, jnp.int32)        # all rows hit partition 0
    t = Table({"k": keys, "v": jnp.ones(n, jnp.float32)})
    got = group_aggregate(t, "k", n_groups, {"s": ("sum", "v")},
                          executor="kernel", capacity_factor=1.0)
    assert int(np.asarray(got["_overflow"])) > 0


def test_pkfk_join_cached_index_matches_uncached(rng):
    n_dim, n_fact = 500, 4000
    dk = jnp.asarray(rng.permutation(n_dim), jnp.int32)
    dim = Table({"dk": dk, "payload": jnp.asarray(rng.randn(n_dim),
                                                 jnp.float32)})
    # fact keys include misses (>= n_dim) which must zero the mask
    fk = jnp.asarray(rng.randint(0, n_dim + 100, n_fact), jnp.int32)
    fact = Table({"fk": fk})

    cold = pkfk_join(fact, dim, "fk", "dk", {"p": "payload"})
    assert "dk" in dim.index_cache            # build index was cached
    warm = pkfk_join(fact, dim, "fk", "dk", {"p": "payload"})
    np.testing.assert_array_equal(np.asarray(cold.col("p")),
                                  np.asarray(warm.col("p")))
    np.testing.assert_array_equal(np.asarray(cold.weights()),
                                  np.asarray(warm.weights()))
    # oracle: dense lookup
    lut = np.zeros(n_dim + 100, np.float32)
    lut[np.asarray(dk)] = np.asarray(dim.col("payload"))
    hit = np.asarray(fk) < n_dim
    np.testing.assert_allclose(np.asarray(cold.col("p")) * hit,
                               lut[np.asarray(fk)] * hit, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(cold.weights()),
                                  hit.astype(np.float32))


def test_index_cache_propagation(rng):
    n = 256
    t = Table({"a": jnp.asarray(rng.permutation(n), jnp.int32),
               "b": jnp.asarray(rng.randn(n), jnp.float32)})
    t.key_index("a")
    # filter keeps column identity -> shares the cache
    assert "a" in t.filter(t.col("b") > 0).index_cache
    # adding an unrelated column keeps the entry; overwriting drops it
    assert "a" in t.with_columns(c=t.col("b")).index_cache
    assert "a" not in t.with_columns(a=t.col("a") + 1).index_cache


def test_plan_cache_keying(data):
    clear_plan_cache()
    run_query("q1", data, executor="xla")
    n1 = plan_cache_size()
    assert n1 == 1
    run_query("q1", data, executor="xla")        # same key -> no new plan
    assert plan_cache_size() == n1
    run_query("q1", data, executor="kernel")     # executor is part of the key
    assert plan_cache_size() == n1 + 1
    other = generate(scale=0.006, seed=3)        # new shapes -> new plan
    run_query("q1", other, executor="xla")
    assert plan_cache_size() == n1 + 2
    # same shapes, different values -> cached plan, fresh (correct) results:
    # the seed behavior baked tables in as constants, which this catches
    twin = generate(scale=0.004, seed=9)
    before = plan_cache_size()
    out = run_query("q1", twin, executor="xla")
    assert plan_cache_size() == before
    li = twin.tables["lineitem"]
    expect = li["l_quantity"][li["l_shipdate"] <= DATE1 - 90].sum()
    np.testing.assert_allclose(float(np.asarray(out["sum_qty"]).sum()),
                               expect, rtol=1e-5)
