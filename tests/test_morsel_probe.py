"""Intra-query morsel parallelism: split-probe joins + distributed TopK.

Covers the two PR-10 execution paths end to end:

  * **Split-probe joins** — q3/q5/q18 dispatched through MorselScheduler
    with >= 2 probe morsels must be BIT-IDENTICAL to serial run_query
    across the full ThreadPlacement x {FIRST_TOUCH, INTERLEAVE} grid
    (policy set with mesh=None: the lowering stays local, which is
    exactly the serving tier's configuration), with sane per-pool
    executed/steal counters; JoinIndexPool must materialize the build
    side ONCE per pool — never per morsel; planner.probe_split must
    DECLINE (never degrade) kernel joins, sub-threshold probes,
    distributed plans, and join-free pipelines.
  * **Distributed TopK** — the candidates lowering (local top-k per
    shard, gather k*n candidate rows) must be bit-identical to the
    replicated lowering, move <= k x n_shards rows per shard on the wire
    (telemetry-observed), and be the cost model's pick where
    k*n << G*(n-1)/n; priced in explain as a DistTopK decision.
  * **Selectivity-fed sizing** (satellite) — telemetry.refresh_profile's
    observed filter_selectivity must flow into Compact capacity and the
    agg push-down crossover on the next lowering.
"""
import dataclasses

import numpy as np
import pytest

from conftest import run_with_devices

from repro.analytics import plan as L
from repro.analytics import planner, telemetry
import repro.analytics.physical as PH
from repro.analytics.planner import ExecutionContext
from repro.analytics.service.scheduler import MorselScheduler, ThreadPlacement
from repro.analytics.tpch import LOGICAL_QUERIES, generate, run_query
from repro.core.config import PlacementPolicy

SPLIT_QUERIES = ("q3", "q5", "q18")


@pytest.fixture(scope="module")
def data():
    return generate(scale=0.004, seed=1)


@pytest.fixture(scope="module")
def tables(data):
    return data.as_jax()


@pytest.fixture(autouse=True)
def _restore_profile():
    yield
    planner.set_cost_profile(None)


# ---------------------------------------------------------------------------
# split-probe parity: ThreadPlacement x PlacementPolicy, bit-identical
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", [PlacementPolicy.FIRST_TOUCH,
                                    PlacementPolicy.INTERLEAVE])
@pytest.mark.parametrize("placement", list(ThreadPlacement))
def test_split_probe_bit_identical(data, tables, placement, policy):
    ctx = ExecutionContext(executor="cost", policy=policy)
    for name in SPLIT_QUERIES:
        ref = run_query(name, data, context=ctx)
        with MorselScheduler(n_pools=2, workers_per_pool=2,
                             morsel_rows=1000, placement=placement) as sched:
            task = sched.build_task(LOGICAL_QUERIES[name], tables, ctx)
            # the probe ACTUALLY split: >= 2 morsels dispatched, no
            # whole-plan CompiledPlan fallback
            assert task.split and task.compiled is None, name
            assert len(task.morsels) >= 2, name
            got = sched.submit(task).wait()
            st = sched.stats()
        assert st.morsels_dispatched == len(task.morsels)
        assert sum(st.executed_per_pool) == st.morsels_dispatched
        # steal counters sane under split-probe tasks: a pool can only
        # steal work that was dispatched, and every steal is counted on
        # exactly one pool
        assert 0 <= st.steals <= st.morsels_dispatched
        assert set(got) == set(ref), name
        for k in ref:
            np.testing.assert_array_equal(
                np.asarray(got[k]), np.asarray(ref[k]),
                err_msg=f"{name}/{placement.value}/{policy.name}/{k}")


def test_build_side_replicated_once_per_pool(tables):
    """The join build index is materialized once per POOL, never per
    morsel: q3 dispatches ~24 probe morsels over 2 pools but the pool
    grows by exactly one base build + one replica per pool, and a second
    round of the same task adds none."""
    pool = planner.join_index_pool()
    pool.clear()
    planner.clear_plan_cache()
    ctx = ExecutionContext(executor="cost")
    with MorselScheduler(n_pools=2, workers_per_pool=2,
                         morsel_rows=1000,
                         placement=ThreadPlacement.SPARSE) as sched:
        task = sched.build_task(LOGICAL_QUERIES["q3"], tables, ctx)
        assert len(task.morsels) >= 2 * 2     # plenty of morsels per pool
        sched.submit(task).wait()
        replicas_after_first = pool.replicas
        builds_after_first = pool.builds
        # q3 has ONE on-path split join (orders.o_orderkey): one replica
        # per pool, regardless of morsel count
        assert replicas_after_first == 2
        sched.submit(sched.build_task(LOGICAL_QUERIES["q3"], tables,
                                      ctx)).wait()
    assert pool.replicas == replicas_after_first      # LRU hits only
    assert pool.builds == builds_after_first


def test_replica_values_match_base(tables):
    pool = planner.join_index_pool()
    pool.clear()
    arr = tables["orders"]["o_orderkey"]
    base = pool.get("orders", "o_orderkey", arr)
    r0 = pool.replica("orders", "o_orderkey", arr, 0)
    r1 = pool.replica("orders", "o_orderkey", arr, 1)
    assert pool.replicas == 2
    for rep in (r0, r1):
        np.testing.assert_array_equal(np.asarray(rep[0]),
                                      np.asarray(base[0]))
        np.testing.assert_array_equal(np.asarray(rep[1]),
                                      np.asarray(base[1]))
    # distinct buffers per pool (the point of replication), same values
    assert r0[0] is not r1[0] and r0[0] is not base[0]
    # repeat fetch is a cache hit, not a new replica
    pool.replica("orders", "o_orderkey", arr, 0)
    assert pool.replicas == 2


# ---------------------------------------------------------------------------
# probe_split declines rather than degrades
# ---------------------------------------------------------------------------
def _lower(name, tables, ctx, n_shards=None, profile=None):
    rows = {t: next(iter(c.values())).shape[0] for t, c in tables.items()}
    return planner.lower(LOGICAL_QUERIES[name], ctx, rows,
                         profile or planner.current_cost_profile(),
                         n_shards=n_shards)


def test_probe_split_declines(tables):
    base = planner.current_cost_profile()
    ctx = ExecutionContext(executor="cost")
    # splittable at the default threshold
    assert planner.probe_split(_lower("q3", tables, ctx)) is not None
    # a distributed plan is never split by the serving scheduler
    assert planner.probe_split(
        _lower("q3", tables, ctx, n_shards=4)) is None
    # sub-threshold probes: the cost model declines the mark
    big = dataclasses.replace(base, morsel_split_rows=1 << 30)
    phys = _lower("q3", tables, ctx, profile=big)
    assert not any(n.morsel_split for n in PH.walk_unique(phys.root)
                   if isinstance(n, PH.PJoin))
    assert planner.probe_split(phys) is None
    # kernel-strategy joins change overflow semantics under slicing
    assert planner.probe_split(
        _lower("q3", tables, ExecutionContext(executor="cost",
                                              join="kernel"))) is None
    # join-free pipelines have no probe to parallelize
    assert planner.probe_split(_lower("q1", tables, ctx)) is None


def test_split_marks_in_physical_plan(tables):
    phys = _lower("q5", tables, ExecutionContext(executor="cost"))
    marked = [n for n in PH.walk_unique(phys.root)
              if isinstance(n, PH.PJoin) and n.morsel_split]
    assert len(marked) == 3        # both probe-chain joins + the big
    split = planner.probe_split(phys)   # build-side orders join
    assert split is not None
    assert split.scan.table == "lineitem"
    assert [p.index for p in split.preludes if p.index is not None] == \
        [("supplier", "s_suppkey"), ("orders", "o_orderkey")]
    assert "morsel_split" in PH.describe(phys)


# ---------------------------------------------------------------------------
# distributed TopK: cost model + parity + wire accounting
# ---------------------------------------------------------------------------
def test_topk_cost_model():
    costs = planner.topk_costs(6000, 10, 4)
    assert costs == {"replicated": 6000 * 3 / 4, "candidates": 40.0}
    ctx = ExecutionContext()
    assert planner.choose_dist_topk(6000, 10, 4, ctx) == "candidates"
    # tiny group table: replicating it is cheaper than k*n candidates
    assert planner.choose_dist_topk(100, 40, 4, ctx) == "replicated"
    # single shard: nothing to distribute
    assert planner.choose_dist_topk(6000, 10, 1, ctx) == "replicated"
    # forced either way wins over cost
    for mode in ("replicated", "candidates"):
        forced = ExecutionContext(dist_topk=mode)
        assert planner.choose_dist_topk(6000, 10, 4, forced) == mode


def test_dist_topk_lowering_shape(tables):
    ctx = ExecutionContext(executor="cost",
                           policy=PlacementPolicy.INTERLEAVE,
                           dist_join="partitioned")
    phys = _lower("q3", tables, ctx, n_shards=4)
    topk = phys.root
    assert isinstance(topk, PH.PTopK) and topk.dist == "candidates"
    ex = topk.child
    assert isinstance(ex, PH.Exchange) and ex.kind == "gather"
    # <= k x n_shards rows on the wire, and rows/est sized to candidates
    assert ex.moved_rows == topk.k * 3 <= topk.k * 4
    assert ex.rows == ex.est == topk.k * 4
    assert topk.rows == topk.k
    # forcing replicated removes the movement node entirely
    rep = _lower("q3", tables,
                 dataclasses.replace(ctx, dist_topk="replicated"),
                 n_shards=4)
    assert rep.root.dist == "replicated"
    assert isinstance(rep.root.child, PH.PAggregate)
    # local plans carry no dist marker at all
    local = _lower("q3", tables, ExecutionContext(executor="cost"))
    assert local.root.dist is None


TOPK_DIST_TEST = """
import dataclasses
import numpy as np, jax
from repro.analytics import planner, telemetry
import repro.analytics.physical as PH
from repro.analytics.planner import ExecutionContext
from repro.analytics.tpch import LOGICAL_QUERIES, generate, run_query
from repro.core.config import PlacementPolicy

mesh = jax.make_mesh((4,), ("data",))
data = generate(scale=0.004, seed=1)
tables = data.as_jax()
plan = LOGICAL_QUERIES["q3"]
base = ExecutionContext(executor="cost", mesh=mesh,
                        policy=PlacementPolicy.INTERLEAVE,
                        capacity_factor=4.0)
ref = run_query("q3", data,
                context=dataclasses.replace(base, dist_topk="replicated"))
cand_ctx = dataclasses.replace(base, dist_topk="candidates")
for tag, ctx in (("candidates", cand_ctx), ("cost", base)):
    got = run_query("q3", data, context=ctx)
    assert set(got) == set(ref), tag
    for k in ref:
        assert np.array_equal(np.asarray(got[k]), np.asarray(ref[k])), \\
            (tag, k)
# explain prices both alternatives and records the pick
dec = [d for d in planner.explain(plan, tables, base)
       if d.node == "DistTopK"]
assert len(dec) == 1 and dec[0].choice == "candidates", dec
costs = dict(dec[0].costs)
assert costs["candidates"] == 40.0 and costs["replicated"] == 6000 * 3 / 4
# telemetry: the candidates gather moves k*(n-1) rows per shard
# (<= k * n_shards) and its observed counters match the estimates exactly
telemetry.registry().clear()
with telemetry.recording() as reg:
    cp = planner.compile_plan(plan, tables, cand_ctx)
    out = cp(tables)
for k in ref:
    assert np.array_equal(np.asarray(out[k]), np.asarray(ref[k])), k
ps = reg.get(cp.cache_key)
nodes = ps.node_list()
topk = [n for n in nodes if isinstance(n, PH.PTopK)][0]
assert topk.dist == "candidates"
ex = topk.child
assert isinstance(ex, PH.Exchange) and ex.kind == "gather"
assert ex.moved_rows == topk.k * 3 <= topk.k * 4
ns = [s for i, s in ps.nodes.items() if nodes[i] is ex][0]
assert ns.last["alive_in"] == topk.k * 4, ns.last
assert ns.last["moved"] == topk.k * 3 * 4, ns.last
print("TOPK_DIST_OK")
"""


def test_dist_topk_parity_and_wire_accounting():
    out = run_with_devices(TOPK_DIST_TEST, n_devices=4, timeout=900)
    assert "TOPK_DIST_OK" in out


# ---------------------------------------------------------------------------
# satellite: telemetry-refreshed selectivity -> Compact + push-down sizing
# ---------------------------------------------------------------------------
def _selective_plan():
    rng = np.random.RandomState(3)
    import jax.numpy as jnp
    n, d = 4096, 256
    tables = {
        "fact": {"fk": jnp.asarray(rng.randint(0, d, n).astype(np.int32)),
                 "gk": jnp.asarray(rng.randint(0, 64, n).astype(np.int32)),
                 "fv": jnp.asarray(rng.rand(n).astype(np.float32))},
        "dim": {"pk": jnp.asarray(np.arange(d, dtype=np.int32)),
                "dv": jnp.asarray(rng.rand(d).astype(np.float32))},
    }
    # the filter sits on a TAKEN column, so the partitioned lowering keeps
    # it ABOVE the join; the aggregate groups by gk != the join key fk, so
    # route-once cannot elide the re-route — maybe_compact must budget the
    # filtered buffer, discounting by selectivity ** filters_below
    plan = L.LogicalPlan(
        L.scan("fact").join(L.scan("dim"), "fk", "pk", {"_dv": "dv"})
        .filter(L.col("_dv") < 0.05)
        .aggregate("gk", 64, c=("count", "fv"), s=("sum", "fv")), None)
    return plan, tables


def _compact_caps(phys):
    return sorted(n.capacity for n in PH.walk_unique(phys.root)
                  if isinstance(n, PH.Compact))


def test_refresh_profile_resizes_compact():
    """Round trip: a recorded execution observes a ~0.05-selective filter,
    refresh_profile folds it into filter_selectivity, and the NEXT
    lowering shrinks the Compact budget over the filtered buffer."""
    plan, tables = _selective_plan()
    rows = {t: next(iter(c.values())).shape[0] for t, c in tables.items()}
    ctx = ExecutionContext(executor="cost",
                           policy=PlacementPolicy.INTERLEAVE,
                           dist_join="partitioned", agg_pushdown=False)
    base = planner.current_cost_profile()
    telemetry.registry().clear()
    with telemetry.recording():
        planner.compile_plan(plan, tables,
                             ExecutionContext(executor="xla"))(tables)
    refreshed = telemetry.refresh_profile(base)
    assert refreshed is not base
    assert refreshed.filter_selectivity < base.filter_selectivity
    before = _compact_caps(planner.lower(plan, ctx, rows, base,
                                         n_shards=4))
    after = _compact_caps(planner.lower(plan, ctx, rows, refreshed,
                                        n_shards=4))
    assert before and after and sum(after) < sum(before), (before, after)


def test_selectivity_never_shrinks_compact_below_est():
    """The clamp: even selectivity ~0 keeps the budget >= 1.0 x est, so a
    bad prior can waste headroom but never surface phantom overflow."""
    child = PH.PFilter(PH.PScan("t", rows=1000, est=1000),
                       pred=None, rows=1000, est=600)
    tight = PH.maybe_compact(child, 1.5, True, selectivity=1e-6)
    assert isinstance(tight, PH.Compact)
    assert tight.capacity >= child.est


def test_selectivity_moves_pushdown_crossover():
    """agg_pushdown=None (cost mode) prices the crossover on the
    selectivity-discounted ALIVE estimate: with G just above the
    physical rows, a selective prior flips push-down off."""
    rng = np.random.RandomState(5)
    import jax.numpy as jnp
    n, d = 512, 700                 # G > n * 0.75: pushdown only wins
    tables = {                      # when filters discount the input
        "fact": {"fk": jnp.asarray(rng.randint(0, d, n).astype(np.int32)),
                 "fv": jnp.asarray(rng.rand(n).astype(np.float32))},
    }
    rows = {"fact": n}
    plan = L.LogicalPlan(
        L.scan("fact").filter(L.col("fv") < 0.5).filter(L.col("fv") > 0.1)
        .aggregate("fk", d, c=("count", "fv")), None)
    ctx = ExecutionContext(executor="cost",
                           policy=PlacementPolicy.INTERLEAVE)
    base = planner.current_cost_profile()
    neutral = dataclasses.replace(base, filter_selectivity=1.0)

    def merges(profile):
        phys = planner.lower(plan, ctx, rows, profile, n_shards=4)
        return [node.merge for node in PH.walk_unique(phys.root)
                if isinstance(node, PH.PAggregate)]

    # sel=1.0: alive est == 512 rows < 700 groups -> no push-down
    assert "pushdown" not in merges(neutral)
    # default sel=0.75 over TWO stacked filters: alive ~288 < 700 still
    # no push-down; a drifted-selective profile keeps it off too, while
    # a single-filter-free shape (G small) is unaffected — flip G below
    # the alive est to see push-down return
    small_g = L.LogicalPlan(
        L.scan("fact").filter(L.col("fv") < 0.5).filter(L.col("fv") > 0.1)
        .aggregate("fk", 64, c=("count", "fv")), None)
    phys = planner.lower(small_g, ctx, rows, neutral, n_shards=4)
    assert "pushdown" in [node.merge
                          for node in PH.walk_unique(phys.root)
                          if isinstance(node, PH.PAggregate)]
