"""Memory-allocator microbenchmark (paper Section 3.1.8 / Figure 2).

Simulates the paper's allocation storm: ``n_streams`` concurrent allocation
streams interleaved round-robin, each performing ``ops_per_stream``
operations — allocate-and-write or read-and-free — with allocation sizes
drawn inversely proportional to the size class (small allocations dominate,
as in the paper). Metrics: wall-clock throughput (Fig 2a), contention
events (the scalability discriminator), memory overhead ratio (Fig 2b).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Dict, List

import numpy as np

from repro.core.config import AllocatorKind
from repro.memory.allocators import Allocator, make_allocator


@dataclass(frozen=True)
class MicrobenchResult:
    kind: str
    n_streams: int
    ops: int
    seconds: float
    ops_per_sec: float
    contentions: int
    contention_rate: float
    overhead_ratio: float
    failed: int


def _size_sampler(rng: np.ndarray, n: int) -> np.ndarray:
    """Sizes in 64B..64KB with P(class) ∝ 1/size (paper's distribution);
    within a class, sizes are continuous so size-class rounding shows up
    as real memory overhead (paper Fig 2b)."""
    classes = 64 << np.arange(11)            # 64B .. 64KB
    weights = 1.0 / classes
    weights = weights / weights.sum()
    base = rng.choice(classes, size=n, p=weights)
    frac = rng.uniform(0.55, 1.0, size=n)
    return np.maximum((base * frac).astype(np.int64), 1)


def run_microbench(kind: AllocatorKind, *, n_streams: int = 8,
                   ops_per_stream: int = 5_000, capacity: int = 1 << 30,
                   granule: int = 64, seed: int = 0,
                   live_target: int = 64) -> MicrobenchResult:
    rng = np.random.RandomState(seed)
    alloc = make_allocator(kind, capacity=capacity, granule=granule)
    sizes = _size_sampler(rng, n_streams * ops_per_stream)
    live: List[List] = [[] for _ in range(n_streams)]
    total_ops = 0
    si = 0
    t0 = time.perf_counter()
    for i in range(ops_per_stream):
        for s in range(n_streams):
            # paper mix: alloc+write until a live target, then read+free
            if len(live[s]) >= live_target or (live[s] and rng.rand() < 0.45):
                blk = live[s].pop(rng.randint(len(live[s])))
                alloc.free(blk, stream=s)
            else:
                blk = alloc.alloc(int(sizes[si]), stream=s)
                si += 1
                if blk is not None:
                    live[s].append(blk)
            total_ops += 1
    dt = time.perf_counter() - t0
    st = alloc.stats
    return MicrobenchResult(
        kind=kind.value, n_streams=n_streams, ops=total_ops, seconds=dt,
        ops_per_sec=total_ops / dt,
        contentions=st.contentions,
        contention_rate=st.contentions / max(total_ops, 1),
        overhead_ratio=st.overhead_ratio,
        failed=st.failed)


def sweep(n_streams_list=(1, 2, 4, 8, 16, 32), **kw) -> List[MicrobenchResult]:
    out = []
    for kind in AllocatorKind:
        for n in n_streams_list:
            out.append(run_microbench(kind, n_streams=n, **kw))
    return out
