"""Paged KV-cache manager: serving state allocated through the paper's
allocators, with the THP analogue (page size) as a first-class knob.

Pages hold ``page_tokens`` tokens of K/V for every layer (vLLM-style block
table). Small pages (16 tokens ~ "4KB") minimize internal fragmentation on
short/ragged sequences but multiply allocator traffic and page-table
entries; large pages (512 tokens ~ "2MB" hugepages) invert the tradeoff —
exactly the paper's Section 3.4.1 tension, measurable here as
(fragmentation ratio, allocator ops, page-table length).

Device-side layout per layer: (n_pages, page_tokens, kv_heads, head_dim);
``gather_sequence`` materializes a contiguous view through the page table
(the serve loop's attention input).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import AllocatorKind
from repro.memory.allocators import Allocator, make_allocator


@dataclass
class SequenceState:
    seq_id: int
    length: int = 0
    pages: List[int] = field(default_factory=list)
    blocks: List[object] = field(default_factory=list)


class PagedKVManager:
    """Host-side page-table manager. Page ids index the device pool."""

    def __init__(self, n_pages: int, page_tokens: int, page_bytes: int,
                 allocator: AllocatorKind = AllocatorKind.SLAB):
        self.n_pages = n_pages
        self.page_tokens = page_tokens
        # pages must be allocator-granule aligned: power-of-two, >= 4 KiB —
        # size-class rounding then never splits or straddles a page
        pb = max(page_bytes, 4096)
        self.page_bytes = 1 << (pb - 1).bit_length()
        kw = {}
        if allocator == AllocatorKind.SLAB:
            # page pools are small relative to slab refill batches; a large
            # batch lets per-stream caches hoard the pool (the paper's
            # tbbmalloc memory-consumption tradeoff) — keep refills small
            kw["batch"] = 2
        self.alloc = make_allocator(allocator,
                                    capacity=n_pages * self.page_bytes,
                                    granule=self.page_bytes, **kw)
        self.sequences: Dict[int, SequenceState] = {}
        self._failed_appends = 0

    # ------------------------------------------------------------------
    def add_sequence(self, seq_id: int) -> SequenceState:
        st = SequenceState(seq_id)
        self.sequences[seq_id] = st
        return st

    def append_tokens(self, seq_id: int, n: int, stream: int = 0) -> bool:
        """Reserve room for ``n`` new tokens; allocates pages on demand."""
        st = self.sequences[seq_id]
        needed_pages = -(-(st.length + n) // self.page_tokens)
        while len(st.pages) < needed_pages:
            blk = self.alloc.alloc(self.page_bytes, stream=stream)
            if blk is None:
                self._failed_appends += 1
                return False
            page_id = blk.offset // self.page_bytes
            st.pages.append(page_id)
            st.blocks.append(blk)
        st.length += n
        return True

    def release_sequence(self, seq_id: int, stream: int = 0) -> None:
        st = self.sequences.pop(seq_id)
        for blk in st.blocks:
            self.alloc.free(blk, stream=stream)

    # ------------------------------------------------------------------
    def page_table(self, seq_id: int, max_pages: int) -> np.ndarray:
        st = self.sequences[seq_id]
        table = np.full((max_pages,), -1, np.int32)
        table[:len(st.pages)] = st.pages[:max_pages]
        return table

    def fragmentation_ratio(self) -> float:
        """Reserved tokens / live tokens (paper Fig 2b analogue)."""
        live = sum(st.length for st in self.sequences.values())
        reserved = sum(len(st.pages) for st in self.sequences.values()) \
            * self.page_tokens
        return reserved / max(live, 1)

    @property
    def allocator_stats(self):
        return self.alloc.stats


def gather_sequence(pool: jax.Array, page_table: jax.Array,
                    length: jax.Array) -> jax.Array:
    """Materialize a contiguous (max_tokens, ...) KV view via the page table.

    pool: (n_pages, page_tokens, ...); page_table: (max_pages,) int32.
    Entries past ``length`` are zeroed.
    """
    pages = jnp.clip(page_table, 0, pool.shape[0] - 1)
    gathered = pool[pages]                       # (max_pages, page_tokens, ...)
    flat = gathered.reshape((-1,) + pool.shape[2:])
    pos = jnp.arange(flat.shape[0])
    mask = (pos < length).reshape((-1,) + (1,) * (flat.ndim - 1))
    return jnp.where(mask, flat, 0)
