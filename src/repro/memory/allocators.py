"""Device-arena allocators: the paper's allocator taxonomy on TPU HBM.

XLA owns physical HBM, but a serving/analytics runtime still performs
*logical* allocation constantly: KV-cache pages, hash-table buffers,
partition scratch. These managers implement the paper's allocator designs
(Section 3.1) over a byte arena, with the same mechanics that decide their
scalability on NUMA hosts:

  BumpAllocator   ptmalloc analogue — one global region, one lock, a single
                  first-fit free list. Every operation serializes.
  ArenaAllocator  jemalloc analogue — streams assigned to arenas round-robin;
                  per-arena locks; memory never migrates between arenas
                  (the documented jemalloc limitation).
  SlabAllocator   tbbmalloc/tcmalloc analogue — size-class slabs, per-stream
                  caches (lock-free fast path), batched refill from a central
                  store (lock only on refill/flush).
  HoardAllocator  Hoard analogue — per-stream heaps + a global heap; blocks
                  overflow to the global heap when a stream's free ratio
                  crosses the emptiness threshold.

Concurrency model: callers pass a ``stream`` id (the per-shard / per-request
analogue of a thread). Lock contention is *modeled deterministically*: a
lock acquisition whose previous holder was a different stream counts one
contention event (cache-line transfer analogue). The microbenchmark reports
wall-clock ops/s (real bookkeeping costs differ per design), contention
events, and the paper's memory-overhead ratio (reserved / requested).
"""
from __future__ import annotations

import abc
import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.config import AllocatorKind


@dataclass
class Block:
    offset: int
    size: int            # rounded (reserved) size
    requested: int       # caller-requested size
    stream: int = 0


@dataclass
class AllocStats:
    allocs: int = 0
    frees: int = 0
    failed: int = 0
    contentions: int = 0
    lock_acquisitions: int = 0
    bytes_requested: int = 0
    bytes_reserved: int = 0
    peak_reserved: int = 0
    live_reserved: int = 0

    def note_alloc(self, requested: int, reserved: int):
        self.allocs += 1
        self.bytes_requested += requested
        self.bytes_reserved += reserved
        self.live_reserved += reserved
        self.peak_reserved = max(self.peak_reserved, self.live_reserved)

    def note_free(self, reserved: int):
        self.frees += 1
        self.live_reserved -= reserved

    @property
    def overhead_ratio(self) -> float:
        if self.bytes_requested == 0:
            return 1.0
        return self.bytes_reserved / self.bytes_requested


class _Lock:
    """Deterministic contention-counting lock."""

    __slots__ = ("holder",)

    def __init__(self):
        self.holder: Optional[int] = None

    def acquire(self, stream: int, stats: AllocStats):
        stats.lock_acquisitions += 1
        if self.holder is not None and self.holder != stream:
            stats.contentions += 1
        self.holder = stream


def _round_up(n: int, granule: int) -> int:
    return -(-n // granule) * granule


_SIZE_CLASSES = [64 << i for i in range(20)]  # 64B .. 32MB


def size_class(n: int) -> int:
    for c in _SIZE_CLASSES:
        if n <= c:
            return c
    return _round_up(n, _SIZE_CLASSES[-1])


class Allocator(abc.ABC):
    kind: AllocatorKind

    def __init__(self, capacity: int, granule: int = 4096):
        self.capacity = capacity
        self.granule = granule
        self.stats = AllocStats()

    @abc.abstractmethod
    def alloc(self, size: int, stream: int = 0) -> Optional[Block]:
        ...

    @abc.abstractmethod
    def free(self, block: Block, stream: int = 0) -> None:
        ...


# ---------------------------------------------------------------------------
class BumpAllocator(Allocator):
    """One lock, one free list, first-fit with top-of-arena bump fallback."""

    kind = AllocatorKind.BUMP

    def __init__(self, capacity: int, granule: int = 4096):
        super().__init__(capacity, granule)
        self._lock = _Lock()
        self._top = 0
        self._free: List[Tuple[int, int]] = []   # (offset, size)

    def alloc(self, size: int, stream: int = 0) -> Optional[Block]:
        self._lock.acquire(stream, self.stats)
        reserved = _round_up(size, self.granule)
        for i, (off, sz) in enumerate(self._free):    # first fit (O(n) walk)
            if sz >= reserved:
                rest = sz - reserved
                if rest:
                    self._free[i] = (off + reserved, rest)
                else:
                    self._free.pop(i)
                self.stats.note_alloc(size, reserved)
                return Block(off, reserved, size, stream)
        if self._top + reserved > self.capacity:
            self.stats.failed += 1
            return None
        off = self._top
        self._top += reserved
        self.stats.note_alloc(size, reserved)
        return Block(off, reserved, size, stream)

    def free(self, block: Block, stream: int = 0) -> None:
        self._lock.acquire(stream, self.stats)
        self._free.append((block.offset, block.size))
        self.stats.note_free(block.size)


# ---------------------------------------------------------------------------
class ArenaAllocator(Allocator):
    """Round-robin arenas, per-arena locks + size-class free lists."""

    kind = AllocatorKind.ARENA

    def __init__(self, capacity: int, granule: int = 4096, n_arenas: int = 8):
        super().__init__(capacity, granule)
        self.n_arenas = n_arenas
        per = capacity // n_arenas
        self._locks = [_Lock() for _ in range(n_arenas)]
        self._tops = [i * per for i in range(n_arenas)]
        self._limits = [(i + 1) * per for i in range(n_arenas)]
        self._free: List[Dict[int, List[int]]] = [dict() for _ in range(n_arenas)]
        self._assignment: Dict[int, int] = {}
        self._next = 0

    def _arena_of(self, stream: int) -> int:
        if stream not in self._assignment:
            self._assignment[stream] = self._next % self.n_arenas
            self._next += 1
        return self._assignment[stream]

    def alloc(self, size: int, stream: int = 0) -> Optional[Block]:
        a = self._arena_of(stream)
        self._locks[a].acquire(stream, self.stats)
        cls = size_class(max(size, self.granule))
        lst = self._free[a].get(cls)
        if lst:
            off = lst.pop()
            self.stats.note_alloc(size, cls)
            return Block(off, cls, size, stream)
        if self._tops[a] + cls > self._limits[a]:
            self.stats.failed += 1
            return None
        off = self._tops[a]
        self._tops[a] += cls
        self.stats.note_alloc(size, cls)
        return Block(off, cls, size, stream)

    def free(self, block: Block, stream: int = 0) -> None:
        # memory never moves between arenas: freed into the OWNER's arena
        a = self._arena_of(block.stream)
        self._locks[a].acquire(stream, self.stats)
        self._free[a].setdefault(block.size, []).append(block.offset)
        self.stats.note_free(block.size)


# ---------------------------------------------------------------------------
class SlabAllocator(Allocator):
    """Size-class slabs + per-stream caches; central store refills in
    batches of ``batch`` blocks (the tcmalloc/tbbmalloc fast path)."""

    kind = AllocatorKind.SLAB

    def __init__(self, capacity: int, granule: int = 4096, batch: int = 16):
        super().__init__(capacity, granule)
        self.batch = batch
        self._central_lock = _Lock()
        self._top = 0
        self._central: Dict[int, List[int]] = {}
        self._caches: Dict[int, Dict[int, List[int]]] = {}

    def _cache(self, stream: int) -> Dict[int, List[int]]:
        return self._caches.setdefault(stream, {})

    def alloc(self, size: int, stream: int = 0) -> Optional[Block]:
        cls = size_class(max(size, self.granule))
        cache = self._cache(stream).setdefault(cls, [])
        if not cache:                                  # refill (locked)
            self._central_lock.acquire(stream, self.stats)
            central = self._central.setdefault(cls, [])
            take = min(self.batch, len(central))
            cache.extend(central[-take:])
            del central[len(central) - take:]
            while len(cache) < self.batch:
                if self._top + cls > self.capacity:
                    break
                cache.append(self._top)
                self._top += cls
        if not cache:
            self.stats.failed += 1
            return None
        off = cache.pop()
        self.stats.note_alloc(size, cls)
        return Block(off, cls, size, stream)

    def free(self, block: Block, stream: int = 0) -> None:
        cache = self._cache(stream).setdefault(block.size, [])
        cache.append(block.offset)                     # lock-free fast path
        self.stats.note_free(block.size)
        if len(cache) > 2 * self.batch:                # flush half (locked)
            self._central_lock.acquire(stream, self.stats)
            half = len(cache) // 2
            self._central.setdefault(block.size, []).extend(cache[:half])
            del cache[:half]


# ---------------------------------------------------------------------------
class HoardAllocator(Allocator):
    """Per-stream heaps with an emptiness threshold that returns surplus
    free blocks to a global heap (bounds blowup, costs a global lock)."""

    kind = AllocatorKind.HOARD

    def __init__(self, capacity: int, granule: int = 4096,
                 empty_fraction: float = 0.5):
        super().__init__(capacity, granule)
        self.empty_fraction = empty_fraction
        self._global_lock = _Lock()
        self._global: Dict[int, List[int]] = {}
        self._top = 0
        self._heaps: Dict[int, Dict[int, List[int]]] = {}
        self._live: Dict[int, int] = {}
        self._cached: Dict[int, int] = {}

    def _heap(self, stream: int) -> Dict[int, List[int]]:
        return self._heaps.setdefault(stream, {})

    def alloc(self, size: int, stream: int = 0) -> Optional[Block]:
        cls = size_class(max(size, self.granule))
        heap = self._heap(stream).setdefault(cls, [])
        if not heap:
            self._global_lock.acquire(stream, self.stats)
            glob = self._global.setdefault(cls, [])
            if glob:
                heap.append(glob.pop())
            elif self._top + cls <= self.capacity:
                heap.append(self._top)
                self._top += cls
        if not heap:
            self.stats.failed += 1
            return None
        off = heap.pop()
        self._cached[stream] = self._cached.get(stream, 0) - cls
        self._live[stream] = self._live.get(stream, 0) + cls
        self.stats.note_alloc(size, cls)
        return Block(off, cls, size, stream)

    def free(self, block: Block, stream: int = 0) -> None:
        heap = self._heap(stream).setdefault(block.size, [])
        heap.append(block.offset)
        self._live[stream] = self._live.get(stream, 0) - block.size
        self._cached[stream] = self._cached.get(stream, 0) + block.size
        self.stats.note_free(block.size)
        live = max(self._live.get(stream, 0), 0)
        cached = self._cached.get(stream, 0)
        if cached > self.granule * 8 and cached > self.empty_fraction * (live + cached):
            self._global_lock.acquire(stream, self.stats)   # return surplus
            self._global.setdefault(block.size, []).append(heap.pop())
            self._cached[stream] -= block.size


ALLOCATORS = {
    AllocatorKind.BUMP: BumpAllocator,
    AllocatorKind.ARENA: ArenaAllocator,
    AllocatorKind.SLAB: SlabAllocator,
    AllocatorKind.HOARD: HoardAllocator,
}


def make_allocator(kind: AllocatorKind, capacity: int,
                   granule: int = 4096, **kw) -> Allocator:
    return ALLOCATORS[kind](capacity, granule, **kw)
