"""Device-arena memory management: allocators, paged KV cache, microbench."""
from repro.memory.allocators import (Allocator, AllocStats, Block,
                                     make_allocator)
from repro.memory.microbench import MicrobenchResult, run_microbench, sweep
from repro.memory.paged_kv import PagedKVManager, gather_sequence
