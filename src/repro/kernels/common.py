"""Kernel dispatch: TPU Pallas kernel | interpret mode | pure-jnp reference.

The container is CPU-only, so the policy is:
  * mode="auto":      Pallas on a TPU backend, reference everywhere else
                      (the dry-run lowers the reference path — its chunked
                      formulations are shaped to match the kernels' working
                      sets so memory analysis stays honest).
  * mode="interpret": run the actual kernel body in the Pallas interpreter
                      (used by the kernel test suites on CPU).
  * mode="ref":       force the pure-jnp oracle.
  * mode="pallas":    force compiled Pallas (TPU only).

Set globally via env REPRO_KERNEL_MODE or per-call with the ``mode`` kwarg.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

_VALID = ("auto", "pallas", "interpret", "ref")


def kernel_mode(mode: Optional[str] = None) -> str:
    mode = mode or os.environ.get("REPRO_KERNEL_MODE", "auto")
    if mode not in _VALID:
        raise ValueError(f"kernel mode {mode!r} not in {_VALID}")
    if mode == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return mode


def pick_block(size: int, preferred: int, minimum: int = 8) -> int:
    """Largest divisor-block <= preferred for a dimension of ``size``."""
    b = min(preferred, size)
    while size % b and b > minimum:
        b -= 1
    return max(b, 1) if size % max(b, 1) == 0 else size
