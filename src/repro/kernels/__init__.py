"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package ships three layers:
  kernel.py  pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py     jit'd public op with mode dispatch (pallas | interpret | ref)
  ref.py     pure-jnp oracle (test ground truth + CPU lowering path)

Kernels:
  flash_attention  train/prefill attention (GQA + causal + local window)
  rglru_scan       RG-LRU linear recurrence (chunked Hillis-Steele)
  rwkv6_scan       RWKV6 WKV recurrence (VMEM-resident per-head state)
  radix_partition  radix histogram pass (analytics W1-W4 partitioner)
  hash_aggregate   partitioned distributive aggregation (W2 hot loop)
  join_probe       partition-wise broadcast-compare probe (W3/W4 hot loop)
"""
from repro.kernels.flash_attention import decode_attention, flash_attention
from repro.kernels.hash_aggregate import hash_aggregate, hash_aggregate_multi
from repro.kernels.join_probe import join_probe
from repro.kernels.radix_partition import block_histograms, radix_partition
from repro.kernels.rglru_scan import linear_scan
from repro.kernels.rwkv6_scan import wkv6, wkv6_step
