from repro.kernels.flash_attention.ops import decode_attention, flash_attention
