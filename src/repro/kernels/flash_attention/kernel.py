"""Pallas TPU flash-attention forward kernel.

TPU-native adaptation notes (vs the CUDA flash-attention formulation):
  * tiling is chosen for VMEM (not shared memory/warps): one (block_q, D)
    query tile and one (block_k, D) K/V tile resident per step, fp32
    accumulators in VMEM scratch — working set ~ (bq + 2*bk) * D * 2B
    + bq * D * 4B, sized to sit well under ~16 MB VMEM.
  * matmul dims aligned to the 128x128 MXU: D is a lane multiple for every
    assigned arch (64..256); block_q/block_k default to 512.
  * the softmax running max/denominator live in VMEM scratch carried across
    the innermost grid dimension (kv blocks) — the Pallas revisiting-output
    pattern — instead of CUDA's per-warp registers.
  * causal + local-window blocks that are fully masked are skipped with
    pl.when (block-level early-out, the TPU version of CUDA block skipping).

Grid: (batch * kv_heads * group, num_q_blocks, num_kv_blocks).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: Optional[int],
               q_offset: int, block_q: int, block_k: int, n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, m_scr.dtype)
        l_scr[...] = jnp.zeros(l_scr.shape, l_scr.dtype)
        acc_scr[...] = jnp.zeros(acc_scr.shape, acc_scr.dtype)

    q_start = q_offset + qi * block_q
    k_start = ki * block_k

    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0].astype(jnp.float32)                  # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        msk = jnp.ones((block_q, block_k), dtype=jnp.bool_)
        if causal:
            msk &= kpos <= qpos
        if window is not None:
            msk &= kpos > qpos - window
        s = jnp.where(msk, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(msk, p, 0.0)  # fully-masked rows must not add exp(0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal or window is not None:
        reachable = jnp.bool_(True)
        if causal:
            reachable &= k_start <= q_start + block_q - 1
        if window is not None:
            reachable &= k_start + block_k - 1 > q_start - window
        pl.when(reachable)(_compute)
    else:
        _compute()

    @pl.when(ki == n_kv_blocks - 1)
    def _emit():
        denom = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        q_offset: int = 0, scale: Optional[float] = None,
                        block_q: int = 512, block_k: int = 512,
                        interpret: bool = False) -> jax.Array:
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D). Returns (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} must be a multiple of Hkv={Hkv}")
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    bq = max(1, min(block_q, Sq))
    while Sq % bq:
        bq //= 2
    bk = max(1, min(block_k, Skv))
    while Skv % bk:
        bk //= 2
    nq, nk = Sq // bq, Skv // bk

    # heads-major layout; flatten (B, Hkv, G) into the leading grid dim so
    # consecutive grid rows for one kv head reuse the same streamed K/V
    qh = jnp.moveaxis(q, 2, 1).reshape(B * Hkv * G, Sq, D)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * Hkv, Skv, D)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * Hkv, Skv, D)

    grid = (B * Hkv * G, nq, nk)
    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, block_q=bq, block_k=bk, n_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda h, qi, ki, G=G: (h // G, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda h, qi, ki, G=G: (h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv * G, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return jnp.moveaxis(out.reshape(B, Hq, Sq, D), 1, 2)
