"""Jit'd public attention op with mode dispatch + custom VJP.

Forward: Pallas kernel on TPU (or interpret mode in kernel tests), chunked
online-softmax jnp elsewhere (CPU lowering / dry-run). Backward: VJP of the
chunked formulation (recompute-based, memory-bounded) — so training works on
every backend and the TPU forward kernel is drop-in.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import kernel_mode
from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.kernel import flash_attention_fwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _attention(q, k, v, causal, window, q_offset, scale, mode):
    resolved = kernel_mode(mode)
    if resolved == "pallas":
        return flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, scale=scale)
    if resolved == "interpret":
        return flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, scale=scale,
                                   interpret=True)
    return ref.attention_chunked(q, k, v, causal=causal, window=window,
                                 q_offset=q_offset, scale=scale)


def _attention_fwd(q, k, v, causal, window, q_offset, scale, mode):
    out = _attention(q, k, v, causal, window, q_offset, scale, mode)
    return out, (q, k, v)


def _attention_bwd(causal, window, q_offset, scale, mode, res, g):
    # Manual flash backward: recompute (out, lse) once, then blockwise
    # dq/dk/dv with O(block^2) transients — NO autodiff residuals. This is
    # what keeps the per-device training memory footprint flat in seq_len.
    q, k, v = res
    out, lse = ref.attention_chunked_with_lse(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        scale=scale)
    return ref.attention_chunked_bwd(
        q, k, v, out, lse, g, causal=causal, window=window,
        q_offset=q_offset, scale=scale)


_attention.defvjp(_attention_fwd, _attention_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, scale: Optional[float] = None,
                    mode: Optional[str] = None) -> jax.Array:
    """Multi-head / grouped-query attention.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0.
    """
    return _attention(q, k, v, causal, window, q_offset, scale, mode)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     cache_len: jax.Array, *, window: Optional[int] = None,
                     scale: Optional[float] = None) -> jax.Array:
    """One-token decode against a KV cache (bandwidth-bound; jnp path)."""
    return ref.decode_attention_ref(q, k, v, cache_len, window=window,
                                    scale=scale)
