"""Pure-jnp oracles for flash attention.

``attention_naive``   materializes the full score matrix — the ground truth
                      for the kernel test sweeps (small shapes only).
``attention_chunked`` exact online-softmax over KV blocks via lax.scan —
                      the memory-bounded formulation used for CPU lowering
                      and as the differentiable training path. Its working
                      set (one q block x one kv block) matches the Pallas
                      kernel's BlockSpec, so dry-run memory analysis reflects
                      the kernel the TPU would run.

Shapes: q (B, Sq, Hq, D); k, v (B, Skv, Hkv, D); Hq = G * Hkv (GQA).
``q_offset`` is the absolute position of q[0] (decode / chunked prefill).
``window`` (if set) masks keys older than ``window`` positions (local attn).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(qpos: jax.Array, kpos: jax.Array, causal: bool,
          window: Optional[int]) -> jax.Array:
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), dtype=bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= kpos[None, :] > (qpos[:, None] - window)
    return m


def attention_naive(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, scale: Optional[float] = None) -> jax.Array:
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg, kf) * scale
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    mask = _mask(qpos, kpos, causal, window)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, vf)
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: Optional[int] = None,
                      q_offset: int = 0, scale: Optional[float] = None,
                      block_q: int = 512, block_k: int = 1024) -> jax.Array:
    """Exact online-softmax attention, O(block_q * block_k) live memory."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    bq = min(block_q, Sq)
    while Sq % bq:
        bq -= 1
    bk = min(block_k, Skv)
    while Skv % bk:
        bk -= 1
    nq, nk = Sq // bq, Skv // bk

    qg = q.reshape(B, nq, bq, Hkv, G, D)
    kb = k.reshape(B, nk, bk, Hkv, D)
    vb = v.reshape(B, nk, bk, Hkv, D)

    def q_block(qi, qblk):
        qpos = q_offset + qi * bq + jnp.arange(bq)
        # blocks stay in the input dtype (bf16 in production); the dots
        # accumulate in fp32 via preferred_element_type — exactly the MXU
        # behaviour of the Pallas kernel, and half the HBM block traffic
        qf = qblk * jnp.asarray(scale, qblk.dtype)

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, kblk, vblk = inputs
            kpos = ki * bk + jnp.arange(bk)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kblk,
                           preferred_element_type=jnp.float32)
            msk = jnp.ones((bq, bk), dtype=bool)
            if causal:
                msk &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                msk &= kpos[None, :] > (qpos[:, None] - window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            # fully-masked positions would otherwise contribute exp(0)=1
            p = jnp.where(msk[None, None, None], p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(qblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, bq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, bq, D), jnp.float32)
        ks = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (ks, jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-37)
        return jnp.moveaxis(out, 3, 1).reshape(B, bq, Hq, D)  # b h g q d -> b q (h g) d

    outs = jax.lax.map(lambda args: q_block(*args),
                       (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hq, D).astype(q.dtype)


def attention_chunked_with_lse(q: jax.Array, k: jax.Array, v: jax.Array, *,
                               causal: bool = True,
                               window: Optional[int] = None,
                               q_offset: int = 0,
                               scale: Optional[float] = None,
                               block_q: int = 512, block_k: int = 1024):
    """attention_chunked + per-row logsumexp stats (needed by the manual
    flash backward). Returns (out (B,Sq,Hq,D), lse fp32 (B,Sq,Hq))."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, Sq)
    while Sq % bq:
        bq -= 1
    bk = min(block_k, Skv)
    while Skv % bk:
        bk -= 1
    nq, nk = Sq // bq, Skv // bk
    qg = q.reshape(B, nq, bq, Hkv, G, D)
    kb = jnp.moveaxis(k.reshape(B, nk, bk, Hkv, D), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, bk, Hkv, D), 1, 0)

    def q_block(qi, qblk):
        qpos = q_offset + qi * bq + jnp.arange(bq)
        qf = qblk * jnp.asarray(scale, qblk.dtype)

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, kblk, vblk = inputs
            kpos = ki * bk + jnp.arange(bk)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kblk,
                           preferred_element_type=jnp.float32)
            msk = jnp.ones((bq, bk), dtype=bool)
            if causal:
                msk &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                msk &= kpos[None, :] > (qpos[:, None] - window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.where(msk[None, None, None],
                          jnp.exp(s - m_new[..., None]), 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(qblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, bq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, bq, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l[..., None], 1e-37)
        lse = m + jnp.log(jnp.maximum(l, 1e-37))
        return (jnp.moveaxis(out, 3, 1).reshape(B, bq, Hq, D),
                jnp.moveaxis(lse, 3, 1).reshape(B, bq, Hq))

    outs, lses = jax.lax.map(lambda args: q_block(*args),
                             (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hq, D).astype(q.dtype)
    lse = jnp.moveaxis(lses, 0, 1).reshape(B, Sq, Hq)
    return out, lse


def attention_chunked_bwd(q, k, v, out, lse, dout, *, causal=True,
                          window=None, q_offset=0, scale=None,
                          block_q: int = 512, block_k: int = 1024):
    """Manual flash-attention backward: recompute scores blockwise from
    (q, k, v, lse); O(block_q x block_k) transients, no saved inner-scan
    residuals (this is what keeps the training memory roofline honest —
    XLA autodiff of the chunked forward would save every kv-step carry).

    Outer scan over kv blocks (emitting dk_j, dv_j), inner scan over q
    blocks (accumulating dq in-place). Causal block skipping is left to
    the TPU kernel; here fully-masked blocks simply contribute zeros.
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, Sq)
    while Sq % bq:
        bq -= 1
    bk = min(block_k, Skv)
    while Skv % bk:
        bk -= 1
    nq, nk = Sq // bq, Skv // bk

    qg = jnp.moveaxis(q.reshape(B, nq, bq, Hkv, G, D), 1, 0)
    og = jnp.moveaxis(out.reshape(B, nq, bq, Hkv, G, D), 1, 0)
    dog = jnp.moveaxis(dout.reshape(B, nq, bq, Hkv, G, D), 1, 0)
    lseg = jnp.moveaxis(lse.reshape(B, nq, bq, Hkv, G), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, bk, Hkv, D), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, bk, Hkv, D), 1, 0)
    # delta = rowsum(dout * out)  (B, nq, bq, Hkv, G) — O(S) stats
    delta = jnp.einsum("nbqhgd,nbqhgd->nbqhg",
                       dog.astype(jnp.float32), og.astype(jnp.float32))

    def kv_block(dq_acc, kv_inputs):
        kj, kblk, vblk = kv_inputs
        kpos = kj * bk + jnp.arange(bk)
        kf = kblk
        vf = vblk

        def q_step(carry, q_inputs):
            dq_acc, dk_j, dv_j = carry
            qi, qblk, doblk, lseblk, dblk = q_inputs
            qpos = q_offset + qi * bq + jnp.arange(bq)
            qf = qblk
            dof = doblk
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf,
                           preferred_element_type=jnp.float32) * scale
            msk = jnp.ones((bq, bk), dtype=bool)
            if causal:
                msk &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                msk &= kpos[None, :] > (qpos[:, None] - window)
            lse_t = jnp.moveaxis(lseblk.astype(jnp.float32), 1, -1)  # b h g q
            p = jnp.where(msk[None, None, None],
                          jnp.exp(s - lse_t[..., None]), 0.0)
            pc = p.astype(qf.dtype)
            dv_j = dv_j + jnp.einsum("bhgqk,bqhgd->bkhd", pc, dof,
                                     preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", dof, vf,
                            preferred_element_type=jnp.float32)
            d_t = jnp.moveaxis(dblk.astype(jnp.float32), 1, -1)      # b h g q
            ds = (p * (dp - d_t[..., None]) * scale)
            dsc = ds.astype(qf.dtype)
            dq_i = jnp.einsum("bhgqk,bkhd->bqhgd", dsc, kf,
                              preferred_element_type=jnp.float32)
            dk_j = dk_j + jnp.einsum("bhgqk,bqhgd->bkhd", dsc, qf,
                                     preferred_element_type=jnp.float32)
            dq_acc = dq_acc.at[qi].add(dq_i)
            return (dq_acc, dk_j, dv_j), None

        dk0 = jnp.zeros((B, bk, Hkv, D), jnp.float32)
        dv0 = jnp.zeros((B, bk, Hkv, D), jnp.float32)
        (dq_acc, dk_j, dv_j), _ = jax.lax.scan(
            q_step, (dq_acc, dk0, dv0),
            (jnp.arange(nq), qg, dog, lseg, delta))
        return dq_acc, (dk_j, dv_j)

    dq0 = jnp.zeros((nq, B, bq, Hkv, G, D), jnp.float32)
    dq_acc, (dks, dvs) = jax.lax.scan(kv_block, dq0,
                                      (jnp.arange(nk), kb, vb))
    dq = jnp.moveaxis(dq_acc, 0, 1).reshape(B, Sq, Hq, D).astype(q.dtype)
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, Skv, Hkv, D).astype(k.dtype)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Skv, Hkv, D).astype(v.dtype)
    return dq, dk, dv


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         cache_len: jax.Array, *,
                         window: Optional[int] = None,
                         scale: Optional[float] = None) -> jax.Array:
    """Single-token decode: q (B, 1, Hq, D); k/v (B, Smax, Hkv, D) ring/linear
    buffer with ``cache_len`` valid entries (the new token already appended).
    Bandwidth-bound; XLA handles it well so this is also the production path
    on TPU (no Pallas kernel needed — see DESIGN.md)."""
    B, _, Hq, D = q.shape
    _, Smax, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bthd->bhgt", qg, k.astype(jnp.float32)) * scale
    tpos = jnp.arange(Smax)
    valid = tpos[None, :] < cache_len[:, None]  # (B, Smax)
    if window is not None:
        valid &= tpos[None, :] > (cache_len[:, None] - 1 - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, 1, Hq, D).astype(q.dtype)
