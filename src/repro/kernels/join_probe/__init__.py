from repro.kernels.join_probe.ops import join_probe
