"""Pallas TPU kernel: partition-wise join probe.

TPU adaptation of the W3 hash-join probe. The CPU version (Blanas '11)
chases hash buckets per tuple — per-lane random access that the paper speeds
up with allocators and placement. TPUs have no per-lane gather worth using,
so the partition-local probe is recast as a *blocked broadcast compare*:
the build partition's (keys, vals) tile stays resident in VMEM while probe
blocks stream through; an (bp x bb) equality matrix (VPU) followed by a
matmul against build values (MXU) yields matched values — effectively a
tiny nested-loop join per partition, which on the MXU is faster than any
scatter/gather hash probe for build tiles <= ~2K keys. Radix partitioning
(kernels/radix_partition) guarantees that bound.

Grid: (n_partitions, n_probe_blocks); the build tile is re-fetched per
partition (index_map keyed on partition only).
Working set: bb*(2) + bp + bp*bb fp32 ~ (1024 x 1024) -> ~4.2 MB VMEM.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _probe_kernel(bkeys_ref, bvals_ref, pkeys_ref, vals_ref, found_ref):
    bk = bkeys_ref[0]                                  # (Bk,)
    bv = bvals_ref[0].astype(jnp.float32)
    pk = pkeys_ref[0]                                  # (bp,)
    eq = (pk[:, None] == bk[None, :])                  # (bp, Bk)
    eqf = eq.astype(jnp.float32)
    vals = jax.lax.dot_general(eqf, bv[:, None], (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    vals_ref[0] = vals[:, 0]
    found_ref[0] = eq.any(axis=-1)


def join_probe_pallas(build_keys: jax.Array, build_vals: jax.Array,
                      probe_keys: jax.Array, *, block_p: int = 1024,
                      interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """build_keys/vals: (P, Bk); probe_keys: (P, Pk), Pk % block_p == 0."""
    P, Bk = build_keys.shape
    _, Pk = probe_keys.shape
    bp = max(1, min(block_p, Pk))
    while Pk % bp:
        bp //= 2
    n_blocks = Pk // bp

    vals, found = pl.pallas_call(
        _probe_kernel,
        grid=(P, n_blocks),
        in_specs=[
            pl.BlockSpec((1, Bk), lambda p, b: (p, 0)),
            pl.BlockSpec((1, Bk), lambda p, b: (p, 0)),
            pl.BlockSpec((1, bp), lambda p, b: (p, b)),
        ],
        out_specs=[
            pl.BlockSpec((1, bp), lambda p, b: (p, b)),
            pl.BlockSpec((1, bp), lambda p, b: (p, b)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P, Pk), jnp.float32),
            jax.ShapeDtypeStruct((P, Pk), jnp.bool_),
        ],
        interpret=interpret,
    )(build_keys, build_vals, probe_keys)
    return vals, found
