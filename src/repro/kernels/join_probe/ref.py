"""Oracle for the partition-wise join probe (W3/W4, paper Section 2.1).

Both relations arrive radix-partitioned on the join key; within a partition
the build side is small enough to broadcast. Build keys are unique (the
paper's Blanas dataset is a PK-FK join). Probe misses return value 0 and
found=False. A build-side padding convention of key == -1 marks empty slots.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def join_probe_ref(build_keys: jax.Array, build_vals: jax.Array,
                   probe_keys: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """build_keys/vals: (P, Bk); probe_keys: (P, Pk).
    Returns (vals (P, Pk) f32, found (P, Pk) bool)."""
    eq = probe_keys[:, :, None] == build_keys[:, None, :]     # (P, Pk, Bk)
    found = eq.any(axis=-1)
    vals = jnp.einsum("pqb,pb->pq", eq.astype(jnp.float32),
                      build_vals.astype(jnp.float32))
    return vals, found
