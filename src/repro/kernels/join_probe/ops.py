"""Public partition-wise join probe with mode dispatch."""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.kernels.common import kernel_mode
from repro.kernels.join_probe.kernel import join_probe_pallas
from repro.kernels.join_probe.ref import join_probe_ref


def join_probe(build_keys: jax.Array, build_vals: jax.Array,
               probe_keys: jax.Array, *, block_p: int = 1024,
               mode: Optional[str] = None) -> Tuple[jax.Array, jax.Array]:
    """PK-FK partition-local probe -> (matched vals (P,Pk), found (P,Pk))."""
    resolved = kernel_mode(mode)
    if resolved == "pallas":
        return join_probe_pallas(build_keys, build_vals, probe_keys,
                                 block_p=block_p)
    if resolved == "interpret":
        return join_probe_pallas(build_keys, build_vals, probe_keys,
                                 block_p=block_p, interpret=True)
    return join_probe_ref(build_keys, build_vals, probe_keys)
