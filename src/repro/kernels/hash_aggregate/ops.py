"""Public partitioned-aggregation op with mode dispatch."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.common import kernel_mode
from repro.kernels.hash_aggregate.kernel import hash_aggregate_pallas
from repro.kernels.hash_aggregate.ref import hash_aggregate_ref


def hash_aggregate(ids: jax.Array, vals: jax.Array, *, n_bins: int,
                   block: int = 512, mode: Optional[str] = None) -> jax.Array:
    """Partition-local segment sums. ids, vals: (P, T) -> (P, n_bins)."""
    resolved = kernel_mode(mode)
    if resolved == "pallas":
        return hash_aggregate_pallas(ids, vals, n_bins=n_bins, block=block)
    if resolved == "interpret":
        return hash_aggregate_pallas(ids, vals, n_bins=n_bins, block=block,
                                     interpret=True)
    return hash_aggregate_ref(ids, vals, n_bins=n_bins)
