"""Public partitioned-aggregation ops with mode dispatch."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.common import kernel_mode
from repro.kernels.hash_aggregate.kernel import hash_aggregate_multi_pallas
from repro.kernels.hash_aggregate.ref import hash_aggregate_multi_ref


def hash_aggregate_multi(ids: jax.Array, vals: jax.Array, *, n_bins: int,
                         block: int = 512,
                         mode: Optional[str] = None) -> jax.Array:
    """Fused partition-local segment sums over C stacked measure columns.

    ids: (P, T); vals: (P, T, C) -> (P, n_bins, C). The one-hot/ids stream
    cost is paid once for all C aggregates (see kernel.py)."""
    resolved = kernel_mode(mode)
    if resolved == "pallas":
        return hash_aggregate_multi_pallas(ids, vals, n_bins=n_bins,
                                           block=block)
    if resolved == "interpret":
        return hash_aggregate_multi_pallas(ids, vals, n_bins=n_bins,
                                           block=block, interpret=True)
    return hash_aggregate_multi_ref(ids, vals, n_bins=n_bins)


def hash_aggregate(ids: jax.Array, vals: jax.Array, *, n_bins: int,
                   block: int = 512, mode: Optional[str] = None) -> jax.Array:
    """Partition-local segment sums. ids, vals: (P, T) -> (P, n_bins).

    Thin single-aggregate wrapper over :func:`hash_aggregate_multi`."""
    return hash_aggregate_multi(ids, vals[..., None], n_bins=n_bins,
                                block=block, mode=mode)[..., 0]
