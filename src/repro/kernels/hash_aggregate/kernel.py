"""Pallas TPU kernel: partitioned hash aggregation (distributive SUM/COUNT).

This is the W2 hot loop (paper Section 2.1) made TPU-native. The CPU
implementation the paper benchmarks is a concurrent cuckoo hash table whose
scalability is gated by allocator arenas and cache-line contention. On TPU
we keep the *partition table resident in VMEM scratch* across the stream of
record blocks (the analogue of a per-thread table in L2 — LOCAL_ALLOC at
tile scale), and the per-record "table update" becomes a one_hot^T @ vals
MXU matmul — contention-free by construction.

Fused multi-aggregate form: TPC-H Q1 needs seven independent SUMs over the
same key column. Instead of seven passes, the kernel computes the
(block, n_bins) one-hot ONCE per record block and contracts it against a
stacked (block, n_cols) values matrix in a single MXU dot — the ids stream
and the one-hot build are amortized across every aggregate, so the sweep is
one read of each measure column and one read of the key column, total.

Grid: (n_partitions, n_blocks); blocks innermost so the scratch table for a
partition accumulates across its stream, then emits once.
Working set: (block x n_bins) one-hot fp32 + (n_bins, n_cols) table — with
block=512, bins=2048, cols=8: ~4.3 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _agg_multi_kernel(ids_ref, vals_ref, out_ref, table_scr, *, n_bins: int,
                      block: int, n_blocks: int):
    bi = pl.program_id(1)

    @pl.when(bi == 0)
    def _init():
        table_scr[...] = jnp.zeros(table_scr.shape, table_scr.dtype)

    ids = ids_ref[0]                                    # (block,)
    vals = vals_ref[0].astype(jnp.float32)              # (block, C)
    bins = jax.lax.broadcasted_iota(jnp.int32, (block, n_bins), 1)
    oh = (ids[:, None] == bins).astype(jnp.float32)     # (block, n_bins)
    contrib = jax.lax.dot_general(oh, vals, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    table_scr[...] = table_scr[...] + contrib           # (n_bins, C)

    @pl.when(bi == n_blocks - 1)
    def _emit():
        out_ref[...] = table_scr[...][None]


def hash_aggregate_multi_pallas(ids: jax.Array, vals: jax.Array, *,
                                n_bins: int, block: int = 512,
                                interpret: bool = False) -> jax.Array:
    """ids: (P, T); vals: (P, T, C) with T % block == 0.

    Returns (P, n_bins, C) f32: per-partition tables of C fused sums."""
    P, T = ids.shape
    if vals.shape[:2] != (P, T):
        raise ValueError(f"vals {vals.shape} does not match ids {ids.shape}")
    C = vals.shape[2]
    if T % block:
        raise ValueError(f"T={T} not divisible by block={block}")
    n_blocks = T // block
    kernel = functools.partial(_agg_multi_kernel, n_bins=n_bins, block=block,
                               n_blocks=n_blocks)
    return pl.pallas_call(
        kernel,
        grid=(P, n_blocks),
        in_specs=[
            pl.BlockSpec((1, block), lambda p, b: (p, b)),
            pl.BlockSpec((1, block, C), lambda p, b: (p, b, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_bins, C), lambda p, b: (p, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((P, n_bins, C), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n_bins, C), jnp.float32)],
        interpret=interpret,
    )(ids, vals)


def hash_aggregate_pallas(ids: jax.Array, vals: jax.Array, *, n_bins: int,
                          block: int = 512,
                          interpret: bool = False) -> jax.Array:
    """Single-aggregate entrypoint: thin wrapper over the fused kernel.

    ids, vals: (P, T) with T % block == 0. Returns (P, n_bins) f32."""
    out = hash_aggregate_multi_pallas(ids, vals[..., None], n_bins=n_bins,
                                      block=block, interpret=interpret)
    return out[..., 0]
