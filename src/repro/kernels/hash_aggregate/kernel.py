"""Pallas TPU kernel: partitioned hash aggregation (distributive SUM/COUNT).

This is the W2 hot loop (paper Section 2.1) made TPU-native. The CPU
implementation the paper benchmarks is a concurrent cuckoo hash table whose
scalability is gated by allocator arenas and cache-line contention. On TPU
we keep the *partition table resident in VMEM scratch* across the stream of
record blocks (the analogue of a per-thread table in L2 — LOCAL_ALLOC at
tile scale), and the per-record "table update" becomes a one_hot^T @ vals
MXU matmul — contention-free by construction.

Grid: (n_partitions, n_blocks); blocks innermost so the scratch table for a
partition accumulates across its stream, then emits once.
Working set: (block x n_bins) one-hot fp32 + (n_bins,) table — with
block=512, bins=2048: ~4.2 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _agg_kernel(ids_ref, vals_ref, out_ref, table_scr, *, n_bins: int,
                block: int, n_blocks: int):
    bi = pl.program_id(1)

    @pl.when(bi == 0)
    def _init():
        table_scr[...] = jnp.zeros(table_scr.shape, table_scr.dtype)

    ids = ids_ref[0]                                    # (block,)
    vals = vals_ref[0].astype(jnp.float32)              # (block,)
    bins = jax.lax.broadcasted_iota(jnp.int32, (block, n_bins), 1)
    oh = (ids[:, None] == bins).astype(jnp.float32)     # (block, n_bins)
    contrib = jax.lax.dot_general(vals[None, :], oh, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    table_scr[...] = table_scr[...] + contrib           # (1, n_bins)

    @pl.when(bi == n_blocks - 1)
    def _emit():
        out_ref[...] = table_scr[...]


def hash_aggregate_pallas(ids: jax.Array, vals: jax.Array, *, n_bins: int,
                          block: int = 512,
                          interpret: bool = False) -> jax.Array:
    """ids, vals: (P, T) with T % block == 0. Returns (P, n_bins) f32."""
    P, T = ids.shape
    if T % block:
        raise ValueError(f"T={T} not divisible by block={block}")
    n_blocks = T // block
    kernel = functools.partial(_agg_kernel, n_bins=n_bins, block=block,
                               n_blocks=n_blocks)
    return pl.pallas_call(
        kernel,
        grid=(P, n_blocks),
        in_specs=[
            pl.BlockSpec((1, block), lambda p, b: (p, b)),
            pl.BlockSpec((1, block), lambda p, b: (p, b)),
        ],
        out_specs=pl.BlockSpec((1, n_bins), lambda p, b: (p, 0)),
        out_shape=jax.ShapeDtypeStruct((P, n_bins), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, n_bins), jnp.float32)],
        interpret=interpret,
    )(ids, vals)
