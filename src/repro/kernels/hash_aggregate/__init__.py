from repro.kernels.hash_aggregate.ops import (hash_aggregate,
                                              hash_aggregate_multi)
