"""Oracle for partitioned hash aggregation (distributive: SUM / COUNT).

Inputs are pre-partitioned: ids[p, t] in [0, n_bins) are partition-local
group slots, vals[p, t] the aggregated measure (1.0 for COUNT). A padding
slot id == n_bins-1 with val 0 is the convention for ragged partitions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hash_aggregate_ref(ids: jax.Array, vals: jax.Array, *,
                       n_bins: int) -> jax.Array:
    """ids: (P, T) int32; vals: (P, T) f32. Returns (P, n_bins) f32 sums."""
    def one(i, v):
        return jax.ops.segment_sum(v, i, num_segments=n_bins)
    return jax.vmap(one)(ids, vals.astype(jnp.float32))
