"""Oracle for partitioned hash aggregation (distributive: SUM / COUNT).

Inputs are pre-partitioned: ids[p, t] in [0, n_bins) are partition-local
group slots, vals[p, t(, c)] the aggregated measures (1.0 for COUNT). A
padding slot with val 0 is the convention for ragged partitions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hash_aggregate_multi_ref(ids: jax.Array, vals: jax.Array, *,
                             n_bins: int) -> jax.Array:
    """ids: (P, T) int32; vals: (P, T, C) f32. Returns (P, n_bins, C) sums.

    One fused pass: segment_sum carries all C measure columns per record, so
    the key stream is read once regardless of how many aggregates ride on it
    (the XLA-lowered shape of the fused Pallas kernel).
    """
    def one(i, v):
        return jax.ops.segment_sum(v, i, num_segments=n_bins)
    return jax.vmap(one)(ids, vals.astype(jnp.float32))


def hash_aggregate_ref(ids: jax.Array, vals: jax.Array, *,
                       n_bins: int) -> jax.Array:
    """ids: (P, T) int32; vals: (P, T) f32. Returns (P, n_bins) f32 sums."""
    return hash_aggregate_multi_ref(ids, vals[..., None], n_bins=n_bins)[..., 0]
