"""Pallas TPU kernel for the RWKV6 WKV recurrence.

TPU adaptation: the (N x N) per-head state lives in VMEM scratch across the
sequential time-block grid axis (N = 64 -> 16 KB fp32, trivially resident).
Each step is one (1,N)x(N,N) matvec (MXU) plus rank-1 state update (VPU);
the time loop is an in-kernel fori_loop over the current (block_s, N) tile.
A chunked matmul formulation (flash-linear-attention style) is the recorded
hillclimb follow-up; this kernel is the faithful, bandwidth-efficient
baseline: r/k/v/w stream through VMEM once, state never leaves VMEM.

Grid: (batch * heads, seq_blocks); time is innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, sout_ref, s_scr, *,
                block_s: int, n_s_blocks: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        s_scr[...] = jnp.zeros(s_scr.shape, s_scr.dtype)

    r = r_ref[0].astype(jnp.float32)   # (bs, N)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)   # (1, N)

    def body(i, S):
        ri = jax.lax.dynamic_slice_in_dim(r, i, 1, 0)   # (1, N)
        ki = jax.lax.dynamic_slice_in_dim(k, i, 1, 0)
        vi = jax.lax.dynamic_slice_in_dim(v, i, 1, 0)
        wi = jax.lax.dynamic_slice_in_dim(w, i, 1, 0)
        bonus = jnp.sum(ri * u * ki)                    # scalar
        y = jax.lax.dot_general(ri, S, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        y = y + bonus * vi                              # (1, N)
        # index the leading block dim with a size-1 dslice, not a bare int:
        # int indices crash the interpreter's store discharge on this jax
        pl.store(o_ref, (pl.dslice(0, 1), pl.dslice(i, 1), slice(None)),
                 y[None].astype(o_ref.dtype))
        S = wi.reshape(-1, 1) * S + ki.reshape(-1, 1) * vi
        return S

    S = jax.lax.fori_loop(0, block_s, body, s_scr[...])
    s_scr[...] = S

    @pl.when(si == n_s_blocks - 1)
    def _emit_state():
        sout_ref[...] = S[None].astype(sout_ref.dtype)


def wkv6_pallas(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                u: jax.Array, *, block_s: int = 256,
                interpret: bool = False):
    """r,k,v,w: (B, S, H, N); u: (H, N). Returns (y (B,S,H,N) fp32, S_out)."""
    B, S, H, N = r.shape
    bs = max(1, min(block_s, S))
    while S % bs:
        bs //= 2
    ns = S // bs

    def hm(x):  # (B,S,H,N) -> (B*H, S, N) heads-major
        return jnp.moveaxis(x, 2, 1).reshape(B * H, S, N)

    rh, kh, vh, wh = hm(r), hm(k), hm(v), hm(w)
    kernel = functools.partial(_wkv_kernel, block_s=bs, n_s_blocks=ns)
    grid = (B * H, ns)
    y, s_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, N), lambda g, si: (g, si, 0)),
            pl.BlockSpec((1, bs, N), lambda g, si: (g, si, 0)),
            pl.BlockSpec((1, bs, N), lambda g, si: (g, si, 0)),
            pl.BlockSpec((1, bs, N), lambda g, si: (g, si, 0)),
            pl.BlockSpec((1, N), lambda g, si, H=H: (g % H, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, N), lambda g, si: (g, si, 0)),
            pl.BlockSpec((1, N, N), lambda g, si: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, N), jnp.float32),
            jax.ShapeDtypeStruct((B * H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(rh, kh, vh, wh, u)
    y = jnp.moveaxis(y.reshape(B, H, S, N), 1, 2)
    return y, s_out.reshape(B, H, N, N)
