"""Public WKV6 op with mode dispatch.

Backward: differentiating through the ref lax.scan (recompute-friendly under
remat). The Pallas kernel accelerates forward (inference/prefill); training
on TPU can keep the kernel forward via this custom_vjp whose backward uses
the scan formulation.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import kernel_mode
from repro.kernels.rwkv6_scan.kernel import wkv6_pallas
from repro.kernels.rwkv6_scan.ref import wkv6_ref, wkv6_step_ref


def _dispatch(r, k, v, w, u, mode):
    resolved = kernel_mode(mode)
    if resolved == "pallas":
        return wkv6_pallas(r, k, v, w, u)
    if resolved == "interpret":
        return wkv6_pallas(r, k, v, w, u, interpret=True)
    return wkv6_ref(r, k, v, w, u)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _wkv6(r, k, v, w, u, mode):
    return _dispatch(r, k, v, w, u, mode)


def _fwd(r, k, v, w, u, mode):
    out = _dispatch(r, k, v, w, u, mode)
    return out, (r, k, v, w, u)


def _bwd(mode, res, g):
    r, k, v, w, u = res
    gy, gs = g
    _, vjp = jax.vjp(lambda *args: wkv6_ref(*args), r, k, v, w, u)
    return vjp((gy, gs))


_wkv6.defvjp(_fwd, _bwd)


def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, mode: Optional[str] = None
         ) -> Tuple[jax.Array, jax.Array]:
    """WKV6 scan. r,k,v,w: (B,S,H,N); u: (H,N) -> (y, final_state)."""
    return _wkv6(r, k, v, w, u, mode)


def wkv6_step(r, k, v, w, u, state):
    """Decode step (jnp; bandwidth-bound)."""
    return wkv6_step_ref(r, k, v, w, u, state)
