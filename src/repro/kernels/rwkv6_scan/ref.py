"""Pure-jnp oracle for the RWKV6 (Finch) WKV recurrence.

Per (batch, head), with state S in R^{N x N} (key dim i, value dim j):
    y_t[j]  = sum_i r_t[i] * (S[i,j] + u[i] * k_t[i] * v_t[j])
    S[i,j] <- w_t[i] * S[i,j] + k_t[i] * v_t[j]
w_t in (0,1) is the data-dependent per-channel decay (the Finch novelty).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def wkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, state: Optional[jax.Array] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """r,k,v,w: (B, S, H, N) ; u: (H, N).  Returns (y (B,S,H,N) fp32, S_out)."""
    B, S, H, N = r.shape
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)
    if state is None:
        state = jnp.zeros((B, H, N, N), jnp.float32)

    def step(S_c, inp):
        r_t, k_t, v_t, w_t = inp                       # (B, H, N)
        kv = k_t[..., :, None] * v_t[..., None, :]     # (B, H, N, N)
        y = jnp.einsum("bhi,bhij->bhj", r_t, S_c + uf[..., :, None] * kv)
        S_n = w_t[..., :, None] * S_c + kv
        return S_n, y

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (rf, kf, vf, wf))
    S_out, ys = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), S_out


def wkv6_step_ref(r, k, v, w, u, state):
    """Single decode step. r,k,v,w: (B, H, N); state: (B, H, N, N) fp32."""
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]
    y = jnp.einsum("bhi,bhij->bhj", rf, state + uf[..., :, None] * kv)
    state = wf[..., :, None] * state + kv
    return y, state
