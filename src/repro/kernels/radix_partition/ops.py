"""Public radix-partition ops: histogram pass (kernel) + scatter pass (XLA
sort) composed into a full partitioner."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import kernel_mode
from repro.kernels.radix_partition.kernel import block_histograms_pallas
from repro.kernels.radix_partition.ref import block_histograms_ref


def block_histograms(keys: jax.Array, *, n_bins: int, shift: int = 0,
                     block: int = 1024,
                     mode: Optional[str] = None) -> jax.Array:
    resolved = kernel_mode(mode)
    if resolved == "pallas":
        return block_histograms_pallas(keys, n_bins=n_bins, shift=shift,
                                       block=block)
    if resolved == "interpret":
        return block_histograms_pallas(keys, n_bins=n_bins, shift=shift,
                                       block=block, interpret=True)
    return block_histograms_ref(keys, n_bins=n_bins, shift=shift, block=block)


def padded_bin_counts(keys: jax.Array, *, n_bins: int, shift: int = 0,
                      block: int = 1024,
                      mode: Optional[str] = None) -> jax.Array:
    """Total per-digit counts via the block-histogram kernel, for any N.

    Keys are padded with zeros to a block multiple; padding lands in the
    digit-0 bin ((0 >>> shift) & mask == 0 under logical shift), so the
    count of that sentinel bin is corrected before returning. N == 0 is a
    static degenerate case: all-zero counts."""
    n = keys.shape[0]
    if n == 0:
        return jnp.zeros((n_bins,), jnp.int32)
    pad = -n % block
    padded = jnp.pad(keys, (0, pad)) if pad else keys
    hist = block_histograms(padded, n_bins=n_bins, shift=shift, block=block,
                            mode=mode)
    counts = hist.sum(axis=0)
    if pad:
        counts = counts.at[0].add(-pad)
    return counts


def radix_partition(keys: jax.Array, values: jax.Array, *, n_bins: int,
                    shift: int = 0, block: int = 1024,
                    mode: Optional[str] = None
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Partition (keys, values) by radix digit.

    Returns (keys_out, values_out, bin_starts) with records stably grouped
    by digit. Histogram via the kernel; scatter via a stable sort on the
    digit (XLA's radix sort — the TPU-native scatter)."""
    digits = jax.lax.shift_right_logical(keys, shift) & (n_bins - 1)
    counts = padded_bin_counts(keys, n_bins=n_bins, shift=shift, block=block,
                               mode=mode)
    starts = jnp.cumsum(counts) - counts
    order = jnp.argsort(digits, stable=True)
    return keys[order], values[order], starts
