"""Pallas TPU kernel: per-block radix histograms.

TPU adaptation of the CPU/GPU radix counting loop: instead of per-lane
scatter-increment into a shared histogram (bank-conflict territory on GPUs,
cache-line ping-pong on NUMA CPUs — the exact contention the paper's
allocator/placement work fights), each block computes
    one_hot(digits) summed over the block via an MXU matmul-shaped reduce,
so the "histogram update" becomes a dense (block x n_bins) reduction with no
scatter at all. Each grid step owns its output row — zero write contention,
the embodiment of the paper's LOCAL_ALLOC-then-merge recipe at tile scale.

Grid: (n_blocks,). Working set: (1, block) keys + (block, n_bins) one-hot
in fp32 — block=1024, bins=256 -> ~1 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(keys_ref, out_ref, *, n_bins: int, shift: int, block: int):
    k = keys_ref[0]                                   # (block,) int32
    digits = jax.lax.shift_right_logical(k, shift) & (n_bins - 1)
    bins = jax.lax.broadcasted_iota(jnp.int32, (block, n_bins), 1)
    oh = (digits[:, None] == bins).astype(jnp.float32)
    ones = jnp.ones((1, block), jnp.float32)
    counts = jax.lax.dot_general(ones, oh, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    out_ref[...] = counts.astype(jnp.int32)


def block_histograms_pallas(keys: jax.Array, *, n_bins: int, shift: int,
                            block: int, interpret: bool = False) -> jax.Array:
    N = keys.shape[0]
    if N % block:
        raise ValueError(f"N={N} not divisible by block={block}")
    n_blocks = N // block
    kernel = functools.partial(_hist_kernel, n_bins=n_bins, shift=shift,
                               block=block)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n_bins), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, n_bins), jnp.int32),
        interpret=interpret,
    )(keys.reshape(n_blocks, block))
