"""Oracle for the radix-histogram pass of partitioning.

Radix partitioning is the memory-bound hot loop of every NUMA-aware
join/aggregation in the paper's lineage (Blanas'11, Balkesen'13, Schuh'16):
pass 1 counts keys per radix digit per block, pass 2 scatters. The count
pass is what the Pallas kernel accelerates; the scatter is a sort (XLA).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_histograms_ref(keys: jax.Array, *, n_bins: int, shift: int,
                         block: int) -> jax.Array:
    """keys: (N,) int32, N % block == 0. Returns (N//block, n_bins) int32
    histograms of the radix digit (keys >> shift) & (n_bins-1) per block."""
    digits = jax.lax.shift_right_logical(keys, shift) & (n_bins - 1)
    blocks = digits.reshape(-1, block)
    oh = jax.nn.one_hot(blocks, n_bins, dtype=jnp.int32)
    return oh.sum(axis=1)
