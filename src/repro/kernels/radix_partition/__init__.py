from repro.kernels.radix_partition.ops import block_histograms, radix_partition
