from repro.kernels.radix_partition.ops import (block_histograms,
                                               padded_bin_counts,
                                               radix_partition)
