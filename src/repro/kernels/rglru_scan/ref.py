"""Pure-jnp oracle for the RG-LRU linear recurrence h_t = a_t h_{t-1} + b_t.

Parallel-prefix formulation via ``jax.lax.associative_scan`` over the
associative combine  (a2,b2) o (a1,b1) = (a1*a2, b1*a2 + b2)  — O(S log S)
work, O(log S) depth, fully vectorized over (batch, d_rnn).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_scan_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """a, b: (B, S, D) fp32. Returns h with h_{-1} = 0."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def linear_scan_sequential(a: jax.Array, b: jax.Array) -> jax.Array:
    """Step-by-step lax.scan version (independent second oracle)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    a_t = jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    b_t = jnp.moveaxis(b.astype(jnp.float32), 1, 0)
    h0 = jnp.zeros(a.shape[:1] + a.shape[2:], jnp.float32)
    _, hs = jax.lax.scan(step, h0, (a_t, b_t))
    return jnp.moveaxis(hs, 0, 1)
