from repro.kernels.rglru_scan.ops import linear_scan
