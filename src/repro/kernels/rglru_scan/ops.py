"""Public linear-scan op with mode dispatch + custom VJP.

The VJP of h_t = a_t h_{t-1} + b_t is itself a (reversed) linear scan:
  db_t = g_t + a_{t+1} db_{t+1}         (suffix scan of gradients)
  da_t = db_t * h_{t-1}
so the backward pass reuses the same primitive (kernel-accelerated on TPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import kernel_mode
from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
from repro.kernels.rglru_scan.ref import linear_scan_ref


def _dispatch(a, b, mode):
    resolved = kernel_mode(mode)
    if resolved == "pallas":
        return rglru_scan_pallas(a, b)
    if resolved == "interpret":
        return rglru_scan_pallas(a, b, interpret=True)
    return linear_scan_ref(a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def linear_scan(a: jax.Array, b: jax.Array,
                mode: Optional[str] = None) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t along axis 1. a, b: (B, S, D) -> fp32 h."""
    return _dispatch(a, b, mode)


def _fwd(a, b, mode):
    h = _dispatch(a, b, mode)
    return h, (a, h)


def _bwd(mode, res, g):
    a, h = res
    af = a.astype(jnp.float32)
    # suffix scan: db_t = g_t + a_{t+1} db_{t+1}  == reversed prefix scan
    a_next = jnp.concatenate(
        [af[:, 1:], jnp.zeros_like(af[:, :1])], axis=1)
    db = _dispatch(jnp.flip(a_next, 1), jnp.flip(g.astype(jnp.float32), 1),
                   mode)
    db = jnp.flip(db, 1)
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h[:, :1]), h[:, :-1]], axis=1)
    da = db * h_prev
    return da.astype(a.dtype), db.astype(a.dtype)


linear_scan.defvjp(_fwd, _bwd)
