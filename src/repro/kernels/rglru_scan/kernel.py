"""Pallas TPU kernel for the RG-LRU linear recurrence.

TPU adaptation: the recurrence is chunked along time. Each grid step loads
one (block_s, block_d) tile of (a, b) into VMEM, runs a Hillis–Steele
doubling scan *inside registers/VMEM* (log2(block_s) vector ops — the VPU
equivalent of the warp-shuffle scans GPU kernels use), stitches the
inter-chunk carry h from VMEM scratch, and writes the scanned tile out.
The time dimension is the innermost (sequential) grid axis; the carry
scratch persists across it — Pallas' revisiting-output pattern.

Grid: (batch * d_blocks, seq_blocks).
Working set: 3 fp32 tiles of (block_s, block_d) — default 256 x 512 x 4B x 3
= 1.5 MB, comfortably inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(a_ref, b_ref, o_ref, h_scr, *, block_s: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = jnp.zeros(h_scr.shape, h_scr.dtype)

    a = a_ref[0].astype(jnp.float32)   # (bs, bd)
    b = b_ref[0].astype(jnp.float32)

    # Hillis–Steele doubling scan over the time (row) dimension.
    shift = 1
    while shift < block_s:
        a_sh = jnp.concatenate(
            [jnp.ones((shift, a.shape[1]), jnp.float32), a[:-shift]], axis=0)
        b_sh = jnp.concatenate(
            [jnp.zeros((shift, b.shape[1]), jnp.float32), b[:-shift]], axis=0)
        b = b_sh * a + b
        a = a * a_sh
        shift *= 2

    # a[t] now holds prod(a_0..t) within the chunk; b[t] the zero-state scan.
    h = b + a * h_scr[...]
    o_ref[0] = h.astype(o_ref.dtype)
    h_scr[...] = h[-1:]                 # carry last row to the next chunk


def rglru_scan_pallas(a: jax.Array, b: jax.Array, *, block_s: int = 256,
                      block_d: int = 512, interpret: bool = False) -> jax.Array:
    """a, b: (B, S, D). Returns fp32 h of the same shape."""
    B, S, D = a.shape
    bs = max(1, min(block_s, S))
    while S % bs:
        bs //= 2
    bd = max(1, min(block_d, D))
    while D % bd:
        bd //= 2
    ns, nd = S // bs, D // bd

    kernel = functools.partial(_scan_kernel, block_s=bs)
    grid = (B * nd, ns)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bd), lambda g, si, nd=nd: (g // nd, si, g % nd)),
            pl.BlockSpec((1, bs, bd), lambda g, si, nd=nd: (g // nd, si, g % nd)),
        ],
        out_specs=pl.BlockSpec((1, bs, bd),
                               lambda g, si, nd=nd: (g // nd, si, g % nd)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out
