"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block structure (the "recurrent block" of Griffin):
    y_branch = GeLU(W_y x)
    r_branch = W_x x -> causal conv1d(width 4) -> RG-LRU -> h
    out      = W_o (y_branch * h)

RG-LRU recurrence (all elementwise over d_rnn):
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_i x_t + b_i)            input gate
    log_a_t = -c * softplus(Lambda) * r_t   (c = 8)
    a_t = exp(log_a_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is a linear scan h_t = a_t h_{t-1} + b_t: training/prefill use
``jax.lax.associative_scan`` (parallel prefix — sub-quadratic and TPU
friendly; the Pallas chunked kernel in kernels/rglru_scan is the fused
version). Decode is a single fused elementwise step on O(d_rnn) state.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ArchConfig
from repro.core.params import pdef
from repro.kernels.rglru_scan import linear_scan

_C = 8.0


def rglru_schema(arch: ArchConfig) -> Dict[str, Any]:
    h = arch.hybrid
    d = arch.d_model
    dr = h.d_rnn or d
    return {
        "w_y": pdef((d, dr), ("embed", "d_rnn"), "scaled"),
        "w_x": pdef((d, dr), ("embed", "d_rnn"), "scaled"),
        "w_o": pdef((dr, d), ("d_rnn", "embed"), "scaled"),
        "conv_w": pdef((h.conv_width, dr), (None, "d_rnn"), "scaled", 0.1),
        "conv_b": pdef((dr,), ("d_rnn",), "zeros"),
        "w_a": pdef((dr,), ("d_rnn",), "scaled", 0.1),
        "b_a": pdef((dr,), ("d_rnn",), "zeros"),
        "w_i": pdef((dr,), ("d_rnn",), "scaled", 0.1),
        "b_i": pdef((dr,), ("d_rnn",), "zeros"),
        "lam": pdef((dr,), ("d_rnn",), "uniform", 1.0),
    }


def _gates(p, u):
    """u: (..., d_rnn) conv output. Returns (a, b) of the linear recurrence."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * p["w_a"].astype(jnp.float32) + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf * p["w_i"].astype(jnp.float32) + p["b_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (i * uf)
    return a, b


def _causal_conv(p, x, conv_state: Optional[jax.Array] = None):
    """Depthwise causal conv1d. x: (B, S, dr). Returns (out, new_state)."""
    w = p["conv_w"].astype(jnp.float32)         # (W, dr)
    W = w.shape[0]
    xf = x.astype(jnp.float32)
    if conv_state is not None:                   # decode: state (B, W-1, dr)
        ctx = jnp.concatenate([conv_state.astype(jnp.float32), xf], axis=1)
        out = (ctx * w[None]).sum(axis=1, keepdims=True)
        return (out + p["conv_b"].astype(jnp.float32)).astype(x.dtype), \
            ctx[:, 1:].astype(x.dtype)
    pad = jnp.pad(xf, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return (out + p["conv_b"].astype(jnp.float32)).astype(x.dtype), None


def rglru_forward(p: Dict[str, Any], x: jax.Array, arch: ArchConfig,
                  kernel_mode: Optional[str] = None) -> jax.Array:
    """Full-sequence pass. x: (B, S, d)."""
    y = jax.nn.gelu(x @ p["w_y"])
    u = x @ p["w_x"]
    u, _ = _causal_conv(p, u)
    a, b = _gates(p, u)
    h = linear_scan(a, b, mode=kernel_mode)      # (B, S, dr) fp32
    return (y * h.astype(y.dtype)) @ p["w_o"]


def rglru_cache_spec(arch: ArchConfig, batch: int,
                     dtype=jnp.bfloat16) -> Dict[str, Any]:
    h = arch.hybrid
    dr = h.d_rnn or arch.d_model
    return {
        "h": jax.ShapeDtypeStruct((batch, dr), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, h.conv_width - 1, dr), dtype),
    }


CACHE_AXES_RGLRU = {"h": ("batch", "d_rnn"), "conv": ("batch", None, "d_rnn")}


def rglru_init_cache(arch: ArchConfig, batch: int,
                     dtype=jnp.bfloat16) -> Dict[str, Any]:
    h = arch.hybrid
    dr = h.d_rnn or arch.d_model
    return {"h": jnp.zeros((batch, dr), jnp.float32),
            "conv": jnp.zeros((batch, h.conv_width - 1, dr), dtype)}


def rglru_decode(p: Dict[str, Any], x: jax.Array, cache: Dict[str, Any],
                 arch: ArchConfig) -> Tuple[jax.Array, Dict[str, Any]]:
    """One-step decode. x: (B, 1, d). State is O(d_rnn) — constant in context
    length, which is what makes long_500k serveable for this family."""
    y = jax.nn.gelu(x @ p["w_y"])
    u = x @ p["w_x"]
    u, conv_state = _causal_conv(p, u, cache["conv"])
    a, b = _gates(p, u)                          # (B, 1, dr)
    h_new = a[:, 0] * cache["h"] + b[:, 0]
    out = (y * h_new[:, None].astype(y.dtype)) @ p["w_o"]
    return out, {"h": h_new, "conv": conv_state}
