"""Shared layer primitives: norms, activations, RoPE / M-RoPE, losses.

Numerics policy: parameters and activations live in ``bfloat16``; every
reduction that decides stability (norm denominators, softmax, logsumexp,
router probabilities) is computed in ``float32`` and cast back.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e9  # additive-mask value; safe in fp32 softmax accumulators


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * weight.astype(jnp.float32)).astype(dtype)


def head_rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head qk-norm (qwen3): normalize the trailing head_dim."""
    return rms_norm(x, weight, eps)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------
def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": functools.partial(jax.nn.gelu, approximate=True),
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array, act: str = "silu") -> jax.Array:
    """Gated FFN used by every assigned dense architecture."""
    f = activation(act)
    h = f(x @ w_gate) * (x @ w_up)
    return h @ w_down


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for the rotate-half RoPE convention (fp32)."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim // 2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)
    angles = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Tuple[int, int, int] = (1, 1, 2)) -> jax.Array:
    """Multimodal RoPE (qwen2-vl): 3 position streams (t, h, w) rotate
    disjoint sections of the head dimension.

    x: (..., seq, heads, head_dim); positions: (..., seq, 3).
    ``sections`` gives relative widths of the (t, h, w) frequency bands.
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    total = sum(sections)
    widths = [half * s // total for s in sections]
    widths[-1] = half - sum(widths[:-1])
    inv_freq = rope_frequencies(head_dim, theta)
    # build a per-frequency position by selecting the section's stream
    section_id = jnp.concatenate([
        jnp.full((w,), i, dtype=jnp.int32) for i, w in enumerate(widths)])
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),
        jnp.broadcast_to(section_id, positions.shape[:-1] + (half,)).astype(jnp.int32),
        axis=-1)  # (..., seq, half): per-frequency position stream
    angles = pos * inv_freq            # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]  # add heads axis
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------
def cross_entropy(logits: jax.Array, labels: jax.Array, vocab_size: int,
                  z_loss: float = 0.0,
                  mask: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Next-token CE over a (possibly padded) vocab dimension.

    ``logits``: (..., V_padded) bf16; ``labels``: (...) int32 < vocab_size.
    Padded vocab columns are masked additively before the fp32 logsumexp.
    One-hot contraction (iota==label fusion) instead of gather keeps the
    vocab dimension sharded under SPMD.
    Returns (mean loss, mean z-term).
    """
    vpad = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vpad != vocab_size:
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(col < vocab_size, logits, NEG_INF)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, vpad, dtype=jnp.float32)
    label_logit = jnp.sum(logits * onehot, axis=-1)
    nll = lse - label_logit
    z = jnp.square(lse)
    if mask is not None:
        denom = jnp.maximum(mask.sum(), 1.0)
        loss = jnp.sum(nll * mask) / denom
        zterm = jnp.sum(z * mask) / denom
    else:
        loss = jnp.mean(nll)
        zterm = jnp.mean(z)
    return loss + z_loss * zterm, zterm
