"""The decoder LM stack: one composable implementation, ten architectures.

Families and their blocks:
  dense  (yi-34b, qwen2-0.5b, qwen3-1.7b, granite-3-8b):  GQA + SwiGLU
  moe    (phi3.5-moe):                                    GQA + MoE
  moe+mla(deepseek-v3):            MLA + MoE(shared expert) + MTP head
  hybrid (recurrentgemma-2b):      (RG-LRU, RG-LRU, local-attn) pattern + GeGLU
  ssm    (rwkv6-7b):               time-mix + channel-mix (attention-free)
  audio  (musicgen-large):         GQA over precomputed frame embeddings,
                                   4 parallel codebook heads
  vlm    (qwen2-vl-2b):            GQA + M-RoPE over [patch; text] stream

Engineering choices that matter at scale:
  * homogeneous layer stacks are *scanned* (stacked params, one layer HLO)
    — compile time and HLO size stay O(1) in depth; remat wraps the body.
  * all head counts / vocab sizes arrive TP-padded from core.config (exact
    zero-padding — see PaddedDims docstring).
  * sequence-parallel residual stream: optional sharding constraint
    P(data, "model", None) between blocks.
  * decode caches are stacked along layers and scanned jointly with params.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.config import (ArchConfig, AttentionKind, PaddedDims,
                               RopeKind, ShapeConfig, StepKind)
from repro.core.params import ParamDef, pdef
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.layers import cross_entropy, rms_norm, swiglu


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _stack_schema(schema: Dict[str, Any], n: int) -> Dict[str, Any]:
    """Prepend a scanned 'layers' dimension to every ParamDef."""
    def rec(node):
        out = {}
        for k, v in node.items():
            if isinstance(v, ParamDef):
                out[k] = pdef((n,) + v.shape, ("layers",) + v.axes, v.init,
                              v.scale, v.dtype)
            else:
                out[k] = rec(v)
        return out
    return rec(schema)


def _maybe_constrain(x: jax.Array, spec: Optional[P]) -> jax.Array:
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):  # no mesh context (CPU smoke tests)
        return x


def _mlp_schema(arch: ArchConfig, padded: PaddedDims,
                d_ff: Optional[int] = None) -> Dict[str, Any]:
    d = arch.d_model
    f = d_ff if d_ff is not None else padded.d_ff
    return {
        "w_gate": pdef((d, f), ("embed", "ff"), "scaled"),
        "w_up": pdef((d, f), ("embed", "ff"), "scaled"),
        "w_down": pdef((f, d), ("ff", "embed"), "scaled"),
    }


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------
class LMModel:
    """Pure-functional model: schema + apply functions, no owned state."""

    def __init__(self, arch: ArchConfig, tp: int = 1, *,
                 sequence_parallel: bool = False,
                 data_axes: Tuple[str, ...] = ("data",),
                 kernel_mode: Optional[str] = None,
                 remat: str = "block", unroll_layers: bool = False,
                 moe_mesh=None, expert_axes: Tuple[str, ...] = ("model",),
                 cache_dtype=jnp.bfloat16):
        self.arch = arch
        self.tp = tp
        self.padded = PaddedDims.for_tp(arch, tp)
        self.kernel_mode = kernel_mode
        self.remat = remat
        # unroll_layers: python-loop the stack instead of lax.scan — used by
        # the dry-run's cost calibration (XLA cost_analysis counts a scan
        # body once; unrolled shallow variants let us recover per-layer cost)
        self.unroll_layers = unroll_layers
        # moe_mesh != None selects the shard_map expert-parallel dispatch
        # (requires the SP token layout); decode always uses the gather path
        self.moe_mesh = moe_mesh
        self.expert_axes = expert_axes
        self.cache_dtype = cache_dtype
        dp = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
        self.sp_spec = P(dp, "model", None) if sequence_parallel else None
        self.act_spec = P(dp, None, None)

    # ------------------------------------------------------------------ --
    # schema
    # ----------------------------------------------------------------------
    def _attn_schema(self) -> Dict[str, Any]:
        if self.arch.attention == AttentionKind.MLA:
            return attn_mod.mla_schema(self.arch, self.padded)
        return attn_mod.gqa_schema(self.arch, self.padded)

    def _layer_schema(self, kind: str) -> Dict[str, Any]:
        arch, padded = self.arch, self.padded
        d = arch.d_model
        ln = lambda: pdef((d,), ("embed",), "ones")
        if kind == "rwkv":
            # channel-mix params (cm_*) live inside rwkv_schema
            return {"ln1": ln(), "tm": rwkv_mod.rwkv_schema(arch),
                    "ln2": ln()}
        if kind == "rglru":
            return {"ln1": ln(), "rglru": rglru_mod.rglru_schema(arch),
                    "ln2": ln(), "mlp": _mlp_schema(arch, padded)}
        if kind == "local_attn":
            return {"ln1": ln(), "attn": self._attn_schema(),
                    "ln2": ln(), "mlp": _mlp_schema(arch, padded)}
        if kind == "moe":
            expert_axis = "expert"
            return {"ln1": ln(), "attn": self._attn_schema(),
                    "ln2": ln(), "moe": moe_mod.moe_schema(arch, expert_axis)}
        # dense (leading dense layers of an MoE stack may override d_ff)
        d_ff = None
        if arch.moe is not None and arch.moe.dense_d_ff is not None:
            from repro.core.config import pad_to
            d_ff = pad_to(arch.moe.dense_d_ff, self.tp)
        return {"ln1": ln(), "attn": self._attn_schema(),
                "ln2": ln(), "mlp": _mlp_schema(arch, padded, d_ff)}

    def _layer_plan(self) -> Dict[str, Any]:
        """Describe the layer stack: scanned groups + unrolled tails."""
        arch = self.arch
        L = arch.n_layers
        if arch.family == "hybrid":
            pat = arch.hybrid.pattern
            n_super = L // len(pat)
            tail = [pat[i % len(pat)] for i in range(n_super * len(pat), L)]
            return {"kind": "hybrid", "n_super": n_super, "pattern": pat,
                    "tail": tail}
        if arch.moe is not None:
            nd = arch.moe.n_dense_layers
            return {"kind": "moe", "n_dense": nd, "n_moe": L - nd}
        if arch.family == "ssm":
            return {"kind": "rwkv", "n": L}
        return {"kind": "dense", "n": L}

    def schema(self) -> Dict[str, Any]:
        arch, padded = self.arch, self.padded
        d, Vp = arch.d_model, padded.vocab_size
        s: Dict[str, Any] = {}
        if arch.n_codebooks:
            s["embed_codes"] = pdef((arch.n_codebooks, Vp, d),
                                    (None, "vocab", "embed"))
            s["head_codes"] = pdef((arch.n_codebooks, d, Vp),
                                   (None, "embed", "vocab"), "scaled")
        else:
            s["embed"] = pdef((Vp, d), ("vocab", "embed"))
            if not arch.tie_embeddings:
                s["lm_head"] = pdef((d, Vp), ("embed", "vocab"), "scaled")
        s["final_norm"] = pdef((d,), ("embed",), "ones")

        plan = self._layer_plan()
        if plan["kind"] == "hybrid":
            super_schema = {f"sub{i}": self._layer_schema(k)
                            for i, k in enumerate(plan["pattern"])}
            s["blocks"] = _stack_schema(super_schema, plan["n_super"])
            for i, k in enumerate(plan["tail"]):
                s[f"tail{i}"] = self._layer_schema(k)
        elif plan["kind"] == "moe":
            if plan["n_dense"]:
                s["dense_blocks"] = _stack_schema(
                    self._layer_schema("dense"), plan["n_dense"])
            s["blocks"] = _stack_schema(self._layer_schema("moe"),
                                        plan["n_moe"])
        elif plan["kind"] == "rwkv":
            s["blocks"] = _stack_schema(self._layer_schema("rwkv"), plan["n"])
        else:
            s["blocks"] = _stack_schema(self._layer_schema("dense"), plan["n"])

        if arch.mtp:
            s["mtp"] = {
                "proj": pdef((2 * d, d), (None, "embed"), "scaled"),
                "norm_h": pdef((d,), ("embed",), "ones"),
                "norm_e": pdef((d,), ("embed",), "ones"),
                "layer": self._layer_schema("dense"),
            }
        return s

    # ----------------------------------------------------------------------
    # blocks (full sequence)
    # ----------------------------------------------------------------------
    def _block_fwd(self, kind: str, p: Dict[str, Any], x: jax.Array,
                   positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Returns (x_out, aux_loss)."""
        arch = self.arch
        aux = jnp.zeros((), jnp.float32)
        x = _maybe_constrain(x, self.sp_spec)
        h = rms_norm(x, p["ln1"], arch.norm_eps)
        if kind == "rwkv":
            mix = rwkv_mod.time_mix_forward(p["tm"], h, arch, self.kernel_mode)
        elif kind == "rglru":
            mix = rglru_mod.rglru_forward(p["rglru"], h, arch, self.kernel_mode)
        elif arch.attention == AttentionKind.MLA:
            mix = attn_mod.mla_forward(p["attn"], h, arch, positions=positions,
                                       kernel_mode=self.kernel_mode)
        else:
            window = arch.hybrid.window if (kind == "local_attn" and arch.hybrid) else None
            mix = attn_mod.gqa_forward(p["attn"], h, arch, positions=positions,
                                       window=window,
                                       kernel_mode=self.kernel_mode)
        x = x + mix
        x = _maybe_constrain(x, self.sp_spec)
        h = rms_norm(x, p["ln2"], arch.norm_eps)
        if kind == "rwkv":
            y = rwkv_mod.channel_mix_forward(p["tm"], h)
        elif kind == "moe":
            if self.moe_mesh is not None and self.sp_spec is not None:
                y, aux = moe_mod.moe_forward_sharded(
                    p["moe"], h, arch, mesh=self.moe_mesh,
                    expert_axes=self.expert_axes, token_spec=self.sp_spec)
                if arch.moe.n_shared_experts:
                    y = y + moe_mod.shared_expert_forward(p["moe"], h, arch)
            else:
                y, aux = moe_mod.moe_forward(p["moe"], h, arch)
        else:
            y = swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"], arch.act)
        # constrain the block OUTPUT too: the scan carry (= saved remat
        # residual) must live sequence-sharded, not gathered — this is what
        # keeps 60-layer residual storage at 1/TP of the naive footprint
        return _maybe_constrain(x + y, self.sp_spec), aux

    def _scan_blocks(self, blocks: Dict[str, Any], x: jax.Array,
                     positions: jax.Array, kind: str) -> Tuple[jax.Array, jax.Array]:
        def body(carry, lp):
            x, aux = carry
            x, a = self._block_fwd(kind, lp, x, positions)
            return (x, aux + a), None
        if self.remat != "none":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        init = (x, jnp.zeros((), jnp.float32))
        if self.unroll_layers:
            n = jax.tree.leaves(blocks)[0].shape[0]
            carry = init
            for i in range(n):
                carry, _ = body(carry, jax.tree.map(lambda p: p[i], blocks))
            return carry
        (x, aux), _ = jax.lax.scan(body, init, blocks)
        return x, aux

    def _scan_hybrid(self, blocks: Dict[str, Any], x, positions, pattern):
        def body(carry, lp):
            x, aux = carry
            for i, kind in enumerate(pattern):
                x, a = self._block_fwd(kind, lp[f"sub{i}"], x, positions)
                aux = aux + a
            return (x, aux), None
        if self.remat != "none":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        init = (x, jnp.zeros((), jnp.float32))
        if self.unroll_layers:
            n = jax.tree.leaves(blocks)[0].shape[0]
            carry = init
            for i in range(n):
                carry, _ = body(carry, jax.tree.map(lambda p: p[i], blocks))
            return carry
        (x, aux), _ = jax.lax.scan(body, init, blocks)
        return x, aux

    # ----------------------------------------------------------------------
    # embedding / head
    # ----------------------------------------------------------------------
    def _embed(self, params: Dict[str, Any], batch: Dict[str, Any]) -> jax.Array:
        arch = self.arch
        if arch.n_codebooks:
            # audio stub: precomputed frame embeddings (EnCodec frontend)
            return batch["embeds"]
        tok = jnp.take(params["embed"], batch["tokens"], axis=0)
        if arch.vlm and "patch_embeds" in batch:
            tok = jnp.concatenate(
                [batch["patch_embeds"].astype(tok.dtype), tok], axis=1)
        if arch.family == "hybrid":
            tok = tok * jnp.asarray(arch.d_model ** 0.5, tok.dtype)
        return tok

    def _positions(self, batch: Dict[str, Any], seq_len: int) -> jax.Array:
        arch = self.arch
        if arch.rope == RopeKind.MROPE:
            if "patch_pos" in batch:
                B, Ptch = batch["patch_pos"].shape[:2]
                n_text = seq_len - Ptch
                t = Ptch + jnp.arange(n_text)
                text_pos = jnp.broadcast_to(t[None, :, None], (B, n_text, 3))
                return jnp.concatenate(
                    [batch["patch_pos"], text_pos], axis=1).astype(jnp.int32)
            B = batch["tokens"].shape[0]
            t = jnp.arange(seq_len)
            return jnp.broadcast_to(t[None, :, None], (B, seq_len, 3)).astype(jnp.int32)
        return jnp.arange(seq_len)

    def _head(self, params: Dict[str, Any], x: jax.Array) -> jax.Array:
        arch = self.arch
        x = rms_norm(x, params["final_norm"], arch.norm_eps)
        if arch.n_codebooks:
            return jnp.einsum("bsd,cdv->bscv", x, params["head_codes"])
        if arch.tie_embeddings:
            return jnp.einsum("bsd,vd->bsv", x, params["embed"])
        return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])

    # ----------------------------------------------------------------------
    # full forward
    # ----------------------------------------------------------------------
    def forward(self, params: Dict[str, Any], batch: Dict[str, Any]
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Full-sequence pass -> (logits, hidden, aux_loss)."""
        arch = self.arch
        x = self._embed(params, batch)
        x = _maybe_constrain(x, self.act_spec)
        S = x.shape[1]
        positions = self._positions(batch, S)
        plan = self._layer_plan()
        aux = jnp.zeros((), jnp.float32)
        if plan["kind"] == "hybrid":
            x, aux = self._scan_hybrid(params["blocks"], x, positions,
                                       plan["pattern"])
            for i, kind in enumerate(plan["tail"]):
                x, a = self._block_fwd(kind, params[f"tail{i}"], x, positions)
                aux = aux + a
        elif plan["kind"] == "moe":
            if plan["n_dense"]:
                x, a = self._scan_blocks(params["dense_blocks"], x, positions,
                                         "dense")
                aux = aux + a
            x, a = self._scan_blocks(params["blocks"], x, positions, "moe")
            aux = aux + a
        elif plan["kind"] == "rwkv":
            x, aux = self._scan_blocks(params["blocks"], x, positions, "rwkv")
        else:
            x, aux = self._scan_blocks(params["blocks"], x, positions, "dense")
        logits = self._head(params, x)
        return logits, x, aux

    # ----------------------------------------------------------------------
    # losses
    # ----------------------------------------------------------------------
    def loss_fn(self, params: Dict[str, Any], batch: Dict[str, Any],
                z_loss: float = 0.0) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        arch = self.arch
        logits, hidden, aux = self.forward(params, batch)
        labels = batch["labels"]
        if arch.n_codebooks:
            # (B, S, C, V) vs (B, S, C)
            loss, z = cross_entropy(logits, labels, arch.vocab_size, z_loss)
        elif arch.vlm and "patch_embeds" in batch:
            n_text = labels.shape[1]
            text_logits = logits[:, -n_text:]
            loss, z = cross_entropy(text_logits, labels, arch.vocab_size, z_loss)
        else:
            loss, z = cross_entropy(logits, labels, arch.vocab_size, z_loss)
        metrics = {"ce": loss, "aux": aux, "z": z}
        total = loss + aux
        if arch.mtp:
            mtp_loss = self._mtp_loss(params, hidden, batch)
            metrics["mtp"] = mtp_loss
            total = total + 0.3 * mtp_loss
        return total, metrics

    def _mtp_loss(self, params: Dict[str, Any], hidden: jax.Array,
                  batch: Dict[str, Any]) -> jax.Array:
        """DeepSeek multi-token prediction: predict labels[t+1] (= token t+2)
        from [norm(h_t) ; norm(embed(labels_t))] through one extra block."""
        arch = self.arch
        p = params["mtp"]
        labels = batch["labels"]
        e_next = jnp.take(params["embed"], labels, axis=0)
        h = rms_norm(hidden, p["norm_h"], arch.norm_eps)
        e = rms_norm(e_next, p["norm_e"], arch.norm_eps)
        comb = jnp.concatenate([h[:, :-1], e[:, :-1]], axis=-1) @ p["proj"]
        positions = jnp.arange(comb.shape[1])
        comb, _ = self._block_fwd("dense", p["layer"], comb, positions)
        logits = self._head(params, comb)
        loss, _ = cross_entropy(logits, labels[:, 1:], arch.vocab_size)
        return loss

    # ----------------------------------------------------------------------
    # KV / state caches
    # ----------------------------------------------------------------------
    def _layer_cache_spec(self, kind: str, batch: int, cap: int):
        arch, padded = self.arch, self.padded
        if kind == "rwkv":
            return rwkv_mod.rwkv_cache_spec(arch, batch, self.cache_dtype)
        if kind == "rglru":
            return rglru_mod.rglru_cache_spec(arch, batch, self.cache_dtype)
        if arch.attention == AttentionKind.MLA:
            return attn_mod.mla_cache_spec(arch, batch, cap, self.cache_dtype)
        if kind == "local_attn":
            cap = min(cap, arch.hybrid.window)
        return attn_mod.gqa_cache_spec(arch, padded, batch, cap,
                                       self.cache_dtype)

    def _layer_cache_axes(self, kind: str):
        arch = self.arch
        if kind == "rwkv":
            return rwkv_mod.CACHE_AXES_RWKV
        if kind == "rglru":
            return rglru_mod.CACHE_AXES_RGLRU
        if arch.attention == AttentionKind.MLA:
            return attn_mod.CACHE_AXES_MLA
        return attn_mod.CACHE_AXES_GQA

    def _stack_struct(self, spec, n):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), spec,
            is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct))

    def cache_spec(self, batch: int, cap: int) -> Dict[str, Any]:
        plan = self._layer_plan()
        out: Dict[str, Any] = {"len": jax.ShapeDtypeStruct((batch,), jnp.int32)}
        if plan["kind"] == "hybrid":
            super_spec = {f"sub{i}": self._layer_cache_spec(k, batch, cap)
                          for i, k in enumerate(plan["pattern"])}
            out["blocks"] = self._stack_struct(super_spec, plan["n_super"])
            for i, k in enumerate(plan["tail"]):
                out[f"tail{i}"] = self._layer_cache_spec(k, batch, cap)
        elif plan["kind"] == "moe":
            if plan["n_dense"]:
                out["dense_blocks"] = self._stack_struct(
                    self._layer_cache_spec("dense", batch, cap), plan["n_dense"])
            out["blocks"] = self._stack_struct(
                self._layer_cache_spec("moe", batch, cap), plan["n_moe"])
        elif plan["kind"] == "rwkv":
            out["blocks"] = self._stack_struct(
                self._layer_cache_spec("rwkv", batch, cap), plan["n"])
        else:
            out["blocks"] = self._stack_struct(
                self._layer_cache_spec("dense", batch, cap), plan["n"])
        return out

    def cache_axes(self) -> Dict[str, Any]:
        plan = self._layer_plan()
        def stacked(axes_map):
            return {k: ("layers",) + v for k, v in axes_map.items()}
        out: Dict[str, Any] = {"len": (None,)}
        if plan["kind"] == "hybrid":
            out["blocks"] = {f"sub{i}": stacked(self._layer_cache_axes(k))
                             for i, k in enumerate(plan["pattern"])}
            for i, k in enumerate(plan["tail"]):
                out[f"tail{i}"] = self._layer_cache_axes(k)
        elif plan["kind"] == "moe":
            if plan["n_dense"]:
                out["dense_blocks"] = stacked(self._layer_cache_axes("dense"))
            out["blocks"] = stacked(self._layer_cache_axes("moe"))
        else:
            out["blocks"] = stacked(self._layer_cache_axes(plan["kind"]))
        return out

    def init_cache(self, batch: int, cap: int, fill_len: int = 0) -> Dict[str, Any]:
        spec = self.cache_spec(batch, cap)
        cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec,
                             is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct))
        cache["len"] = jnp.full((batch,), fill_len, jnp.int32)
        return cache

    # ----------------------------------------------------------------------
    # decode
    # ----------------------------------------------------------------------
    def _block_decode(self, kind: str, p, x, cache, cache_len):
        arch = self.arch
        x_in = x
        h = rms_norm(x, p["ln1"], arch.norm_eps)
        if kind == "rwkv":
            mix, cache = rwkv_mod.time_mix_decode(p["tm"], h, cache, arch)
        elif kind == "rglru":
            mix, cache = rglru_mod.rglru_decode(p["rglru"], h, cache, arch)
        elif arch.attention == AttentionKind.MLA:
            dp = self.act_spec[0] if self.act_spec is not None else None
            mix, cache = attn_mod.mla_decode(
                p["attn"], h, cache, cache_len, arch,
                score_spec=P(dp, "model", None))
        elif kind == "local_attn":
            # window-sized ring buffer: constant memory in context length
            mix, cache = attn_mod.gqa_decode(p["attn"], h, cache, cache_len,
                                             arch, ring=True)
        else:
            mix, cache = attn_mod.gqa_decode(p["attn"], h, cache, cache_len,
                                             arch, window=None)
        x = x_in + mix
        h = rms_norm(x, p["ln2"], arch.norm_eps)
        if kind == "rwkv":
            y, cache = rwkv_mod.channel_mix_decode(p["tm"], h, cache)
        elif kind == "moe":
            y, _ = moe_mod.moe_forward(p["moe"], h, arch)
        else:
            y = swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"], arch.act)
        return x + y, cache

    def decode_step(self, params: Dict[str, Any], cache: Dict[str, Any],
                    batch: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
        """One-token serve step. batch['tokens']: (B, 1) (or embeds)."""
        arch = self.arch
        cache_len = cache["len"]
        if arch.n_codebooks:
            if "embeds" in batch:
                x = batch["embeds"]
            else:  # (B, 1, C) codes -> summed codebook embeddings
                codes = batch["codes"]
                x = jnp.einsum("bscd->bsd", jnp.stack([
                    jnp.take(params["embed_codes"][c], codes[..., c], axis=0)
                    for c in range(arch.n_codebooks)], axis=2))
        else:
            x = jnp.take(params["embed"], batch["tokens"], axis=0)
            if arch.family == "hybrid":
                x = x * jnp.asarray(arch.d_model ** 0.5, x.dtype)

        plan = self._layer_plan()
        new_cache: Dict[str, Any] = {"len": cache_len + 1}

        def scan_or_unroll(body, x, xs):
            if not self.unroll_layers:
                return jax.lax.scan(body, x, xs)
            n = jax.tree.leaves(xs)[0].shape[0]
            outs = []
            for i in range(n):
                x, o = body(x, jax.tree.map(lambda p: p[i], xs))
                outs.append(o)
            stacked = jax.tree.map(lambda *ys: jnp.stack(ys), *outs)
            return x, stacked

        if plan["kind"] == "hybrid":
            def body(carry, xs):
                x = carry
                lp, lc = xs
                out_c = {}
                for i, kind in enumerate(plan["pattern"]):
                    x, out_c[f"sub{i}"] = self._block_decode(
                        kind, lp[f"sub{i}"], x, lc[f"sub{i}"], cache_len)
                return x, out_c
            x, nc = scan_or_unroll(body, x, (params["blocks"], cache["blocks"]))
            new_cache["blocks"] = nc
            for i, kind in enumerate(plan["tail"]):
                x, c = self._block_decode(kind, params[f"tail{i}"], x,
                                          cache[f"tail{i}"], cache_len)
                new_cache[f"tail{i}"] = c
        else:
            groups = []
            if plan["kind"] == "moe" and plan["n_dense"]:
                groups.append(("dense_blocks", "dense"))
            groups.append(("blocks", {"moe": "moe", "rwkv": "rwkv",
                                      "dense": "dense"}[plan["kind"]]))
            for key, kind in groups:
                def body(carry, xs, kind=kind):
                    x = carry
                    lp, lc = xs
                    x, c = self._block_decode(kind, lp, x, lc, cache_len)
                    return x, c
                x, nc = scan_or_unroll(body, x, (params[key], cache[key]))
                new_cache[key] = nc

        logits = self._head(params, x)
        return logits, new_cache

    # ----------------------------------------------------------------------
    # prefill: full pass that also fills the cache (GQA/MLA only for now;
    # recurrent families fill via their scan final states)
    # ----------------------------------------------------------------------
    def prefill(self, params: Dict[str, Any], batch: Dict[str, Any]
                ) -> Tuple[jax.Array, jax.Array]:
        """Returns (last-token logits, aux). Cache extraction for serving is
        handled by runtime.serve_loop (which re-runs blocks capturing K/V);
        the dry-run prefill cell lowers this full forward."""
        logits, _, aux = self.forward(params, batch)
        return logits[:, -1:], aux


def build_model(arch: ArchConfig, tp: int = 1, **kw) -> LMModel:
    return LMModel(arch, tp, **kw)
