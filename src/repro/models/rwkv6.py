"""RWKV6 "Finch" blocks (arXiv:2404.05892): attention-free time mixing with
data-dependent decay + channel mixing.

Time mixing (per layer):
    sx      = shift(x) - x                      (token shift delta)
    base    = x + sx * mu_x
    deltas  = tanh(base @ W1) @ W2              (5 x LoRA: per-channel mixes)
    x_z     = x + sx * (mu_z + delta_z)         for z in {w, k, v, r, g}
    w       = exp(-exp(w0 + tanh(x_w @ A) @ B)) data-dependent decay (0,1)
    r,k,v   = projections; g = SiLU gate
    y       = WKV6 scan over heads of size N    (kernels/rwkv6_scan)
    out     = (GroupNorm_head(y) * g) @ Wo

Channel mixing:
    x_k = x + sx * mu_ck ; x_r = x + sx * mu_cr
    out = sigmoid(x_r @ Wr) * (relu(x_k @ Wk)^2 @ Wv)

Decode state per layer: WKV state (B, H, N, N) fp32 + the last token
(B, d) for the shift — O(d^2/heads) total, independent of context length
(the long_500k enabler).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ArchConfig, RWKVConfig
from repro.core.params import pdef
from repro.kernels.rwkv6_scan import wkv6, wkv6_step

_MIX_KINDS = ("w", "k", "v", "r", "g")


def rwkv_schema(arch: ArchConfig) -> Dict[str, Any]:
    r = arch.rwkv or RWKVConfig()
    d, dff = arch.d_model, arch.d_ff
    H = d // r.head_size
    s: Dict[str, Any] = {
        "mu_x": pdef((d,), ("embed",), "uniform", 0.5),
        "mix_w1": pdef((d, 5 * r.mix_lora), ("embed", "lora"), "scaled"),
        "mix_w2": pdef((5, r.mix_lora, d), (None, "lora", "embed"), "scaled"),
        "decay_w0": pdef((d,), ("embed",), "uniform", 0.5),
        "decay_w1": pdef((d, r.decay_lora), ("embed", "lora"), "scaled"),
        "decay_w2": pdef((r.decay_lora, d), ("lora", "embed"), "scaled"),
        "bonus_u": pdef((H, r.head_size), ("rwkv_heads", "head_dim"), "uniform", 0.5),
        "w_r": pdef((d, d), ("embed", "d_rnn"), "scaled"),
        "w_k": pdef((d, d), ("embed", "d_rnn"), "scaled"),
        "w_v": pdef((d, d), ("embed", "d_rnn"), "scaled"),
        "w_g": pdef((d, d), ("embed", "d_rnn"), "scaled"),
        "w_o": pdef((d, d), ("d_rnn", "embed"), "scaled"),
        "ln_x_scale": pdef((d,), ("embed",), "ones"),
        "ln_x_bias": pdef((d,), ("embed",), "zeros"),
        "cm_mu_k": pdef((d,), ("embed",), "uniform", 0.5),
        "cm_mu_r": pdef((d,), ("embed",), "uniform", 0.5),
        "cm_wk": pdef((d, dff), ("embed", "ff"), "scaled"),
        "cm_wv": pdef((dff, d), ("ff", "embed"), "scaled"),
        "cm_wr": pdef((d, d), ("embed", "d_rnn"), "scaled"),
    }
    for kind in _MIX_KINDS:
        s[f"mu_{kind}"] = pdef((d,), ("embed",), "uniform", 0.5)
    return s


def _group_norm(y: jax.Array, scale: jax.Array, bias: jax.Array,
                n_heads: int, eps: float = 64e-5) -> jax.Array:
    """Per-head group norm over the flattened (H*N) channel dim."""
    shp = y.shape
    yh = y.reshape(shp[:-1] + (n_heads, shp[-1] // n_heads)).astype(jnp.float32)
    mean = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + eps)
    out = yh.reshape(shp) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out


def _mixes(p: Dict[str, Any], x: jax.Array, sx: jax.Array):
    """Data-dependent token-shift mixes for (w, k, v, r, g)."""
    base = x + sx * p["mu_x"]
    lora = jnp.tanh(base @ p["mix_w1"])                   # (..., 5*L)
    L = p["mix_w2"].shape[1]
    lora = lora.reshape(lora.shape[:-1] + (5, L))
    deltas = jnp.einsum("...zl,zld->...zd", lora, p["mix_w2"])
    out = {}
    for i, kind in enumerate(_MIX_KINDS):
        out[kind] = x + sx * (p[f"mu_{kind}"] + deltas[..., i, :])
    return out


def _decay(p: Dict[str, Any], xw: jax.Array) -> jax.Array:
    dd = jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]
    log_w = -jnp.exp(
        jnp.clip(p["decay_w0"].astype(jnp.float32) + dd.astype(jnp.float32),
                 -8.0, 8.0))
    return jnp.exp(log_w)                                 # (0, 1)


def time_mix_forward(p: Dict[str, Any], x: jax.Array, arch: ArchConfig,
                     kernel_mode: Optional[str] = None) -> jax.Array:
    """Full-sequence time mixing. x: (B, S, d)."""
    r_cfg = arch.rwkv or RWKVConfig()
    B, S, d = x.shape
    H, N = d // r_cfg.head_size, r_cfg.head_size
    shifted = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    sx = shifted - x
    mixes = _mixes(p, x, sx)
    w = _decay(p, mixes["w"]).reshape(B, S, H, N)
    r = (mixes["r"] @ p["w_r"]).reshape(B, S, H, N)
    k = (mixes["k"] @ p["w_k"]).reshape(B, S, H, N)
    v = (mixes["v"] @ p["w_v"]).reshape(B, S, H, N)
    g = jax.nn.silu(mixes["g"] @ p["w_g"])
    y, _ = wkv6(r, k, v, w, p["bonus_u"], mode=kernel_mode)
    y = _group_norm(y.reshape(B, S, d), p["ln_x_scale"], p["ln_x_bias"], H)
    return (y.astype(x.dtype) * g) @ p["w_o"]


def channel_mix_forward(p: Dict[str, Any], x: jax.Array) -> jax.Array:
    shifted = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    sx = shifted - x
    xk = x + sx * p["cm_mu_k"]
    xr = x + sx * p["cm_mu_r"]
    h = jnp.square(jax.nn.relu(xk @ p["cm_wk"])) @ p["cm_wv"]
    return jax.nn.sigmoid(xr @ p["cm_wr"]) * h


def rwkv_cache_spec(arch: ArchConfig, batch: int,
                    dtype=jnp.bfloat16) -> Dict[str, Any]:
    r = arch.rwkv or RWKVConfig()
    d = arch.d_model
    H, N = d // r.head_size, r.head_size
    return {
        "wkv": jax.ShapeDtypeStruct((batch, H, N, N), jnp.float32),
        "shift_tm": jax.ShapeDtypeStruct((batch, d), dtype),
        "shift_cm": jax.ShapeDtypeStruct((batch, d), dtype),
    }


CACHE_AXES_RWKV = {
    "wkv": ("batch", "rwkv_heads", "head_dim", None),
    "shift_tm": ("batch", None),
    "shift_cm": ("batch", None),
}


def rwkv_init_cache(arch: ArchConfig, batch: int) -> Dict[str, Any]:
    spec = rwkv_cache_spec(arch, batch)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec,
                        is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct))


def time_mix_decode(p: Dict[str, Any], x: jax.Array, cache: Dict[str, Any],
                    arch: ArchConfig) -> Tuple[jax.Array, Dict[str, Any]]:
    """One-step time mixing. x: (B, 1, d)."""
    r_cfg = arch.rwkv or RWKVConfig()
    B, _, d = x.shape
    H, N = d // r_cfg.head_size, r_cfg.head_size
    xt = x[:, 0]
    sx = (cache["shift_tm"].astype(xt.dtype) - xt)[:, None]
    mixes = _mixes(p, x, sx)
    w = _decay(p, mixes["w"]).reshape(B, H, N)
    r = (mixes["r"] @ p["w_r"]).reshape(B, H, N)
    k = (mixes["k"] @ p["w_k"]).reshape(B, H, N)
    v = (mixes["v"] @ p["w_v"]).reshape(B, H, N)
    g = jax.nn.silu(mixes["g"] @ p["w_g"])[:, 0]
    y, wkv_state = wkv6_step(r, k, v, w, p["bonus_u"], cache["wkv"])
    y = _group_norm(y.reshape(B, d), p["ln_x_scale"], p["ln_x_bias"], H)
    out = ((y.astype(xt.dtype) * g) @ p["w_o"])[:, None]
    new_cache = dict(cache)
    new_cache["wkv"] = wkv_state
    new_cache["shift_tm"] = xt.astype(cache["shift_tm"].dtype)
    return out, new_cache


def channel_mix_decode(p: Dict[str, Any], x: jax.Array,
                       cache: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
    xt = x[:, 0]
    sx = (cache["shift_cm"].astype(xt.dtype) - xt)[:, None]
    xk = x + sx * p["cm_mu_k"]
    xr = x + sx * p["cm_mu_r"]
    h = jnp.square(jax.nn.relu(xk @ p["cm_wk"])) @ p["cm_wv"]
    out = jax.nn.sigmoid(xr @ p["cm_wr"]) * h
    new_cache = dict(cache)
    new_cache["shift_cm"] = xt.astype(cache["shift_cm"].dtype)
    return out, new_cache
