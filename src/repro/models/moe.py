"""Mixture-of-Experts FFN: top-k token-choice routing, sort-based dispatch.

TPU-native adaptation: instead of per-token pointer chasing (GPU style
scatter into expert queues), tokens are argsorted by expert id and packed
into a dense (E, capacity, d) buffer — all gathers/scatters are large,
contiguous, MXU-feedable ops, and expert FFNs run as one grouped einsum.

Sharding: the expert dimension of the stacked weights carries the logical
axis "expert", mapped to "model" (phi: 16 experts / 16-way TP = 1 expert per
TP rank) or ("data","model") for deepseek-scale EP (256 experts / 256 chips).
Dispatch then lowers to all-to-alls under SPMD; the explicit shard_map
variant is a §Perf hillclimb (see EXPERIMENTS.md).

Aux losses follow the standard load-balancing formulation
(mean_prob_per_expert x token_fraction_per_expert x E).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ArchConfig, MoEConfig
from repro.core.params import pdef
from repro.models.layers import activation


def moe_schema(arch: ArchConfig, expert_axis: str = "expert") -> Dict[str, Any]:
    m = arch.moe
    d, de = arch.d_model, m.d_expert
    E = m.n_experts
    s = {
        "router": pdef((d, E), ("embed", None), "scaled"),
        "w_gate": pdef((E, d, de), (expert_axis, "embed", "expert_ff"), "scaled"),
        "w_up": pdef((E, d, de), (expert_axis, "embed", "expert_ff"), "scaled"),
        "w_down": pdef((E, de, d), (expert_axis, "expert_ff", "embed"), "scaled"),
    }
    if m.n_shared_experts:
        dsh = de * m.n_shared_experts
        s["shared_gate"] = pdef((d, dsh), ("embed", "ff"), "scaled")
        s["shared_up"] = pdef((d, dsh), ("embed", "ff"), "scaled")
        s["shared_down"] = pdef((dsh, d), ("ff", "embed"), "scaled")
    return s


def _capacity(n_tokens: int, moe: MoEConfig) -> int:
    per_expert = n_tokens * moe.top_k / moe.n_experts
    cap = int(per_expert * moe.capacity_factor)
    return max(8, (cap + 7) // 8 * 8)


def shared_expert_forward(p: Dict[str, Any], x: jax.Array,
                          arch: ArchConfig) -> jax.Array:
    """Always-on (deepseek) shared experts — a plain TP FFN, computed
    outside the routed dispatch."""
    f = activation(arch.act)
    sh = f(x @ p["shared_gate"]) * (x @ p["shared_up"])
    return sh @ p["shared_down"]


def moe_forward(p: Dict[str, Any], x: jax.Array, arch: ArchConfig, *,
                capacity: Optional[int] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    m = arch.moe
    B, S, d = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    C = capacity or _capacity(T, m)
    xt = x.reshape(T, d)

    # --- routing (fp32) ----------------------------------------------------
    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                       # (T, E)
    gates, ids = jax.lax.top_k(probs, K)                          # (T, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # --- aux load-balancing loss -------------------------------------------
    me = probs.mean(axis=0)                                       # (E,)
    one_hot_topk = jax.nn.one_hot(ids, E, dtype=jnp.float32).sum(1)  # (T, E)
    ce = one_hot_topk.mean(axis=0) / K
    aux = (me * ce).sum() * E * m.router_aux_weight

    # --- sort-based dispatch -------------------------------------------------
    flat_e = ids.reshape(T * K)                                   # expert ids
    flat_t = jnp.repeat(jnp.arange(T), K)                         # token ids
    flat_g = gates.reshape(T * K)
    order = jnp.argsort(flat_e)  # stable
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.bincount(flat_e, length=E)                       # (E,)
    starts = jnp.cumsum(counts) - counts                          # exclusive
    pos_in_e = jnp.arange(T * K) - starts[se]
    keep = pos_in_e < C
    slot = jnp.where(keep, se * C + pos_in_e, E * C)              # overflow slot

    buf = jnp.zeros((E * C + 1, d), xt.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], xt[st], 0))
    hidden = buf[:-1].reshape(E, C, d)

    # --- grouped expert FFN --------------------------------------------------
    f = activation(arch.act)
    h = f(jnp.einsum("ecd,edf->ecf", hidden, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", hidden, p["w_up"])
    y_exp = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * C, d)
    y_exp = jnp.concatenate([y_exp, jnp.zeros((1, d), y_exp.dtype)], axis=0)

    # --- combine --------------------------------------------------------------
    contrib = y_exp[slot] * (sg * keep).astype(y_exp.dtype)[:, None]
    out = jnp.zeros((T, d), xt.dtype).at[st].add(contrib)

    # --- shared (always-on) experts ------------------------------------------
    if m.n_shared_experts:
        out = out + shared_expert_forward(p, xt, arch)

    return out.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# shard_map expert parallelism: explicit per-device dispatch + all-to-all
# ---------------------------------------------------------------------------
def moe_forward_sharded(p: Dict[str, Any], x: jax.Array, arch: ArchConfig, *,
                        mesh, expert_axes: Tuple[str, ...],
                        token_spec) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE with explicit communication.

    The jit-SPMD (gather) path above defeats the XLA partitioner: the
    data-dependent scatter/gather dispatch gets replicated per device
    (measured: 255 GB/device temp for deepseek-v3 train — see EXPERIMENTS
    §Perf "before"). This version makes the paper's INTERLEAVE policy
    explicit: every device owns E/n experts, routes its resident tokens
    with a dense (n_shards, capacity) all-to-all, runs its expert FFN on
    what arrives, and routes results back. Per-device memory is
    O(T_local * top_k * capacity_factor * d); wire bytes are 2 passes of
    the routed activations — independent of E.

    Requires the residual stream to be fully sharded over ``mesh`` (batch
    over data, sequence over model — the SP layout), so each token lives on
    exactly one device. ``token_spec`` is that PartitionSpec.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    m = arch.moe
    E, K = m.n_experts, m.top_k
    n_shards = 1
    for a in expert_axes:
        n_shards *= mesh.shape[a]
    if E % n_shards:
        raise ValueError(f"{E} experts not divisible by {n_shards} shards")
    e_local = E // n_shards
    axis = expert_axes if len(expert_axes) > 1 else expert_axes[0]
    f = activation(arch.act)

    def local_fn(router, wg, wu, wd, xb):
        # xb: (B_loc, S_loc, d) — this device's resident tokens
        Bl, Sl, d = xb.shape
        T = Bl * Sl
        xt = xb.reshape(T, d)
        logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, ids = jax.lax.top_k(probs, K)                  # (T, K)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

        # aux loss (global): local partials averaged over the WHOLE mesh
        # (tokens are sharded over every axis under the SP layout)
        all_axes = tuple(mesh.axis_names)
        me = jax.lax.pmean(probs.mean(0), all_axes)
        ce = jax.lax.pmean(
            jax.nn.one_hot(ids, E, dtype=jnp.float32).sum(1).mean(0) / K,
            all_axes)
        aux = (me * ce).sum() * E * m.router_aux_weight

        # ---- route to owning shard -----------------------------------
        flat_e = ids.reshape(T * K)
        flat_t = jnp.repeat(jnp.arange(T), K)
        flat_g = gates.reshape(T * K)
        owner = flat_e // e_local                              # (T*K,)
        cap = max(8, -(-int(T * K / n_shards * m.capacity_factor) // 8) * 8)
        order = jnp.argsort(owner, stable=True)
        so, se, st, sg = (owner[order], flat_e[order], flat_t[order],
                          flat_g[order])
        counts = jnp.bincount(owner, length=n_shards)
        starts = jnp.cumsum(counts) - counts
        slot_idx = starts[:, None] + jnp.arange(cap)[None, :]  # (n, cap)
        valid = jnp.arange(cap)[None, :] < jnp.minimum(counts, cap)[:, None]
        slot_idx = jnp.clip(slot_idx, 0, T * K - 1)
        send_x = jnp.where(valid[..., None], xt[st[slot_idx]], 0)
        send_e = jnp.where(valid, se[slot_idx] % e_local, -1)  # local id
        # token origin slot for the return trip is positional (same layout)

        recv_x = jax.lax.all_to_all(send_x, axis, 0, 0, tiled=True)
        recv_e = jax.lax.all_to_all(send_e, axis, 0, 0, tiled=True)
        # recv_*: (n_shards * cap, ...) after tiled concat? tiled all_to_all
        # keeps leading dim = n_shards * cap / n_shards ... reshape to flat:
        rx = recv_x.reshape(-1, d)
        re = recv_e.reshape(-1)

        # ---- local expert FFN (e_local experts on this device) --------
        y = jnp.zeros((rx.shape[0], d), rx.dtype)
        for le in range(e_local):
            sel = (re == le)[:, None].astype(rx.dtype)
            xin = rx * sel
            h = f(xin @ wg[le]) * (xin @ wu[le])
            y = y + (h @ wd[le]) * sel
        y = y.reshape(recv_x.shape)

        # ---- route back + combine -------------------------------------
        back = jax.lax.all_to_all(y, axis, 0, 0, tiled=True)   # (n, cap, d)
        contrib = jnp.where(valid[..., None], back, 0)
        gsel = (sg[slot_idx] * valid).astype(xt.dtype)
        out = jnp.zeros((T, d), xt.dtype).at[
            st[slot_idx].reshape(-1)
        ].add((contrib * gsel[..., None]).reshape(-1, d))
        return out.reshape(Bl, Sl, d), aux

    espec = P(axis)
    wrapped = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(), espec, espec, espec, token_spec),
        out_specs=(token_spec, P()),
        check_rep=False)
    return wrapped(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)
