"""Composable LM model definitions for all assigned architectures."""
from repro.models.lm import (
    LMModel,
    build_model,
)
