"""Attention blocks: GQA (with qk-norm / bias / local window) and MLA.

Schema + forward are kept together so each block owns its parameter layout.
Head counts arrive already TP-padded (core.config.PaddedDims): padded query
heads have zero Wq rows and zero Wo columns, so padded heads contribute
exactly zero to the output.

KV caches:
  GQA   k/v buffers (B, Smax, KVp, Dh) + scalar lengths (B,)
  MLA   latent cache (B, Smax, kv_lora + rope_dim): decode runs the
        *absorbed* formulation (score and mix directly in latent space),
        prefill/train expand per-head K/V (matmul-friendly). This is the
        memory-optimal MLA serving layout.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ArchConfig, AttentionKind, PaddedDims, RopeKind
from repro.core.params import pdef
from repro.kernels.flash_attention import decode_attention, flash_attention
from repro.models.layers import apply_mrope, apply_rope, head_rms_norm, rms_norm


def _constrain(x, spec):
    """Best-effort sharding constraint (no-op outside a mesh context)."""
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------
def gqa_schema(arch: ArchConfig, padded: PaddedDims) -> Dict[str, Any]:
    d, hd = arch.d_model, arch.resolved_head_dim
    H, KV = padded.n_heads, padded.n_kv_heads
    s = {
        "wq": pdef((d, H, hd), ("embed", "heads", "head_dim"), "scaled"),
        "wk": pdef((d, KV, hd), ("embed", "kv_heads", "head_dim"), "scaled"),
        "wv": pdef((d, KV, hd), ("embed", "kv_heads", "head_dim"), "scaled"),
        "wo": pdef((H, hd, d), ("heads", "head_dim", "embed"), "scaled"),
    }
    if arch.qkv_bias:
        s["bq"] = pdef((H, hd), ("heads", "head_dim"), "zeros")
        s["bk"] = pdef((KV, hd), ("kv_heads", "head_dim"), "zeros")
        s["bv"] = pdef((KV, hd), ("kv_heads", "head_dim"), "zeros")
    if arch.qk_norm:
        s["q_norm"] = pdef((hd,), ("head_dim",), "ones")
        s["k_norm"] = pdef((hd,), ("head_dim",), "ones")
    return s


def _project_qkv(p: Dict[str, Any], x: jax.Array, arch: ArchConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if arch.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if arch.qk_norm:
        q = head_rms_norm(q, p["q_norm"], arch.norm_eps)
        k = head_rms_norm(k, p["k_norm"], arch.norm_eps)
    return q, k, v


def _positions_rope(arch: ArchConfig, q, k, q_positions, k_positions):
    if arch.rope == RopeKind.ROPE:
        q = apply_rope(q, q_positions, arch.rope_theta)
        k = apply_rope(k, k_positions, arch.rope_theta)
    elif arch.rope == RopeKind.MROPE:
        q = apply_mrope(q, q_positions, arch.rope_theta)
        k = apply_mrope(k, k_positions, arch.rope_theta)
    return q, k


def gqa_forward(p: Dict[str, Any], x: jax.Array, arch: ArchConfig, *,
                positions: jax.Array, window: Optional[int] = None,
                kernel_mode: Optional[str] = None) -> jax.Array:
    """Full-sequence (train / prefill) GQA pass. x: (B, S, d)."""
    q, k, v = _project_qkv(p, x, arch)
    q, k = _positions_rope(arch, q, k, positions, positions)
    scale = arch.resolved_head_dim ** -0.5
    # positions may be per-example (B, S) or flat (S,): rope handles both;
    # the kernel needs scalar offsets, contiguous positions assumed.
    out = flash_attention(q, k, v, causal=True, window=window, scale=scale,
                          mode=kernel_mode)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def gqa_init_cache(arch: ArchConfig, padded: PaddedDims, batch: int,
                   max_len: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    hd = arch.resolved_head_dim
    buf_len = min(max_len, arch.max_seq_len)
    return {
        "k": jnp.zeros((batch, buf_len, padded.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, buf_len, padded.n_kv_heads, hd), dtype),
    }


def gqa_cache_spec(arch: ArchConfig, padded: PaddedDims, batch: int,
                   max_len: int, dtype=jnp.bfloat16) -> Dict[str, Any]:
    hd = arch.resolved_head_dim
    return {
        "k": jax.ShapeDtypeStruct((batch, max_len, padded.n_kv_heads, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, max_len, padded.n_kv_heads, hd), dtype),
    }


CACHE_AXES_GQA = {
    "k": ("batch", "seq", "kv_heads", "head_dim"),
    "v": ("batch", "seq", "kv_heads", "head_dim"),
}


def gqa_decode(p: Dict[str, Any], x: jax.Array, cache: Dict[str, Any],
               cache_len: jax.Array, arch: ArchConfig, *,
               window: Optional[int] = None,
               ring: bool = False) -> Tuple[jax.Array, Dict[str, Any]]:
    """One-token decode. x: (B, 1, d); cache_len: (B,) absolute positions.

    ``ring=True`` (local-attention layers): the buffer holds exactly the
    last ``buf`` tokens; the new entry lands at ``pos % buf`` and every
    filled slot is valid (keys are roped at absolute positions, so slot
    order is irrelevant to the attention math). Otherwise the buffer is
    linear and the new entry lands at ``pos``.
    """
    q, k, v = _project_qkv(p, x, arch)
    pos = cache_len[:, None]  # (B, 1) absolute position of the new token
    if arch.rope == RopeKind.MROPE:
        pos3 = jnp.broadcast_to(pos[..., None], pos.shape + (3,))
        q, k = _positions_rope(arch, q, k, pos3, pos3)
    else:
        q, k = _positions_rope(arch, q, k, pos, pos)
    # dynamic_update_slice needs a shared index; serving batches are
    # position-aligned per wave, so use example 0's length (documented).
    buf = cache["k"].shape[1]
    idx = cache_len[0] % buf if ring else cache_len[0]
    new_k = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
    new_v = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
    if ring:
        valid = jnp.minimum(cache_len + 1, buf)
        out = decode_attention(q, new_k, new_v, valid, window=None,
                               scale=arch.resolved_head_dim ** -0.5)
    else:
        out = decode_attention(q, new_k, new_v, cache_len + 1, window=window,
                               scale=arch.resolved_head_dim ** -0.5)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": new_k, "v": new_v}


# ---------------------------------------------------------------------------
# MLA (deepseek-v3)
# ---------------------------------------------------------------------------
def mla_schema(arch: ArchConfig, padded: PaddedDims) -> Dict[str, Any]:
    m = arch.mla
    d, H = arch.d_model, padded.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": pdef((d, m.q_lora_rank), ("embed", "q_lora"), "scaled"),
        "q_a_norm": pdef((m.q_lora_rank,), ("q_lora",), "ones"),
        "wq_b": pdef((m.q_lora_rank, H, qk_head), ("q_lora", "heads", "head_dim"), "scaled"),
        "wkv_a": pdef((d, m.kv_lora_rank + m.qk_rope_head_dim), ("embed", "kv_lora"), "scaled"),
        "kv_a_norm": pdef((m.kv_lora_rank,), ("kv_lora",), "ones"),
        "wk_b": pdef((m.kv_lora_rank, H, m.qk_nope_head_dim), ("kv_lora", "heads", "head_dim"), "scaled"),
        "wv_b": pdef((m.kv_lora_rank, H, m.v_head_dim), ("kv_lora", "heads", "head_dim"), "scaled"),
        "wo": pdef((H, m.v_head_dim, d), ("heads", "head_dim", "embed"), "scaled"),
    }


def _mla_latent(p, x, arch):
    """Shared latent path: returns (c_kv normed, k_rope roped-later)."""
    m = arch.mla
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv, k_rope = kv[..., :m.kv_lora_rank], kv[..., m.kv_lora_rank:]
    c_kv = rms_norm(c_kv, p["kv_a_norm"], arch.norm_eps)
    return c_kv, k_rope


def _mla_queries(p, x, arch):
    m = arch.mla
    cq = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
    cq = rms_norm(cq, p["q_a_norm"], arch.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"])
    return q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]


def mla_forward(p: Dict[str, Any], x: jax.Array, arch: ArchConfig, *,
                positions: jax.Array,
                kernel_mode: Optional[str] = None) -> jax.Array:
    """Train/prefill MLA: expand per-head K/V (matmul-friendly)."""
    m = arch.mla
    q_nope, q_rope = _mla_queries(p, x, arch)
    c_kv, k_rope = _mla_latent(p, x, arch)
    q_rope = apply_rope(q_rope, positions, arch.rope_theta)
    k_rope = apply_rope(k_rope[..., None, :], positions, arch.rope_theta)  # 1 shared head
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"])
    H = q_nope.shape[2]
    k_rope_b = jnp.broadcast_to(k_rope, k_rope.shape[:2] + (H, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    # v is narrower than qk head dim; pad v to qk width then slice back (the
    # kernel assumes uniform D) — zero columns are exact.
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_head - m.v_head_dim)))
    out = flash_attention(q, k, v_pad, causal=True, scale=scale,
                          mode=kernel_mode)[..., :m.v_head_dim]
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def mla_cache_spec(arch: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Dict[str, Any]:
    m = arch.mla
    return {
        "latent": jax.ShapeDtypeStruct(
            (batch, max_len, m.kv_lora_rank + m.qk_rope_head_dim), dtype),
    }


CACHE_AXES_MLA = {"latent": ("batch", "seq", "kv_lora")}


def mla_init_cache(arch: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Dict[str, Any]:
    m = arch.mla
    return {"latent": jnp.zeros(
        (batch, max_len, m.kv_lora_rank + m.qk_rope_head_dim), dtype)}


def mla_decode(p: Dict[str, Any], x: jax.Array, cache: Dict[str, Any],
               cache_len: jax.Array, arch: ArchConfig,
               score_spec=None) -> Tuple[jax.Array, Dict[str, Any]]:
    """Absorbed-MLA decode: score and mix in the 512-d latent space.

    Per head h:  logits = (q_nope[h] @ wk_b[:,h,:].T) . c_kv  +  q_rope . k_rope
                 out[h] = (attn @ c_kv) @ wv_b[:,h,:]
    Memory: O(S * kv_lora) cache, no per-head KV expansion.
    """
    m = arch.mla
    q_nope, q_rope = _mla_queries(p, x, arch)      # (B,1,H,*)
    c_new, kr_new = _mla_latent(p, x, arch)        # (B,1,r), (B,1,rope)
    pos = cache_len[:, None]
    q_rope = apply_rope(q_rope, pos, arch.rope_theta)
    kr_new = apply_rope(kr_new[..., None, :], pos, arch.rope_theta)[..., 0, :]
    new_entry = jnp.concatenate([c_new, kr_new], axis=-1)
    latent = jax.lax.dynamic_update_slice(
        cache["latent"], new_entry.astype(cache["latent"].dtype),
        (0, cache_len[0], 0))
    c_kv = latent[..., :m.kv_lora_rank]            # (B, S, r)
    k_rope = latent[..., m.kv_lora_rank:]          # (B, S, rope)
    # absorb wk_b into q: q_lat (B, H, r)
    q_lat = jnp.einsum("bshk,rhk->bhr", q_nope, p["wk_b"])
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s = (jnp.einsum("bhr,bsr->bhs", q_lat, c_kv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bshk,btk->bhst", q_rope, k_rope,
                      preferred_element_type=jnp.float32)[:, :, 0]) * scale
    # the (B, H, S) score matrix is the decode working set: keep it sharded
    # (batch x heads) or XLA may replicate ~TBs of it at deepseek scale
    s = _constrain(s, score_spec)
    tpos = jnp.arange(latent.shape[1])
    valid = tpos[None, :] < (cache_len + 1)[:, None]
    s = jnp.where(valid[:, None, :], s, -1e30)
    attn = jax.nn.softmax(s, axis=-1)
    out_lat = jnp.einsum("bhs,bsr->bhr", attn,
                         c_kv.astype(jnp.float32))   # (B, H, r)
    out = jnp.einsum("bhr,rhk->bhk", out_lat, p["wv_b"].astype(jnp.float32))
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"].astype(jnp.float32))
    return y[:, None, :].astype(x.dtype), {"latent": latent}
