"""Training launcher (CPU-runnable reduced configs; production flags doc'd).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

On a real TPU fleet the same entry point runs under `jax.distributed` with
the production mesh; recommended XLA flags for overlap (recorded here, they
are inert on CPU):
  --xla_tpu_enable_latency_hiding_scheduler=true
  --xla_tpu_spmd_rng_bit_generator_unsafe=true   (faster dropout RNG)
  --xla_tpu_megacore_fusion_allow_ags=true       (AG overlap)
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.reduced import reduced as make_reduced
from repro.core.config import (LM_SHAPES, PlacementPolicy, RunConfig,
                               ShardingConfig, TrainConfig)
from repro.models.lm import LMModel
from repro.runtime import FailureInjector, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="shrink the config for CPU execution")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--policy", default="interleave",
                    choices=[p.value for p in PlacementPolicy])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject failures at these steps (FT drill)")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if args.reduced:
        arch = make_reduced(arch)
    cfg = RunConfig(
        arch=arch, shape=LM_SHAPES["train_4k"],
        sharding=ShardingConfig(policy=PlacementPolicy(args.policy)),
        train=TrainConfig(learning_rate=args.lr, accum_steps=args.accum,
                          warmup_steps=max(2, args.steps // 10)))
    model = LMModel(arch, tp=1, remat="block")
    injector = FailureInjector(fail_at_steps=args.fail_at) if args.fail_at \
        else None
    res = train(model, cfg, n_steps=args.steps, batch=args.batch,
                seq=args.seq, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every if args.ckpt_dir else 0,
                injector=injector)
    print(json.dumps({
        "arch": arch.name, "steps": res.steps_run,
        "first_loss": res.losses[0] if res.losses else None,
        "final_loss": res.final_loss, "restarts": res.restarts,
    }, indent=2))


if __name__ == "__main__":
    main()
