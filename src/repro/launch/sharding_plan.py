"""Build the full sharding plan for one (arch x shape x mesh x policy) cell.

One place decides every placement:
  params      logical axes -> mesh axes via partitioning rules (TP over
              "model"; MoE experts over "model" or ("data","model") for
              deepseek-scale EP)
  opt state   params plan + the NUMA placement policy (FIRST_TOUCH =
              replicated over data = naive DP; INTERLEAVE = ZeRO-1)
  batch       batch dim over the data axes
  kv cache    batch over data, kv_heads over model, recurrent state ditto
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.config import (ArchConfig, PlacementPolicy, RunConfig,
                               ShapeConfig, StepKind)
from repro.core.params import abstract_params, axes_tree, shapes_tree
from repro.core.partitioning import (policy_state_spec, rules_with, spec_for,
                                     tree_specs, validate_spec)
from repro.models.lm import LMModel
from repro.optim import adamw


def data_axes_for(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def make_rules(cfg: RunConfig, mesh: Mesh) -> Dict[str, Any]:
    overrides: Dict[str, Any] = {}
    if cfg.sharding.expert_parallel_data:
        # EP group = ("data","model") = 256; the pod axis replicates experts
        # (grads all-reduce over "pod" automatically) — 256 experts cannot
        # shard over 512 chips
        overrides["expert"] = ("data", "model")
    if getattr(cfg.sharding, "decode_dshard", False):
        # decode: shard head_dim instead of (padded) heads — removes the
        # kv-head padding waste entirely; per-head dots become partial sums
        # + a psum over "model" (flash-decoding layout)
        overrides["heads"] = None
        overrides["kv_heads"] = None
        overrides["head_dim"] = "model"
        overrides["kv_lora"] = "model"   # MLA latent cache: 576/16 divides
    return rules_with(overrides)


def _dp(mesh: Mesh, strategy: str = "tp"):
    axes = data_axes_for(mesh)
    if strategy == "fsdp":               # batch over EVERY axis
        axes = axes + ("model",)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def _fsdp_spec(shape, mesh: Mesh) -> P:
    """FSDP storage sharding: largest divisible dim over "data", second
    largest over "model" (2D keeps divisibility easy at 16x16). Compute
    gathers parameters per use (XLA inserts the all-gathers)."""
    dims = sorted(range(len(shape)), key=lambda i: -shape[i])
    parts = [None] * len(shape)
    for axis in ("data", "model"):
        size = mesh.shape.get(axis, 1)
        for i in dims:
            if parts[i] is None and shape[i] % size == 0 and shape[i] >= size:
                parts[i] = axis
                break
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_specs(model: LMModel, cfg: RunConfig, mesh: Mesh):
    schema = model.schema()
    if cfg.sharding.strategy == "fsdp":
        shapes = shapes_tree(schema)
        return jax.tree.map(
            lambda shp: _fsdp_spec(shp, mesh), shapes,
            is_leaf=lambda x: isinstance(x, tuple) and
            all(isinstance(e, int) for e in x))
    rules = make_rules(cfg, mesh)
    return tree_specs(axes_tree(schema), rules, mesh, shapes_tree(schema))


def param_shardings(model: LMModel, cfg: RunConfig, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(model, cfg, mesh),
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_shardings(model: LMModel, cfg: RunConfig, mesh: Mesh,
                        params_abs: Any, opt_abs: adamw.AdamWState):
    """Placement policy applied to optimizer moments + master weights."""
    pspecs = param_specs(model, cfg, mesh)
    policy = cfg.sharding.policy

    def state_shard(spec_tree, abs_tree):
        def one(spec, ab):
            s = policy_state_spec(policy, spec, ab.shape, mesh)
            return NamedSharding(mesh, s)
        return jax.tree.map(one, spec_tree, abs_tree,
                            is_leaf=lambda x: isinstance(x, P))

    mu = state_shard(pspecs, opt_abs.mu)
    nu = state_shard(pspecs, opt_abs.nu)
    master = (state_shard(pspecs, opt_abs.master)
              if opt_abs.master is not None else None)
    step = NamedSharding(mesh, P())
    return adamw.AdamWState(step, mu, nu, master)


def batch_specs(arch: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                strategy: str = "tp") -> Dict[str, Any]:
    """ShapeDtypeStructs + shardings for the input batch of this cell."""
    dp = _dp(mesh, strategy)
    B = shape.global_batch
    S = shape.seq_len if shape.kind != StepKind.DECODE else 1
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    shards: Dict[str, NamedSharding] = {}

    def add(name, shp, dtype, spec):
        specs[name] = jax.ShapeDtypeStruct(shp, dtype)
        shards[name] = NamedSharding(mesh, validate_spec(shp, spec, mesh))

    if arch.n_codebooks:
        if shape.kind == StepKind.DECODE:
            add("codes", (B, 1, arch.n_codebooks), jnp.int32, P(dp))
        else:
            add("embeds", (B, S, arch.d_model), jnp.bfloat16, P(dp))
            if shape.kind == StepKind.TRAIN:
                add("labels", (B, S, arch.n_codebooks), jnp.int32, P(dp))
    elif arch.vlm and shape.kind != StepKind.DECODE:
        Ptch = arch.n_patches
        add("tokens", (B, S - Ptch), jnp.int32, P(dp))
        add("patch_embeds", (B, Ptch, arch.d_model), jnp.bfloat16, P(dp))
        add("patch_pos", (B, Ptch, 3), jnp.int32, P(dp))
        if shape.kind == StepKind.TRAIN:
            add("labels", (B, S - Ptch), jnp.int32, P(dp))
    else:
        add("tokens", (B, S), jnp.int32, P(dp))
        if shape.kind == StepKind.TRAIN:
            add("labels", (B, S), jnp.int32, P(dp))
    return {"specs": specs, "shardings": shards}


def cache_shardings(model: LMModel, cfg: RunConfig, mesh: Mesh,
                    batch: int, cap: int):
    rules = make_rules(cfg, mesh)  # includes the decode_dshard overrides
    spec_tree = model.cache_spec(batch, cap)
    axes = model.cache_axes()

    def one(ax, s):
        return NamedSharding(mesh, validate_spec(s.shape,
                                                 spec_for(ax, rules, mesh),
                                                 mesh))
    return jax.tree.map(one, axes, spec_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        all(isinstance(e, (str, type(None))) for e in x))
