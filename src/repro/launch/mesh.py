"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS before first jax use, and smoke tests/benches must keep seeing
one device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

from repro.core.config import MeshLayout
from repro.core.meshes import layout_device_order
from repro.core.topology import TorusTopology


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_layout_mesh(*, multi_pod: bool = False,
                     layout: MeshLayout = MeshLayout.SPARSE):
    """Same production shape, devices permuted per the thread-placement
    analogue (core.meshes). NONE reproduces the topology-oblivious OS
    baseline; SPARSE/DENSE are the affinitized layouts."""
    from jax.sharding import Mesh

    topo = TorusTopology(n_pods=2 if multi_pod else 1)
    order = layout_device_order(layout, topo)   # (pods, x, y) of device ids
    devices = np.asarray(jax.devices())
    if devices.size < topo.n_chips:
        raise ValueError(f"need {topo.n_chips} devices, have {devices.size}")
    grid = devices[order.reshape(-1)].reshape(order.shape)
    if multi_pod:
        return Mesh(grid, ("pod", "data", "model"))
    return Mesh(grid[0], ("data", "model"))


def make_host_mesh(n_data: Optional[int] = None, n_model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    n_data = n_data or (n // n_model)
    return jax.make_mesh((n_data, n_model), ("data", "model"))
