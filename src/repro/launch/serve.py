"""Serving launcher: continuous batching with a paged KV budget.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --requests 32 --wave-slots 8 --page-tokens 16
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.configs.reduced import reduced as make_reduced
from repro.core.config import AllocatorKind
from repro.core.params import init_params
from repro.models.lm import LMModel
from repro.runtime import ContinuousBatcher, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--wave-slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-tokens", type=int, default=16,
                    help="THP analogue: tokens per KV page")
    ap.add_argument("--n-pages", type=int, default=512)
    ap.add_argument("--allocator", default="slab",
                    choices=[a.value for a in AllocatorKind])
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if args.reduced:
        arch = make_reduced(arch)
    model = LMModel(arch, tp=1, remat="none")
    params = init_params(model.schema(), jax.random.PRNGKey(args.seed),
                         jnp.float32)
    batcher = ContinuousBatcher(
        model, params, wave_slots=args.wave_slots, max_len=args.max_len,
        page_tokens=args.page_tokens, n_pages=args.n_pages,
        allocator=AllocatorKind(args.allocator))
    rng = np.random.RandomState(args.seed)
    for i in range(args.requests):
        batcher.submit(Request(req_id=i,
                               prompt_len=int(rng.randint(4, 32)),
                               max_new_tokens=args.max_new))
    stats = batcher.run(max_steps=5000)
    out = dataclasses.asdict(stats)
    out["allocator"] = args.allocator
    out["page_tokens"] = args.page_tokens
    out["allocator_contentions"] = batcher.kv.allocator_stats.contentions
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
