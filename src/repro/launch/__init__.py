"""Launchers: production mesh, dry-run driver, train/serve entry points."""
from repro.launch.mesh import (make_host_mesh, make_layout_mesh,
                               make_production_mesh)
