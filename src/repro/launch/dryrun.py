import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh ((16,16) "data","model" or (2,16,16)
     "pod","data","model"),
  2. builds the model at TP=16 with the cell's RunConfig,
  3. lowers the right step (train_step / prefill / serve decode_step) with
     ShapeDtypeStruct inputs — ZERO device allocation at any model size,
  4. compiles, prints memory_analysis() (proves the cell fits) and
     cost_analysis() (FLOPs/bytes for the roofline),
  5. parses the post-SPMD HLO for collective wire bytes,
  6. emits a JSON report consumed by EXPERIMENTS.md and benchmarks/roofline.

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod both --out-dir experiments/dryrun
"""
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_arch
from repro.core.config import (ArchConfig, AttentionKind, LM_SHAPES,
                               PlacementPolicy, RunConfig, ShapeConfig,
                               ShardingConfig, StepKind, TrainConfig)
from repro.core.params import abstract_params
from repro.core import topology
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding_plan import (batch_specs, cache_shardings,
                                        data_axes_for, opt_state_shardings,
                                        param_shardings)
from repro.models.lm import LMModel
from repro.optim import adamw
from repro.runtime.train_loop import make_train_step


# ---------------------------------------------------------------------------
# per-cell configuration (paper-faithful defaults; §Perf overrides via CLI)
# ---------------------------------------------------------------------------
def cell_config(arch: ArchConfig, shape: ShapeConfig, *,
                policy: str = "interleave", sequence_parallel: bool = True,
                accum: Optional[int] = None,
                strategy: str = "tp",
                accum_bf16: Optional[bool] = None) -> RunConfig:
    is_deepseek = arch.name == "deepseek-v3"
    is_moe = arch.moe is not None
    big_dense = arch.param_count() > 20e9
    default_accum = 8 if is_deepseek else (4 if is_moe else
                                           (2 if big_dense else 1))
    train = TrainConfig(
        accum_steps=accum if accum is not None else default_accum,
        grad_accum_dtype="bfloat16" if (accum_bf16 if accum_bf16 is not None
                                        else is_deepseek) else "float32",
        moment_dtype="bfloat16" if is_deepseek else "float32",
        master_weights=not is_deepseek,
        remat="block",
    )
    sharding = ShardingConfig(
        policy=PlacementPolicy(policy),
        strategy=strategy,
        sequence_parallel=sequence_parallel and strategy == "tp",
        expert_parallel_data=is_deepseek,
    )
    return RunConfig(arch=arch, shape=shape, sharding=sharding, train=train)


def skip_reason(arch: ArchConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return ("skipped: pure full-attention arch — 512k-token dense KV at "
                "batch 1 is not a sub-quadratic-serving shape (DESIGN.md §8)")
    return None


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|"
                       r"u64|u32|u16|u8|pred)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "f16": 2, "bf16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "f8e4m3fn": 1, "f8e5m2": 1, "pred": 1}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_GROUPS_GRID_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_GRID_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes(hlo_text: str, n_chips: int) -> Dict[str, Any]:
    """Per-device operand + wire bytes per collective kind, from the
    post-SPMD optimized HLO.

    Operands are referenced by name (no inline types), so operand size is
    recovered from the RESULT type and the op semantics:
      all-gather      operand = result / g      wire = result * (g-1)/g
      all-reduce      operand = result          wire = 2 * result * (g-1)/g
      reduce-scatter  operand = result * g      wire = result * (g-1)
      all-to-all      operand = result          wire = result * (g-1)/g
      collective-permute operand = result       wire = result
    (g = replica group size; the partitioned HLO is already per-device.)
    """
    out = {k: {"count": 0, "operand_bytes": 0.0, "wire_bytes": 0.0}
           for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.find(" = ")
        if eq < 0:
            continue
        rhs = s[eq + 3:]
        for kind in _COLLECTIVES:
            # result may be a bare type or a tuple "(t1, t2)"
            m = re.match(r"([^ ]+|\([^)]*\)) " + kind + r"(-start)?\(", rhs)
            if not m:
                continue
            result_b = _shape_bytes(m.group(1))
            g = _group_size(s, n_chips)
            if kind == "all-gather":
                operand = result_b / max(g, 1)
                wire = result_b * (g - 1) / max(g, 1)
            elif kind == "all-reduce":
                operand = result_b
                wire = 2.0 * result_b * (g - 1) / max(g, 1)
            elif kind == "reduce-scatter":
                operand = result_b * g
                wire = result_b * (g - 1)
            elif kind == "all-to-all":
                operand = result_b
                wire = result_b * (g - 1) / max(g, 1)
            else:  # collective-permute
                operand = result_b
                wire = result_b
            out[kind]["count"] += 1
            out[kind]["operand_bytes"] += operand
            out[kind]["wire_bytes"] += wire
            break
    out["operand_bytes"] = sum(v["operand_bytes"] for v in out.values()
                               if isinstance(v, dict))
    out["wire_bytes"] = sum(v["wire_bytes"] for v in out.values()
                            if isinstance(v, dict))
    return out


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------
def build_cell(arch: ArchConfig, shape: ShapeConfig, mesh, cfg: RunConfig,
               unroll_layers: bool = False):
    """Returns (jitted_fn, example_args) ready to .lower()."""
    tp = mesh.shape["model"]
    # shard_map MoE needs the SP token layout and a full-sequence pass
    use_sharded_moe = (arch.moe is not None
                       and cfg.sharding.sequence_parallel
                       and shape.kind != StepKind.DECODE)
    # EP group excludes "pod": experts replicate across pods (see
    # sharding_plan.make_rules)
    expert_axes = (("data", "model")
                   if cfg.sharding.expert_parallel_data else ("model",))
    if cfg.sharding.strategy == "fsdp":
        data_axes = data_axes_for(mesh) + ("model",)
        tp = 1  # no tensor parallelism: pad only to MXU lanes
    else:
        data_axes = data_axes_for(mesh)
    if getattr(cfg.sharding, "decode_dshard", False):
        tp = 1  # head_dim sharding needs NO head padding
    model = LMModel(arch, tp=tp,
                    sequence_parallel=cfg.sharding.sequence_parallel,
                    data_axes=data_axes,
                    kernel_mode="ref", remat=cfg.train.remat,
                    unroll_layers=unroll_layers,
                    moe_mesh=mesh if use_sharded_moe else None,
                    expert_axes=expert_axes)
    params_abs = abstract_params(model.schema(),
                                 jnp.dtype(cfg.param_dtype))
    pshard = param_shardings(model, cfg, mesh)
    binfo = batch_specs(arch, shape, mesh, cfg.sharding.strategy)
    repl = NamedSharding(mesh, P())

    if shape.kind == StepKind.TRAIN:
        opt_abs = adamw.abstract_state(params_abs, cfg.train)
        oshard = opt_state_shardings(model, cfg, mesh, params_abs, opt_abs)
        step_fn = make_train_step(model, cfg)
        jitted = jax.jit(
            step_fn,
            in_shardings=(pshard, oshard, binfo["shardings"], repl),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1))
        args = (params_abs, opt_abs, binfo["specs"],
                jax.ShapeDtypeStruct((), jnp.int32))
        return jitted, args

    if shape.kind == StepKind.PREFILL:
        jitted = jax.jit(
            lambda p, b: model.prefill(p, b),
            in_shardings=(pshard, binfo["shardings"]),
        )
        return jitted, (params_abs, binfo["specs"])

    # DECODE: one token against a cache of seq_len
    B = shape.global_batch
    cap = shape.seq_len
    cache_abs = model.cache_spec(B, cap)
    cshard = cache_shardings(model, cfg, mesh, B, cap)
    jitted = jax.jit(
        lambda p, c, b: model.decode_step(p, c, b),
        in_shardings=(pshard, cshard, binfo["shardings"]),
        donate_argnums=(1,))
    return jitted, (params_abs, cache_abs, binfo["specs"])


# ---------------------------------------------------------------------------
# cost calibration: XLA cost_analysis counts a lax.scan body ONCE, so the
# scanned full-depth module under-reports FLOPs/bytes by ~n_layers. We lower
# shallow UNROLLED variants of the same cell and extrapolate linearly in
# depth (exact for homogeneous stacks; hybrid gets per-superblock and
# per-tail terms). Memory analysis and the compile proof still come from
# the real scanned module.
# ---------------------------------------------------------------------------
def _cell_costs(arch, shape, mesh, cfg, n_chips, unroll=True):
    jitted, args = build_cell(arch, shape, mesh, cfg, unroll_layers=unroll)
    compiled = jitted.lower(*args).compile()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text(), n_chips)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_operand": coll["operand_bytes"],
        "coll_wire": coll["wire_bytes"],
        "collectives": coll,
    }


def _lin(base, per, n):
    # per-layer deltas can dip slightly negative when XLA optimizes the
    # 1-layer and 2-layer modules differently; clamp at the base cost
    return {k: max(base[k] + per[k] * n, base[k], 0.0)
            for k in ("flops", "bytes", "coll_operand", "coll_wire")}


def _sub(a, b):
    return {k: a[k] - b[k]
            for k in ("flops", "bytes", "coll_operand", "coll_wire")}


def calibrate_costs(arch: ArchConfig, shape: ShapeConfig, mesh,
                    cfg: RunConfig, n_chips: int) -> Dict[str, Any]:
    """Extrapolated full-depth costs from shallow unrolled lowerings."""
    L = arch.n_layers
    if arch.family == "hybrid":
        pat_len = len(arch.hybrid.pattern)
        n_super, n_tail = L // pat_len, L % pat_len
        c1 = _cell_costs(dataclasses.replace(arch, n_layers=pat_len),
                         shape, mesh, cfg, n_chips)
        c2 = _cell_costs(dataclasses.replace(arch, n_layers=2 * pat_len),
                         shape, mesh, cfg, n_chips)
        per_super = _sub(c2, c1)
        total = _lin(c1, per_super, n_super - 1)
        if n_tail:
            ct = _cell_costs(
                dataclasses.replace(arch, n_layers=pat_len + n_tail),
                shape, mesh, cfg, n_chips)
            per_tail_group = _sub(ct, c1)
            total = {k: total[k] + per_tail_group[k] for k in total}
        return total
    if arch.moe is not None and arch.moe.n_dense_layers:
        nd = arch.moe.n_dense_layers
        c1 = _cell_costs(dataclasses.replace(arch, n_layers=nd + 1),
                         shape, mesh, cfg, n_chips)
        c2 = _cell_costs(dataclasses.replace(arch, n_layers=nd + 2),
                         shape, mesh, cfg, n_chips)
        per_moe = _sub(c2, c1)
        return _lin(c1, per_moe, (L - nd) - 1)
    c1 = _cell_costs(dataclasses.replace(arch, n_layers=1),
                     shape, mesh, cfg, n_chips)
    c2 = _cell_costs(dataclasses.replace(arch, n_layers=2),
                     shape, mesh, cfg, n_chips)
    per_layer = _sub(c2, c1)
    return _lin(c1, per_layer, L - 1)


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool,
             policy: str = "interleave", sequence_parallel: bool = True,
             accum: Optional[int] = None, strategy: str = "tp",
             accum_bf16: Optional[bool] = None,
             decode_dshard: bool = False,
             verbose: bool = True) -> Dict[str, Any]:
    arch = get_arch(arch_name)
    shape = LM_SHAPES[shape_name]
    report: Dict[str, Any] = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "policy": policy, "sequence_parallel": sequence_parallel,
        "strategy": strategy,
    }
    reason = skip_reason(arch, shape)
    if reason:
        report["status"] = "skipped"
        report["reason"] = reason
        return report

    cfg = cell_config(arch, shape, policy=policy,
                      sequence_parallel=sequence_parallel, accum=accum,
                      strategy=strategy, accum_bf16=accum_bf16)
    if decode_dshard:
        report["decode_dshard"] = True
        cfg = dataclasses.replace(
            cfg, sharding=dataclasses.replace(cfg.sharding,
                                              decode_dshard=True))
    report["accum_steps"] = cfg.train.accum_steps
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    t0 = time.time()
    with mesh:
        jitted, args = build_cell(arch, shape, mesh, cfg)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()

    report["status"] = "ok"
    report["lower_s"] = round(t_lower, 1)
    report["compile_s"] = round(t_compile, 1)

    # ---- memory (proves it fits) ---------------------------------------
    mem_fields = {}
    for field in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
        mem_fields[field] = getattr(mem, field, None)
    args_b = mem_fields.get("argument_size_in_bytes") or 0
    temp_b = mem_fields.get("temp_size_in_bytes") or 0
    out_b = mem_fields.get("output_size_in_bytes") or 0
    alias_b = mem_fields.get("alias_size_in_bytes") or 0
    # memory_analysis is PER-DEVICE on the partitioned module (verified
    # against analytic shard sizes); live bytes = args + temps + outputs
    # minus donated aliases (outputs reusing argument buffers)
    per_device = args_b + temp_b + out_b - alias_b
    report["memory"] = mem_fields
    report["bytes_per_device"] = per_device
    report["fits_16gb"] = bool(per_device < 16e9)

    # ---- raw cost + collective schedule of the real (scanned) module -----
    flops_raw = float(cost.get("flops", 0.0)) if cost else 0.0
    bytes_raw = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
    hlo = compiled.as_text()
    report["raw"] = {"hlo_flops": flops_raw, "hlo_bytes": bytes_raw,
                     "collectives": collective_bytes(hlo, n_chips),
                     "hlo_lines": hlo.count("\n")}
    del hlo, compiled, lowered, jitted

    # ---- depth-calibrated costs (see calibrate_costs docstring) -----------
    t0 = time.time()
    with mesh:
        cal = calibrate_costs(arch, shape, mesh, cfg, n_chips)
    report["calibrate_s"] = round(time.time() - t0, 1)
    # the gradient-accumulation lax.scan body is ALSO counted once by
    # cost_analysis (verified empirically: scan cost is trip-count
    # invariant); microbatch bodies are identical, so scale by accum.
    # The opt-update tail gets overcounted by the same factor — a <1%
    # error at these sizes, noted in EXPERIMENTS.md.
    if shape.kind == StepKind.TRAIN and cfg.train.accum_steps > 1:
        a = cfg.train.accum_steps
        cal = {k: v * a for k, v in cal.items()}
        report["accum_scaled"] = a
    # cost_analysis on the partitioned module reports PER-DEVICE numbers;
    # record both per-device and global
    report["hlo_flops_per_device"] = cal["flops"]
    report["hlo_flops"] = cal["flops"] * n_chips
    report["hlo_bytes_per_device"] = cal["bytes"]
    report["hlo_bytes"] = cal["bytes"] * n_chips
    report["collective_operand_bytes_per_device"] = cal["coll_operand"]
    report["collective_wire_bytes_per_device"] = cal["coll_wire"]

    # ---- roofline terms ---------------------------------------------------
    compute_s = report["hlo_flops"] / (n_chips * topology.PEAK_FLOPS_BF16)
    memory_s = report["hlo_bytes"] / (n_chips * topology.HBM_BW)
    # assignment form: collective_bytes / (chips x link_bw); per-device wire
    # bytes already divide by chips, and each chip drives ICI_LINKS_PER_CHIP
    # links — report the per-link-pessimistic (1 link) number as the term
    collective_s = cal["coll_wire"] / topology.ICI_LINK_BW
    report["roofline"] = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": max(
            (("compute", compute_s), ("memory", memory_s),
             ("collective", collective_s)), key=lambda kv: kv[1])[0],
    }
    # model flops: 6ND (dense) / 6 N_active D (MoE) per trained token;
    # decode/prefill use 2ND per generated/prefilled token
    n_active = arch.active_param_count()
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind == StepKind.TRAIN else
                                   (shape.seq_len
                                    if shape.kind == StepKind.PREFILL else 1))
    mult = 6.0 if shape.kind == StepKind.TRAIN else 2.0
    model_flops = mult * n_active * tokens
    report["model_flops"] = model_flops
    report["useful_flops_ratio"] = (model_flops / report["hlo_flops"]
                                    if report["hlo_flops"] else None)
    step_s = max(compute_s, memory_s, collective_s)
    report["roofline"]["step_s_lower_bound"] = step_s
    report["roofline"]["mfu_bound"] = (
        model_flops / (step_s * n_chips * topology.PEAK_FLOPS_BF16)
        if step_s > 0 else None)
    if verbose:
        print(json.dumps(report, indent=2, default=str))
    return report


# ---------------------------------------------------------------------------
def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"],
                    default="off")
    ap.add_argument("--policy", default="interleave",
                    choices=[p.value for p in PlacementPolicy])
    ap.add_argument("--no-sp", action="store_true",
                    help="disable sequence parallelism")
    ap.add_argument("--strategy", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--accum-bf16", action="store_true")
    ap.add_argument("--decode-dshard", action="store_true",
                    help="shard decode KV caches over head_dim (INTERLEAVE "
                         "applied to the cache: avoids kv-head padding)")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--out-dir", default=None)
    args = ap.parse_args()

    cells = []
    archs = sorted(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = sorted(LM_SHAPES) if (args.all or not args.shape) else [args.shape]
    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in pods:
                cells.append((a, s, mp))

    failures = 0
    for a, s, mp in cells:
        tag = f"{a}|{s}|{'multi' if mp else 'single'}"
        try:
            rep = run_cell(a, s, multi_pod=mp, policy=args.policy,
                           sequence_parallel=not args.no_sp,
                           accum=args.accum, strategy=args.strategy,
                           accum_bf16=args.accum_bf16 or None,
                           decode_dshard=args.decode_dshard)
        except Exception as e:  # noqa: BLE001 — report and continue
            rep = {"arch": a, "shape": s,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            failures += 1
            print(f"[FAIL] {tag}: {e}", file=sys.stderr)
        if args.out_dir:
            os.makedirs(args.out_dir, exist_ok=True)
            fname = f"{a}_{s}_{'multi' if mp else 'single'}"
            if args.policy != "interleave":
                fname += f"_{args.policy}"
            if args.no_sp:
                fname += "_nosp"
            if args.strategy != "tp":
                fname += f"_{args.strategy}"
            if args.accum_bf16:
                fname += "_accbf16"
            if args.decode_dshard:
                fname += "_dshard"
            with open(os.path.join(args.out_dir, fname + ".json"), "w") as f:
                json.dump(rep, f, indent=2, default=str)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
