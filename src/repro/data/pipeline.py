"""Host data pipeline: sharded synthetic token streams with prefetch.

Production shape: each host generates/loads only its shard of the global
batch (host_id, n_hosts), a background thread keeps ``prefetch`` batches
ahead (device transfer overlapped with the train step), and every batch is
deterministic in (seed, step) — so restarts resume mid-stream exactly
(fault tolerance requires replayable data).

Modality stubs (assignment): musicgen batches carry precomputed frame
embeddings; qwen2-vl batches carry patch embeddings + 3D M-RoPE positions.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.core.config import ArchConfig


def _rng_for(seed: int, step: int, host_id: int) -> np.random.RandomState:
    return np.random.RandomState((seed * 1_000_003 + step * 9_973 + host_id)
                                 % (2**31 - 1))


def synth_batch(arch: ArchConfig, batch: int, seq: int, *, step: int,
                seed: int = 0, host_id: int = 0) -> Dict[str, np.ndarray]:
    """One host-local batch. Labels are next-token shifted ids."""
    rng = _rng_for(seed, step, host_id)
    if arch.n_codebooks:
        embeds = rng.randn(batch, seq, arch.d_model).astype(np.float32) * 0.02
        labels = rng.randint(0, arch.vocab_size,
                             (batch, seq, arch.n_codebooks)).astype(np.int32)
        return {"embeds": embeds, "labels": labels}
    ids = rng.randint(0, arch.vocab_size, (batch, seq + 1)).astype(np.int32)
    out = {"tokens": ids[:, :-1], "labels": ids[:, 1:]}
    if arch.vlm:
        P = arch.n_patches
        n_text = seq - P
        out["tokens"] = ids[:, :n_text]
        out["labels"] = ids[:, 1:n_text + 1]
        out["patch_embeds"] = rng.randn(batch, P, arch.d_model).astype(
            np.float32) * 0.02
        grid = int(np.ceil(np.sqrt(P)))
        hh, ww = np.meshgrid(np.arange(grid), np.arange(grid), indexing="ij")
        pos = np.stack([np.zeros_like(hh), hh, ww],
                       axis=-1).reshape(-1, 3)[:P]
        out["patch_pos"] = np.broadcast_to(pos, (batch, P, 3)).astype(np.int32)
    return out


class PrefetchingLoader:
    """Background-thread prefetcher over synth_batch (double buffering)."""

    def __init__(self, arch: ArchConfig, batch: int, seq: int, *,
                 seed: int = 0, host_id: int = 0, n_hosts: int = 1,
                 start_step: int = 0, prefetch: int = 2,
                 transform=None):
        self.arch, self.batch, self.seq = arch, batch, seq
        self.seed, self.host_id = seed, host_id
        self.step = start_step
        self.transform = transform
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            b = synth_batch(self.arch, self.batch, self.seq, step=step,
                            seed=self.seed, host_id=self.host_id)
            if self.transform is not None:
                b = self.transform(b)
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self):
        step, b = self._q.get()
        return b

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
