from repro.data.pipeline import PrefetchingLoader, synth_batch
