"""Optimizers: AdamW (+ ZeRO-1 via placement policy), schedules, compression."""
from repro.optim import adamw, compression, schedules
from repro.optim.adamw import AdamWState
