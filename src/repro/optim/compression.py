"""Gradient compression for the data-parallel all-reduce.

int8 block-quantization with error feedback: grads are scaled per block of
``block`` values, quantized to int8, summed across the data axes (4x fewer
wire bytes than bf16, 2x fewer than... fp32), dequantized, and the
quantization residual is carried to the next step (error feedback keeps the
scheme unbiased over time). Used inside shard_map-based DP sync; off by
default (ShardingConfig.gradient_compression).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, block: int = 256
                  ) -> Tuple[jax.Array, jax.Array]:
    """Returns (q int8 (N,), scales fp32 (N/block,)); x flattened + padded."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0]


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, block: int = 256
                    ) -> jax.Array:
    blocks = q.astype(jnp.float32).reshape(-1, block) * scale[:, None]
    n = 1
    for s in shape:
        n *= s
    return blocks.reshape(-1)[:n].reshape(shape)


def compressed_psum(grads: Any, axis: str, errors: Optional[Any] = None,
                    block: int = 256) -> Tuple[Any, Any]:
    """Inside shard_map: psum each grad leaf in int8 with error feedback.

    Quantization happens per shard; the psum itself rides int32 (int8 sums
    can overflow across >127 shards) with per-shard scales all-gathered and
    averaged — a mean-of-quantized scheme. Returns (synced grads, new error
    feedback tree)."""
    if errors is None:
        errors = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, scale = quantize_int8(target, block)
        q32 = q.astype(jnp.int32)
        summed = jax.lax.psum(q32, axis)
        scale_sum = jax.lax.psum(scale, axis)
        n = jax.lax.psum(jnp.ones(()), axis)
        deq = dequantize_int8(
            (summed.astype(jnp.float32) / n).astype(jnp.float32),
            scale_sum / n, g.shape, block)
        # local error: what our shard's contribution lost
        local_deq = dequantize_int8(q.astype(jnp.float32), scale, g.shape,
                                    block)
        new_e = target - local_deq
        return deq.astype(g.dtype), new_e

    out = jax.tree.map(one, grads, errors)
    pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), pick(1)
