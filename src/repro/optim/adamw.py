"""AdamW with placement-policy-controlled state sharding.

The optimizer is deliberately placement-agnostic (the paper's thesis): its
moments are *state arrays*, and their sharding comes from
``core.partitioning.policy_state_spec`` —
  FIRST_TOUCH  -> moments replicated along the data axes (naive DP),
  INTERLEAVE   -> moments round-robin sharded over data axes (ZeRO-1).
The update math is identical either way; XLA inserts the collectives the
placement implies. ``moment_dtype=bfloat16`` halves optimizer HBM (the
deepseek-scale configuration).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import TrainConfig


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any
    master: Optional[Any]   # fp32 master weights (None = update in bf16)


def init(params: Any, cfg: TrainConfig) -> AdamWState:
    mdtype = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdtype)
    mu = jax.tree.map(zeros, params)
    nu = jax.tree.map(zeros, params)
    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if cfg.master_weights else None)
    return AdamWState(jnp.zeros((), jnp.int32), mu, nu, master)


def abstract_state(params_abs: Any, cfg: TrainConfig) -> AdamWState:
    """ShapeDtypeStruct mirror of init() for dry-run lowering."""
    mdtype = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, mdtype)
    mu = jax.tree.map(zeros, params_abs)
    nu = jax.tree.map(zeros, params_abs)
    master = (jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                           params_abs) if cfg.master_weights else None)
    return AdamWState(jax.ShapeDtypeStruct((), jnp.int32), mu, nu, master)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(grads: Any, state: AdamWState, params: Any, lr: jax.Array,
           cfg: TrainConfig) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else jnp.ones(())
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdtype = jnp.dtype(cfg.moment_dtype)

    use_master = state.master is not None
    master = state.master if use_master else params

    def upd(g, m, v, p, pm):
        gf = g.astype(jnp.float32) * clip
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        vf = v.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
        mhat = mf / c1
        vhat = vf / c2
        base = pm.astype(jnp.float32)
        stepv = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * base
        new_master = base - lr * stepv
        return (new_master.astype(p.dtype), mf.astype(mdtype),
                vf.astype(mdtype), new_master)

    out = jax.tree.map(upd, grads, state.mu, state.nu, params, master)
    pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    new_params = pick(0)
    new_mu = pick(1)
    new_nu = pick(2)
    new_master = pick(3) if use_master else None
    metrics = {"grad_norm": gnorm, "clip": clip}
    return new_params, AdamWState(step, new_mu, new_nu, new_master), metrics
