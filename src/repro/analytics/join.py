"""W3 (hash join) and W4 (index nested-loop join) operators.

W3 — non-partitioning hash join of the paper becomes, on TPU, a radix-
partitioned broadcast-compare join (see kernels/join_probe docstring): both
sides are hash-partitioned so each build partition fits VMEM, then the
Pallas probe streams the probe side through.

W4 — the paper's in-memory indexes (ART / Masstree / SkipList) are pointer
machines; the TPU adaptation keeps the *workload semantics* (a pre-built
read-only index accelerating lookups) with three vectorizable index kinds:
  radix_index   bucket directory on hash prefix + sorted runs (ART analogue)
  sorted_index  plain binary search over the sorted key array (B+Tree leaf
                analogue / SkipList analogue)
  hash_index    open-addressing table probed by rehash (Masstree analogue)
Join output is the standard microbench aggregate: match count + value
checksum (static shape).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analytics.hashing import multiply_shift, pad_partitions, partition_of
from repro.kernels.join_probe import join_probe


# ---------------------------------------------------------------------------
# W3: partitioned hash join
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n_partitions", "capacity_factor",
                                             "mode"))
def hash_join(build_keys: jax.Array, build_vals: jax.Array,
              probe_keys: jax.Array, *, n_partitions: int = 64,
              capacity_factor: float = 2.0, mode: Optional[str] = None
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """PK-FK join. Returns (match_count, value_checksum, overflow)."""
    R, S = build_keys.shape[0], probe_keys.shape[0]

    def layout(keys, vals, n, pad_unit, pad_key):
        part = partition_of(keys, n_partitions)
        order = jnp.argsort(part, stable=True)
        counts = jnp.bincount(part, length=n_partitions)
        starts = jnp.cumsum(counts) - counts
        pad_t = int(max(pad_unit,
                        -(-int(n // n_partitions * capacity_factor) // pad_unit)
                        * pad_unit))
        return pad_partitions(keys[order], vals[order], starts, counts,
                              n_partitions, pad_t, pad_key=pad_key)

    bk, bv, ovf_b = layout(build_keys, build_vals, R, 128, -1)
    pk, _, ovf_p = layout(probe_keys, jnp.ones_like(probe_keys, jnp.float32),
                          S, 128, -2)
    vals, found = join_probe(bk, bv, pk, mode=mode)
    return found.sum(), vals.sum(), ovf_b + ovf_p


# ---------------------------------------------------------------------------
# W4: index joins
# ---------------------------------------------------------------------------
class RadixIndex(NamedTuple):
    """ART analogue: a radix directory over hash prefixes + sorted runs."""
    sorted_keys: jax.Array     # (R,) sorted by (bucket, key)
    sorted_vals: jax.Array
    bucket_starts: jax.Array   # (n_buckets + 1,)
    bits: int


def build_radix_index(keys: jax.Array, vals: jax.Array, *,
                      bits: int = 10) -> RadixIndex:
    n_buckets = 1 << bits
    bucket = multiply_shift(keys, bits).astype(jnp.int32)
    # two-pass stable sort -> ordered by (bucket, key) without 64-bit keys
    order_k = jnp.argsort(keys, stable=True)
    k1, v1, b1 = keys[order_k], vals[order_k], bucket[order_k]
    order_b = jnp.argsort(b1, stable=True)
    counts = jnp.bincount(bucket, length=n_buckets)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)])
    return RadixIndex(k1[order_b], v1[order_b], starts, bits)


def probe_radix_index(index: RadixIndex, probe_keys: jax.Array
                      ) -> Tuple[jax.Array, jax.Array]:
    """Vectorized bucket + binary search probe."""
    bucket = multiply_shift(probe_keys, index.bits).astype(jnp.int32)
    lo = index.bucket_starts[bucket]
    hi = index.bucket_starts[bucket + 1]
    # branchless binary search within [lo, hi) — fixed trip count
    n = index.sorted_keys.shape[0]
    steps = max(1, int(n).bit_length())
    pos = lo

    def body(_, state):
        lo, hi = state
        mid = (lo + hi) // 2
        mk = index.sorted_keys[jnp.clip(mid, 0, n - 1)]
        go_right = mk < probe_keys
        return jnp.where(go_right, mid + 1, lo), jnp.where(go_right, hi, mid)

    lo, hi = jax.lax.fori_loop(0, steps, body, (lo, hi))
    pos = jnp.clip(lo, 0, n - 1)
    found = index.sorted_keys[pos] == probe_keys
    return jnp.where(found, index.sorted_vals[pos], 0.0), found


class SortedIndex(NamedTuple):
    """B+Tree-leaf / SkipList analogue: binary search over sorted keys."""
    sorted_keys: jax.Array
    sorted_vals: jax.Array


def build_sorted_index(keys: jax.Array, vals: jax.Array) -> SortedIndex:
    order = jnp.argsort(keys)
    return SortedIndex(keys[order], vals[order])


def probe_sorted_index(index: SortedIndex, probe_keys: jax.Array
                       ) -> Tuple[jax.Array, jax.Array]:
    pos = jnp.searchsorted(index.sorted_keys, probe_keys)
    pos = jnp.clip(pos, 0, index.sorted_keys.shape[0] - 1)
    found = index.sorted_keys[pos] == probe_keys
    return jnp.where(found, index.sorted_vals[pos], 0.0), found


class HashIndex(NamedTuple):
    """Open-addressing linear-probe table (Masstree analogue for lookups)."""
    table_keys: jax.Array      # (C,) int32, -1 = empty
    table_vals: jax.Array
    capacity: int
    max_probes: int


def build_hash_index(keys: jax.Array, vals: jax.Array, *,
                     load_factor: float = 0.5,
                     max_probes: int = 16) -> HashIndex:
    """Vectorized linear-probe insertion: each round, every unplaced key
    bids for its next slot; scatter-max arbitrates contention (the TPU
    analogue of the CAS loop a CPU concurrent table would run)."""
    R = keys.shape[0]
    cap = 1 << max(4, int((R / load_factor) - 1).bit_length())
    tk = jnp.full((cap,), -1, jnp.int32)
    tv = jnp.zeros((cap,), jnp.float32)
    home = (multiply_shift(keys) % jnp.uint32(cap)).astype(jnp.int32)

    def insert_round(state, i):
        tk, tv, placed = state
        want = (home + i) % cap                       # this round's bid
        empty = tk[want] == -1
        bidding = ~placed & empty
        slot_bid = jnp.where(bidding, want, cap)      # cap = OOB, dropped
        # arbitrate: highest key id wins a contested empty slot
        bids = jnp.full((cap,), -1, jnp.int32).at[slot_bid].max(
            keys, mode="drop")
        won = bidding & (bids[jnp.clip(want, 0, cap - 1)] == keys)
        target = jnp.where(won, want, cap)
        tk = tk.at[target].set(keys, mode="drop")
        tv = tv.at[target].set(vals, mode="drop")
        return (tk, tv, placed | won), None

    (tk, tv, placed), _ = jax.lax.scan(
        insert_round, (tk, tv, jnp.zeros_like(keys, bool)),
        jnp.arange(max_probes))
    return HashIndex(tk, tv, cap, max_probes)


def probe_hash_index(index: HashIndex, probe_keys: jax.Array
                     ) -> Tuple[jax.Array, jax.Array]:
    cap = index.capacity
    slot = (multiply_shift(probe_keys) % jnp.uint32(cap)).astype(jnp.int32)
    found = jnp.zeros_like(probe_keys, bool)
    vals = jnp.zeros_like(probe_keys, jnp.float32)

    def body(i, state):
        found, vals = state
        s = (slot + i) % cap
        hit = (index.table_keys[s] == probe_keys) & ~found
        vals = jnp.where(hit, index.table_vals[s], vals)
        return found | hit, vals

    found, vals = jax.lax.fori_loop(0, index.max_probes, body, (found, vals))
    return vals, found


@functools.partial(jax.jit, static_argnames=("index_kind",))
def index_join(build_keys: jax.Array, build_vals: jax.Array,
               probe_keys: jax.Array, index_kind: str = "radix"
               ) -> Tuple[jax.Array, jax.Array]:
    """W4: pre-built-index join -> (match_count, value_checksum)."""
    if index_kind == "radix":
        idx = build_radix_index(build_keys, build_vals)
        vals, found = probe_radix_index(idx, probe_keys)
    elif index_kind == "sorted":
        idx = build_sorted_index(build_keys, build_vals)
        vals, found = probe_sorted_index(idx, probe_keys)
    elif index_kind == "hash":
        idx = build_hash_index(build_keys, build_vals)
        vals, found = probe_hash_index(idx, probe_keys)
    else:
        raise ValueError(f"unknown index kind {index_kind!r}")
    return found.sum(), vals.sum()
