"""Physical columnar operators (the W5 "database system" layer).

A Table is a struct-of-arrays with static length; selection is mask-based
(TPU-friendly: no compaction, predicates become aggregation weights), joins
are PK-FK gathers through a sorted index, and aggregations are masked
segment ops.  This module is the *physical operator library*: queries are
authored as logical plans (plan.py) and the cost-based planner (planner.py)
lowers each logical node onto one of the operators here.

Grouped aggregation has three physical layouts the planner chooses between
per Aggregate node — see planner.choose_aggregate for the cost model:

  "xla"          one XLA segment op per aggregate — the naive plan a query
                 compiler emits without memory tuning. N passes over the
                 table for N aggregates.
  "dense"        fused-kernel sweep with positionally-chunked full-width
                 tables (no sort; exact): every (sum, avg, count) aggregate
                 over one key column is stacked into a single values matrix
                 and swept in ONE pass through the hash_aggregate Pallas
                 kernel (VMEM-resident tables — the paper's
                 partition-then-per-thread-table recipe). Valid for key
                 domains up to DENSE_GROUP_LIMIT.
  "partitioned"  fused-kernel sweep after a range-partitioning pass, so each
                 partition's table stays narrow; overflow is counted exactly
                 (never dropped silently). Pays an argsort of the keys —
                 worthwhile only when many aggregates amortize it.

Order statistics (max/min/median) are not distributive sums and stay on
exact XLA lowerings under every layout — max/min on segment ops, median on
the ``segment_median`` sort-based selection (holistic: a group's median
needs all of its values co-located, paper Section 2).  ``group_aggregate``'s string ``executor``
knob ("xla" picks the first layout, "kernel" the domain-appropriate fused
one) is kept as the untuned/tuned axis the Fig 8/9 benchmark measures.

PK-FK joins have two physical forms: ``pkfk_join`` (sorted-index
searchsorted gather; the build-side argsort is cached per Table and
propagated through filter/with_columns/join derivations — and hoisted out
of the compiled plan entirely by planner.JoinIndexPool) and
``pkfk_join_kernel`` (hash-partition both sides, probe through the
kernels/join_probe broadcast-compare kernel; capacity overflow triggers a
residual re-probe of the kernel's misses through the sorted path, so
skewed keys stay exact — or is counted and surfaced with residual=False).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analytics.hashing import pad_partitions, partition_of
from repro.analytics.plan import is_holistic, parse_quantile
from repro.kernels.hash_aggregate import hash_aggregate_multi
from repro.kernels.join_probe import join_probe

# Largest key domain aggregated with full-width per-chunk tables (the
# one-hot is (block, n_bins): 512 x 4096 fp32 = 8 MB VMEM). Beyond this the
# kernel path range-partitions so each partition table stays narrow.
DENSE_GROUP_LIMIT = 4096


@dataclass
class Table:
    columns: Dict[str, jax.Array]
    mask: Optional[jax.Array] = None     # float32 selection weights (None = 1)
    # name -> (order, sorted_keys) argsort cache for join build sides.
    # Shared with derived tables whose column arrays are unchanged; entries
    # for overwritten columns are dropped at derivation time.
    index_cache: Dict[str, Tuple[jax.Array, jax.Array]] = field(
        default_factory=dict, repr=False)

    def __post_init__(self):
        lens = {c.shape[0] for c in self.columns.values()}
        if len(lens) != 1:
            raise ValueError(f"ragged table: {lens}")

    @property
    def n_rows(self) -> int:
        return next(iter(self.columns.values())).shape[0]

    def col(self, name: str) -> jax.Array:
        return self.columns[name]

    def weights(self) -> jax.Array:
        if self.mask is None:
            return jnp.ones((self.n_rows,), jnp.float32)
        return self.mask

    def key_index(self, name: str) -> Tuple[jax.Array, jax.Array]:
        """(order, sorted_keys) for ``name``, built once per column array.

        Never caches a TRACER computed from a concrete column: a Table that
        outlives the trace it was first joined in (e.g. an eager dimension
        table closed over by a jitted query) would otherwise serve a dead
        trace's tracer to every later call."""
        hit = self.index_cache.get(name)
        if hit is None:
            k = self.columns[name]
            order = jnp.argsort(k)
            hit = (order, k[order])
            if not (isinstance(order, jax.core.Tracer)
                    and not isinstance(k, jax.core.Tracer)):
                self.index_cache[name] = hit
        return hit

    def filter(self, pred: jax.Array) -> "Table":
        """AND a predicate into the selection mask (no data movement)."""
        w = self.weights() * pred.astype(jnp.float32)
        return Table(self.columns, w, self.index_cache)

    def with_columns(self, **cols: jax.Array) -> "Table":
        merged = dict(self.columns)
        merged.update(cols)
        cache = {k: v for k, v in self.index_cache.items() if k not in cols}
        return Table(merged, self.mask, cache)


def concat_slices(parts):
    """Concatenate (columns, mask) row-slice pairs, in order, into one
    (columns, mask) pair.

    The merge primitive of the serving tier's split-probe path: each part
    is one morsel's slice of a per-row pipeline's output, so concatenation
    in slice order rebuilds the unsliced table bit-for-bit (a row concat,
    never a float re-ordering). ``mask`` is None only when every part's
    mask is None (a maskless pipeline stays maskless)."""
    cols0, mask0 = parts[0]
    cols = {c: jnp.concatenate([p[0][c] for p in parts]) for c in cols0}
    mask = (None if mask0 is None
            else jnp.concatenate([p[1] for p in parts]))
    return cols, mask


def pkfk_join(fact: Table, dim: Table, fact_key: str, dim_key: str,
              take: Mapping[str, str]) -> Table:
    """Gather dim columns into the fact table through the PK (sorted index).

    ``take`` maps new-column-name -> dim-column-name. Misses zero the mask.
    The build-side sorted index comes from ``dim.key_index`` — cached on the
    Table, so joining the same dimension (or a filtered view of it) again
    re-uses the argsort instead of re-sorting per call site.
    """
    order, sk = dim.key_index(dim_key)
    pos = jnp.clip(jnp.searchsorted(sk, fact.col(fact_key)), 0, sk.shape[0] - 1)
    found = sk[pos] == fact.col(fact_key)
    dim_w = dim.weights()[order][pos]
    new_cols = {new: dim.col(src)[order][pos] for new, src in take.items()}
    out = fact.with_columns(**new_cols)
    return Table(out.columns, out.weights() * found.astype(jnp.float32) * dim_w,
                 out.index_cache)


def pkfk_join_kernel(fact: Table, dim: Table, fact_key: str, dim_key: str,
                     take: Mapping[str, str], *, n_partitions: int = 32,
                     capacity_factor: float = 2.0,
                     mode: Optional[str] = None,
                     residual: bool = True
                     ) -> Tuple[Table, jax.Array]:
    """PK-FK join probed through the kernels/join_probe blocked compare.

    Both sides are hash-partitioned (hashing.partition_of, so matching keys
    co-partition) into dense (P, cap) layouts; the kernel matches each probe
    slot against its partition's build tile and returns the matched build
    ROW POSITION, through which the ``take`` columns (and the build-side
    mask) are gathered. Keys must be non-negative (key -1 is the padding
    sentinel).

    Rows beyond a partition's capacity — on either side — are dropped from
    the dense layouts and would degrade to join misses under key skew. With
    ``residual=True`` (default) a residual pass re-probes the kernel's
    misses through the exact sorted path whenever overflow occurred, so the
    result is EXACT under any skew and the returned overflow is 0. With
    ``residual=False`` the overflow is counted and surfaced (never silent),
    and overflowed rows stay misses — the PR-2 accounting behavior.
    Returns (joined table, overflow).
    """
    fk = fact.col(fact_key).astype(jnp.int32)
    dk = dim.col(dim_key).astype(jnp.int32)
    n_fact, n_dim = fk.shape[0], dk.shape[0]
    if max(n_fact, n_dim) >= 1 << 24:
        # row positions ride through the kernel as float32 payloads, which
        # are only exact integers below 2^24 — beyond that positions would
        # silently collide; refuse rather than corrupt the join
        raise ValueError(f"pkfk_join_kernel limited to <2^24 rows per side, "
                         f"got fact={n_fact}, dim={n_dim}")
    P = n_partitions

    def _layout(keys, payload, cap_rows):
        part = partition_of(keys, P)
        order = jnp.argsort(part, stable=True)
        counts = jnp.bincount(part, length=P)
        starts = jnp.cumsum(counts) - counts
        cap = int(max(128, -(-int(cap_rows // P * capacity_factor) // 128)
                      * 128))
        return pad_partitions(keys[order], payload[order], starts, counts,
                              P, cap)

    # build side carries its own row positions as the probe payload
    bkeys, bpos, ovf_b = _layout(dk, jnp.arange(n_dim, dtype=jnp.float32),
                                 n_dim)
    pkeys, prow, ovf_p = _layout(fk, jnp.arange(n_fact, dtype=jnp.float32),
                                 n_fact)
    vals, found = join_probe(bkeys, bpos, pkeys, mode=mode)
    # scatter per-slot results back to original row order; padding slots
    # (key -1) collide on a dummy row that is sliced off
    slot_valid = (pkeys >= 0).reshape(-1)
    rows = jnp.where(slot_valid, prow.reshape(-1).astype(jnp.int32), n_fact)
    pos = (jnp.zeros((n_fact + 1,), jnp.int32)
           .at[rows].set(vals.reshape(-1).astype(jnp.int32))[:n_fact])
    found_r = (jnp.zeros((n_fact + 1,), jnp.bool_)
               .at[rows].set(found.reshape(-1) & slot_valid)[:n_fact])
    overflow = (ovf_b + ovf_p).astype(jnp.int32)
    if residual:
        # Residual pass: capacity overflow drops rows from the dense
        # layouts — a dropped probe row never reaches a slot, and a
        # dropped build row makes its probes compare not-found — so every
        # missed match surfaces as found_r == False. Re-probing the
        # kernel's misses through the exact sorted index restores
        # exactness under any skew; lax.cond defers that cost until
        # overflow actually happened (the argsort itself is cached on the
        # build Table / hoisted by the planner's JoinIndexPool).
        order, sk = dim.key_index(dim_key)

        def _reprobe(args):
            pos0, found0 = args
            spos = jnp.clip(jnp.searchsorted(sk, fk), 0, sk.shape[0] - 1)
            sfound = sk[spos] == fk
            return (jnp.where(found0, pos0, order[spos].astype(jnp.int32)),
                    found0 | sfound)

        pos, found_r = jax.lax.cond(overflow > 0, _reprobe, lambda a: a,
                                    (pos, found_r))
        overflow = jnp.zeros((), jnp.int32)
    pos = jnp.clip(pos, 0, n_dim - 1)
    dim_w = dim.weights()[pos]
    new_cols = {new: dim.col(src)[pos] for new, src in take.items()}
    out = fact.with_columns(**new_cols)
    joined = Table(out.columns,
                   out.weights() * found_r.astype(jnp.float32) * dim_w,
                   out.index_cache)
    return joined, overflow


# ---------------------------------------------------------------------------
# grouped aggregation: default XLA plan vs tuned fused-kernel plan
# ---------------------------------------------------------------------------
def group_aggregate(table: Table, key: str, n_groups: int,
                    aggs: Mapping[str, Tuple[str, str]], *,
                    executor: str = "xla", mode: Optional[str] = None,
                    layout: Optional[str] = None,
                    n_partitions: int = 64, capacity_factor: float = 2.0
                    ) -> Dict[str, jax.Array]:
    """aggs: out_name -> (op, column); op in {sum, count, avg, max, min}.
    Masked rows contribute nothing. Returns dict of (n_groups,) arrays plus
    ``_count`` and ``_overflow`` (records beyond partition capacity on the
    kernel path; always 0 on the XLA path and the dense kernel path).

    ``layout`` overrides the kernel path's dense/partitioned choice (the
    cost-based planner sets it per Aggregate node); None keeps the
    DENSE_GROUP_LIMIT domain-size rule."""
    if executor == "kernel":
        return _group_aggregate_kernel(table, key, n_groups, aggs, mode=mode,
                                       layout=layout,
                                       n_partitions=n_partitions,
                                       capacity_factor=capacity_factor)
    if executor != "xla":
        raise ValueError(f"unknown executor {executor!r}")
    return _group_aggregate_xla(table, key, n_groups, aggs)


def _group_aggregate_xla(table: Table, key: str, n_groups: int,
                         aggs: Mapping[str, Tuple[str, str]]
                         ) -> Dict[str, jax.Array]:
    """Default plan: one segment op per aggregate."""
    keys = jnp.clip(table.col(key), 0, n_groups - 1)
    w = table.weights()
    out: Dict[str, jax.Array] = {}
    cnt = jax.ops.segment_sum(w, keys, num_segments=n_groups)
    for name, (op, col) in aggs.items():
        if op == "count":
            out[name] = cnt
            continue
        v = table.col(col).astype(jnp.float32)
        if op in ("sum", "avg"):
            s = jax.ops.segment_sum(v * w, keys, num_segments=n_groups)
            out[name] = s if op == "sum" else s / jnp.maximum(cnt, 1.0)
        elif op in ("max", "min") or is_holistic(op):
            out[name] = segment_order_stat(table, keys, n_groups, op, col)
        else:
            raise ValueError(f"unknown agg op {op!r}")
    out["_count"] = cnt
    out["_overflow"] = jnp.zeros((), jnp.int32)
    return out


def stacked_columns(table: Table, key: str, n_groups: int,
                    aggs: Mapping[str, Tuple[str, str]]
                    ) -> Tuple[jax.Array, jax.Array, list]:
    """(keys, stacked values matrix, distinct sum/avg source columns).

    Column 0 of the matrix carries the selection weights (COUNT); masked
    rows have weight 0 so they vanish from every fused sum."""
    keys = jnp.clip(table.col(key), 0, n_groups - 1).astype(jnp.int32)
    w = table.weights()
    src: list = []                       # distinct sum/avg source columns
    for name, (op, col) in aggs.items():
        if op in ("sum", "avg") and col not in src:
            src.append(col)
        elif (op not in ("sum", "avg", "count", "max", "min")
              and not is_holistic(op)):
            raise ValueError(f"unknown agg op {op!r}")
    vals = jnp.stack(
        [w] + [table.col(c).astype(jnp.float32) * w for c in src], axis=1)
    return keys, vals, src


def stacked_group_sums(keys: jax.Array, vals: jax.Array, n_groups: int, *,
                       layout: str, mode: Optional[str] = None,
                       n_partitions: int = 64, capacity_factor: float = 2.0
                       ) -> Tuple[jax.Array, jax.Array]:
    """Per-group sums of a stacked (N, C) values matrix under one layout.

    The single physical primitive every grouped-sum lowering shares: the
    local executor, the distributed per-shard partials (planner.py) and
    aggregate.count_partitioned all funnel through here. Returns
    ((n_groups, C) sums, overflow)."""
    if layout == "xla":
        return (jax.ops.segment_sum(vals, keys, num_segments=n_groups),
                jnp.zeros((), jnp.int32))
    if layout == "dense":
        return _fused_dense(keys, vals, n_groups, mode=mode), \
            jnp.zeros((), jnp.int32)
    if layout == "partitioned":
        sums, overflow = _fused_partitioned(
            keys, vals, n_groups, mode=mode, n_partitions=n_partitions,
            capacity_factor=capacity_factor)
        return sums, overflow.astype(jnp.int32)
    raise ValueError(f"unknown layout {layout!r}")


def _segment_selection(keys: jax.Array, vals: jax.Array, n_groups: int):
    """Shared sort pass of the order-statistic primitives: per-group
    value-sorted runs plus each run's (count, start). Keys < 0 are
    EXCLUDED (the routed-buffer padding / masked-row sentinel); keys >=
    n_groups clip into the last group (the stacked_columns convention —
    the selection math needs the key order and the count clipping to
    agree, so the clip is enforced here, not left to callers). Returns
    (sorted_vals, counts f32, starts i32 shifted past the excluded run,
    sorted_keys i-dtype in the same order as sorted_vals)."""
    keys = jnp.where(keys < 0, -1, jnp.minimum(keys, n_groups - 1))
    order_v = jnp.argsort(vals, stable=True)
    k1, v1 = keys[order_v], vals[order_v]
    order_k = jnp.argsort(k1, stable=True)
    sv = v1[order_k]
    sk = k1[order_k]
    counts = jax.ops.segment_sum(
        jnp.ones_like(keys, jnp.float32),
        jnp.clip(keys, 0, n_groups - 1), num_segments=n_groups)
    # discard excluded records (key < 0) from counts (clipped to group 0)
    pad = jax.ops.segment_sum(
        jnp.where(keys < 0, 1.0, 0.0),
        jnp.zeros_like(keys), num_segments=n_groups)
    counts = counts - pad
    starts = jnp.cumsum(counts) - counts
    # excluded records sort first (key < 0): shift starts past them
    starts = starts + pad[0]
    return sv, counts, starts, sk


def segment_median(keys: jax.Array, vals: jax.Array, n_groups: int
                   ) -> Tuple[jax.Array, jax.Array]:
    """Exact per-group median by local sort + selection.

    The holistic (order-statistic) primitive: a group's median cannot be
    merged from partials (paper Section 2), so every median lowering —
    single-device, full-replication, or routed distributed selection —
    funnels through this one sort-based selection (shared with
    ``segment_quantile``, the arbitrary-rank generalization). The median
    is the mean of the run's two middle elements (NaN for empty groups).
    Returns (medians, counts), both (n_groups,)."""
    sv, counts, starts, _sk = _segment_selection(keys, vals, n_groups)
    c, s = counts.astype(jnp.int32), starts.astype(jnp.int32)
    lo = jnp.clip(s + jnp.maximum((c - 1) // 2, 0), 0, sv.shape[0] - 1)
    hi = jnp.clip(s + jnp.maximum(c // 2, 0), 0, sv.shape[0] - 1)
    med = (sv[lo] + sv[hi]) * 0.5
    return jnp.where(c > 0, med, jnp.nan), counts


def segment_quantile(keys: jax.Array, vals: jax.Array, n_groups: int,
                     rank: float) -> Tuple[jax.Array, jax.Array]:
    """Exact per-group ``rank`` quantile (linear interpolation, the
    numpy default): median generalized to an arbitrary selection index.

    Rides the same sort pass as ``segment_median`` — the selection
    position within a group's value-sorted run is rank * (count - 1); a
    fractional position interpolates between the two neighboring order
    statistics. Keys < 0 are excluded, empty groups yield NaN. ``rank``
    must lie in the OPEN interval (0, 1) — the endpoints are min/max,
    which have exact distributive lowerings. Returns (quantiles, counts),
    both (n_groups,)."""
    if not 0.0 < float(rank) < 1.0:
        raise ValueError(f"quantile rank must be in (0, 1), got {rank}")
    sv, counts, starts, _sk = _segment_selection(keys, vals, n_groups)
    c, s = counts.astype(jnp.int32), starts.astype(jnp.int32)
    pos = jnp.float32(rank) * jnp.maximum(c - 1, 0).astype(jnp.float32)
    base = jnp.floor(pos).astype(jnp.int32)
    frac = pos - base.astype(jnp.float32)
    lo = jnp.clip(s + base, 0, sv.shape[0] - 1)
    hi = jnp.clip(s + jnp.minimum(base + 1, jnp.maximum(c - 1, 0)),
                  0, sv.shape[0] - 1)
    q = sv[lo] + (sv[hi] - sv[lo]) * frac
    return jnp.where(c > 0, q, jnp.nan), counts


def segment_distinct(keys: jax.Array, vals: jax.Array, n_groups: int
                     ) -> Tuple[jax.Array, jax.Array]:
    """Exact per-group distinct-value count via the shared selection sort.

    Within a group's value-sorted run, a value is counted when it differs
    from its predecessor (the run's first element always counts): the
    distinct count is the per-group sum of those boundaries. Holistic
    like median — partials from disjoint shards cannot be merged (the
    same value may appear on two shards) — but when one shard holds ALL
    of a group's records (routed or placed lowerings) the local count is
    exact. Keys < 0 are excluded; empty groups yield 0, not NaN (a count,
    not an order statistic). Returns (distinct f32, counts f32)."""
    sv, counts, _starts, sk = _segment_selection(keys, vals, n_groups)
    prev_k = jnp.concatenate([sk[:1] - 1, sk[:-1]])
    prev_v = jnp.concatenate([sv[:1], sv[:-1]])
    new = (sk >= 0) & ((sk != prev_k) | (sv != prev_v))
    distinct = jax.ops.segment_sum(
        jnp.where(new, 1.0, 0.0), jnp.clip(sk, 0, n_groups - 1),
        num_segments=n_groups)
    return distinct, counts


def segment_order_stat(table: Table, keys: jax.Array, n_groups: int,
                       op: str, col: str) -> jax.Array:
    """Masked per-group max/min/median/quantile/distinct via exact XLA
    lowerings (none of these are distributive sums, so they never ride
    the fused sweep)."""
    v = table.col(col).astype(jnp.float32)
    w = table.weights()
    if op == "median":
        return segment_median(jnp.where(w > 0, keys, -1), v, n_groups)[0]
    if op == "distinct":
        return segment_distinct(jnp.where(w > 0, keys, -1), v, n_groups)[0]
    rank = parse_quantile(op)
    if rank is not None:
        return segment_quantile(jnp.where(w > 0, keys, -1), v, n_groups,
                                rank)[0]
    if op == "max":
        big = jnp.where(w > 0, v, -jnp.inf)
        return jax.ops.segment_max(big, keys, num_segments=n_groups)
    small = jnp.where(w > 0, v, jnp.inf)
    return jax.ops.segment_min(small, keys, num_segments=n_groups)


def finalize_stacked(aggs: Mapping[str, Tuple[str, str]], src: list,
                     sums: jax.Array, order_stat) -> Dict[str, jax.Array]:
    """Named outputs from a merged (n_groups, C) stacked-sums table.

    Shared by the local kernel path and the distributed per-policy path so
    the two can never drift. ``order_stat(op, col)`` supplies max/min (the
    distributed executor composes a cross-shard reduction on top of the
    segment ops)."""
    cnt = sums[:, 0]
    out: Dict[str, jax.Array] = {}
    for name, (op, col) in aggs.items():
        if op == "count":
            out[name] = cnt
        elif op == "sum":
            out[name] = sums[:, 1 + src.index(col)]
        elif op == "avg":
            out[name] = sums[:, 1 + src.index(col)] / jnp.maximum(cnt, 1.0)
        else:
            out[name] = order_stat(op, col)
    out["_count"] = cnt
    return out


def _group_aggregate_kernel(table: Table, key: str, n_groups: int,
                            aggs: Mapping[str, Tuple[str, str]], *,
                            mode: Optional[str], layout: Optional[str],
                            n_partitions: int,
                            capacity_factor: float) -> Dict[str, jax.Array]:
    """Tuned plan: all distributive aggregates fused into one kernel sweep."""
    keys, vals, src = stacked_columns(table, key, n_groups, aggs)
    if layout is None:
        layout = "dense" if n_groups <= DENSE_GROUP_LIMIT else "partitioned"
    sums, overflow = stacked_group_sums(
        keys, vals, n_groups, layout=layout, mode=mode,
        n_partitions=n_partitions, capacity_factor=capacity_factor)
    out = finalize_stacked(
        aggs, src, sums,
        lambda op, col: segment_order_stat(table, keys, n_groups, op, col))
    out["_overflow"] = overflow.astype(jnp.int32)
    return out


def _fused_dense(keys: jax.Array, vals: jax.Array, n_groups: int, *,
                 mode: Optional[str], block: int = 512) -> jax.Array:
    """Small key domain: positional chunking, full-width tables, no sort.

    Rows are split into chunks by position; each chunk's (n_bins, C) table
    covers every group, so the result is the exact sum of chunk tables —
    no partitioning pass, no overflow possible. Padding rows carry zero
    values, so their bin placement is irrelevant."""
    N, C = vals.shape
    bins = max(128, -(-n_groups // 128) * 128)
    n_chunks = 8 if N >= 8 * block else 1
    per_chunk = -(-N // n_chunks)
    t = -(-per_chunk // block) * block
    pad = n_chunks * t - N
    k = jnp.pad(keys, (0, pad))
    v = jnp.pad(vals, ((0, pad), (0, 0)))
    table = hash_aggregate_multi(k.reshape(n_chunks, t),
                                 v.reshape(n_chunks, t, C),
                                 n_bins=bins, block=block, mode=mode)
    return table.sum(axis=0)[:n_groups]


def _fused_partitioned(keys: jax.Array, vals: jax.Array, n_groups: int, *,
                       mode: Optional[str], n_partitions: int,
                       capacity_factor: float, block: int = 256
                       ) -> Tuple[jax.Array, jax.Array]:
    """Large key domain: range partition, then fused per-partition tables.

    Range partitioning on the (clipped, dense) group ids makes the
    partition-local slot (key % range_size) collision-free, so the kernel
    result is EXACT whenever no partition overflows its capacity; overflow
    is counted and returned, as in aggregate.count_partitioned."""
    N, C = vals.shape
    range_size = -(-n_groups // n_partitions)
    bins = max(128, -(-range_size // 128) * 128)
    part = jnp.clip(keys // range_size, 0, n_partitions - 1)
    order = jnp.argsort(part, stable=True)
    sk, sv = keys[order], vals[order]
    counts_p = jnp.bincount(part, length=n_partitions)
    starts = jnp.cumsum(counts_p) - counts_p
    pad_t = int(max(block,
                    -(-int(N // n_partitions * capacity_factor) // block)
                    * block))
    pk, pv, overflow = pad_partitions(sk, sv, starts, counts_p, n_partitions,
                                      pad_t)
    local = jnp.where(pk < 0, 0, pk % range_size)   # padded vals are zero
    table = hash_aggregate_multi(local, pv, n_bins=bins, block=block,
                                 mode=mode)
    flat = table[:, :range_size, :].reshape(n_partitions * range_size, C)
    return flat[:n_groups], overflow
