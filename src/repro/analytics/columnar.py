"""Mini columnar query executor (the W5 "database system" layer).

A Table is a struct-of-arrays with static length; selection is mask-based
(TPU-friendly: no compaction, predicates become aggregation weights), joins
are PK-FK gathers through a sorted index, and aggregations are masked
segment ops. The executor runs the TPC-H-style queries in tpch.py under the
same placement/allocator knobs as everything else.

Two executor paths (the paper's "default vs tuned" configurations):

  executor="xla"     one XLA segment op per aggregate — the naive plan a
                     query compiler emits without memory tuning. N passes
                     over the table for N aggregates.
  executor="kernel"  the tuned path: every (sum, avg, count) aggregate over
                     one key column is stacked into a single values matrix
                     and swept in ONE fused pass through the hash_aggregate
                     Pallas kernel (VMEM-resident partition tables — the
                     paper's partition-then-per-thread-table recipe).
                     Small key domains run chunk-parallel with full-width
                     tables; large domains are range-partitioned first so
                     each partition's table fits, with overflow counted
                     exactly (never dropped silently) as in
                     aggregate.count_partitioned. Order statistics
                     (max/min) are not distributive sums and stay on exact
                     XLA segment ops under either executor.

Join build-side indexes (argsort of the PK column) are cached per Table and
propagated through filter/with_columns/join derivations, so a dimension
table re-used across several joins of one query plan is sorted once.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analytics.hashing import pad_partitions
from repro.kernels.hash_aggregate import hash_aggregate_multi

# Largest key domain aggregated with full-width per-chunk tables (the
# one-hot is (block, n_bins): 512 x 4096 fp32 = 8 MB VMEM). Beyond this the
# kernel path range-partitions so each partition table stays narrow.
DENSE_GROUP_LIMIT = 4096


@dataclass
class Table:
    columns: Dict[str, jax.Array]
    mask: Optional[jax.Array] = None     # float32 selection weights (None = 1)
    # name -> (order, sorted_keys) argsort cache for join build sides.
    # Shared with derived tables whose column arrays are unchanged; entries
    # for overwritten columns are dropped at derivation time.
    index_cache: Dict[str, Tuple[jax.Array, jax.Array]] = field(
        default_factory=dict, repr=False)

    def __post_init__(self):
        lens = {c.shape[0] for c in self.columns.values()}
        if len(lens) != 1:
            raise ValueError(f"ragged table: {lens}")

    @property
    def n_rows(self) -> int:
        return next(iter(self.columns.values())).shape[0]

    def col(self, name: str) -> jax.Array:
        return self.columns[name]

    def weights(self) -> jax.Array:
        if self.mask is None:
            return jnp.ones((self.n_rows,), jnp.float32)
        return self.mask

    def key_index(self, name: str) -> Tuple[jax.Array, jax.Array]:
        """(order, sorted_keys) for ``name``, built once per column array."""
        hit = self.index_cache.get(name)
        if hit is None:
            k = self.columns[name]
            order = jnp.argsort(k)
            hit = (order, k[order])
            self.index_cache[name] = hit
        return hit

    def filter(self, pred: jax.Array) -> "Table":
        """AND a predicate into the selection mask (no data movement)."""
        w = self.weights() * pred.astype(jnp.float32)
        return Table(self.columns, w, self.index_cache)

    def with_columns(self, **cols: jax.Array) -> "Table":
        merged = dict(self.columns)
        merged.update(cols)
        cache = {k: v for k, v in self.index_cache.items() if k not in cols}
        return Table(merged, self.mask, cache)


def pkfk_join(fact: Table, dim: Table, fact_key: str, dim_key: str,
              take: Mapping[str, str]) -> Table:
    """Gather dim columns into the fact table through the PK (sorted index).

    ``take`` maps new-column-name -> dim-column-name. Misses zero the mask.
    The build-side sorted index comes from ``dim.key_index`` — cached on the
    Table, so joining the same dimension (or a filtered view of it) again
    re-uses the argsort instead of re-sorting per call site.
    """
    order, sk = dim.key_index(dim_key)
    pos = jnp.clip(jnp.searchsorted(sk, fact.col(fact_key)), 0, sk.shape[0] - 1)
    found = sk[pos] == fact.col(fact_key)
    dim_w = dim.weights()[order][pos]
    new_cols = {new: dim.col(src)[order][pos] for new, src in take.items()}
    out = fact.with_columns(**new_cols)
    return Table(out.columns, out.weights() * found.astype(jnp.float32) * dim_w,
                 out.index_cache)


# ---------------------------------------------------------------------------
# grouped aggregation: default XLA plan vs tuned fused-kernel plan
# ---------------------------------------------------------------------------
def group_aggregate(table: Table, key: str, n_groups: int,
                    aggs: Mapping[str, Tuple[str, str]], *,
                    executor: str = "xla", mode: Optional[str] = None,
                    n_partitions: int = 64, capacity_factor: float = 2.0
                    ) -> Dict[str, jax.Array]:
    """aggs: out_name -> (op, column); op in {sum, count, avg, max, min}.
    Masked rows contribute nothing. Returns dict of (n_groups,) arrays plus
    ``_count`` and ``_overflow`` (records beyond partition capacity on the
    kernel path; always 0 on the XLA path and the dense kernel path)."""
    if executor == "kernel":
        return _group_aggregate_kernel(table, key, n_groups, aggs, mode=mode,
                                       n_partitions=n_partitions,
                                       capacity_factor=capacity_factor)
    if executor != "xla":
        raise ValueError(f"unknown executor {executor!r}")
    return _group_aggregate_xla(table, key, n_groups, aggs)


def _group_aggregate_xla(table: Table, key: str, n_groups: int,
                         aggs: Mapping[str, Tuple[str, str]]
                         ) -> Dict[str, jax.Array]:
    """Default plan: one segment op per aggregate."""
    keys = jnp.clip(table.col(key), 0, n_groups - 1)
    w = table.weights()
    out: Dict[str, jax.Array] = {}
    cnt = jax.ops.segment_sum(w, keys, num_segments=n_groups)
    for name, (op, col) in aggs.items():
        if op == "count":
            out[name] = cnt
            continue
        v = table.col(col).astype(jnp.float32)
        if op in ("sum", "avg"):
            s = jax.ops.segment_sum(v * w, keys, num_segments=n_groups)
            out[name] = s if op == "sum" else s / jnp.maximum(cnt, 1.0)
        elif op == "max":
            big = jnp.where(w > 0, v, -jnp.inf)
            out[name] = jax.ops.segment_max(big, keys, num_segments=n_groups)
        elif op == "min":
            small = jnp.where(w > 0, v, jnp.inf)
            out[name] = jax.ops.segment_min(small, keys, num_segments=n_groups)
        else:
            raise ValueError(f"unknown agg op {op!r}")
    out["_count"] = cnt
    out["_overflow"] = jnp.zeros((), jnp.int32)
    return out


def _group_aggregate_kernel(table: Table, key: str, n_groups: int,
                            aggs: Mapping[str, Tuple[str, str]], *,
                            mode: Optional[str], n_partitions: int,
                            capacity_factor: float) -> Dict[str, jax.Array]:
    """Tuned plan: all distributive aggregates fused into one kernel sweep."""
    keys = jnp.clip(table.col(key), 0, n_groups - 1).astype(jnp.int32)
    w = table.weights()
    src: list = []                       # distinct sum/avg source columns
    for name, (op, col) in aggs.items():
        if op in ("sum", "avg") and col not in src:
            src.append(col)
        elif op not in ("sum", "avg", "count", "max", "min"):
            raise ValueError(f"unknown agg op {op!r}")
    # column 0 carries the weights (COUNT); masked rows have weight 0 so
    # they vanish from every fused sum.
    vals = jnp.stack(
        [w] + [table.col(c).astype(jnp.float32) * w for c in src], axis=1)
    if n_groups <= DENSE_GROUP_LIMIT:
        sums = _fused_dense(keys, vals, n_groups, mode=mode)
        overflow = jnp.zeros((), jnp.int32)
    else:
        sums, overflow = _fused_partitioned(
            keys, vals, n_groups, mode=mode, n_partitions=n_partitions,
            capacity_factor=capacity_factor)
    cnt = sums[:, 0]
    out: Dict[str, jax.Array] = {}
    for name, (op, col) in aggs.items():
        if op == "count":
            out[name] = cnt
        elif op == "sum":
            out[name] = sums[:, 1 + src.index(col)]
        elif op == "avg":
            out[name] = sums[:, 1 + src.index(col)] / jnp.maximum(cnt, 1.0)
        else:  # max/min: order statistics stay on exact XLA segment ops
            v = table.col(col).astype(jnp.float32)
            if op == "max":
                big = jnp.where(w > 0, v, -jnp.inf)
                out[name] = jax.ops.segment_max(big, keys,
                                                num_segments=n_groups)
            else:
                small = jnp.where(w > 0, v, jnp.inf)
                out[name] = jax.ops.segment_min(small, keys,
                                                num_segments=n_groups)
    out["_count"] = cnt
    out["_overflow"] = overflow.astype(jnp.int32)
    return out


def _fused_dense(keys: jax.Array, vals: jax.Array, n_groups: int, *,
                 mode: Optional[str], block: int = 512) -> jax.Array:
    """Small key domain: positional chunking, full-width tables, no sort.

    Rows are split into chunks by position; each chunk's (n_bins, C) table
    covers every group, so the result is the exact sum of chunk tables —
    no partitioning pass, no overflow possible. Padding rows carry zero
    values, so their bin placement is irrelevant."""
    N, C = vals.shape
    bins = max(128, -(-n_groups // 128) * 128)
    n_chunks = 8 if N >= 8 * block else 1
    per_chunk = -(-N // n_chunks)
    t = -(-per_chunk // block) * block
    pad = n_chunks * t - N
    k = jnp.pad(keys, (0, pad))
    v = jnp.pad(vals, ((0, pad), (0, 0)))
    table = hash_aggregate_multi(k.reshape(n_chunks, t),
                                 v.reshape(n_chunks, t, C),
                                 n_bins=bins, block=block, mode=mode)
    return table.sum(axis=0)[:n_groups]


def _fused_partitioned(keys: jax.Array, vals: jax.Array, n_groups: int, *,
                       mode: Optional[str], n_partitions: int,
                       capacity_factor: float, block: int = 256
                       ) -> Tuple[jax.Array, jax.Array]:
    """Large key domain: range partition, then fused per-partition tables.

    Range partitioning on the (clipped, dense) group ids makes the
    partition-local slot (key % range_size) collision-free, so the kernel
    result is EXACT whenever no partition overflows its capacity; overflow
    is counted and returned, as in aggregate.count_partitioned."""
    N, C = vals.shape
    range_size = -(-n_groups // n_partitions)
    bins = max(128, -(-range_size // 128) * 128)
    part = jnp.clip(keys // range_size, 0, n_partitions - 1)
    order = jnp.argsort(part, stable=True)
    sk, sv = keys[order], vals[order]
    counts_p = jnp.bincount(part, length=n_partitions)
    starts = jnp.cumsum(counts_p) - counts_p
    pad_t = int(max(block,
                    -(-int(N // n_partitions * capacity_factor) // block)
                    * block))
    pk, pv, overflow = pad_partitions(sk, sv, starts, counts_p, n_partitions,
                                      pad_t)
    local = jnp.where(pk < 0, 0, pk % range_size)   # padded vals are zero
    table = hash_aggregate_multi(local, pv, n_bins=bins, block=block,
                                 mode=mode)
    flat = table[:, :range_size, :].reshape(n_partitions * range_size, C)
    return flat[:n_groups], overflow
