"""Mini columnar query executor (the W5 "database system" layer).

A Table is a struct-of-arrays with static length; selection is mask-based
(TPU-friendly: no compaction, predicates become aggregation weights), joins
are PK-FK gathers through a sorted index, and aggregations are masked
segment ops. The executor runs the TPC-H-style queries in tpch.py under the
same placement/allocator knobs as everything else.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass
class Table:
    columns: Dict[str, jax.Array]
    mask: Optional[jax.Array] = None     # float32 selection weights (None = 1)

    def __post_init__(self):
        lens = {c.shape[0] for c in self.columns.values()}
        if len(lens) != 1:
            raise ValueError(f"ragged table: {lens}")

    @property
    def n_rows(self) -> int:
        return next(iter(self.columns.values())).shape[0]

    def col(self, name: str) -> jax.Array:
        return self.columns[name]

    def weights(self) -> jax.Array:
        if self.mask is None:
            return jnp.ones((self.n_rows,), jnp.float32)
        return self.mask

    def filter(self, pred: jax.Array) -> "Table":
        """AND a predicate into the selection mask (no data movement)."""
        w = self.weights() * pred.astype(jnp.float32)
        return Table(self.columns, w)

    def with_columns(self, **cols: jax.Array) -> "Table":
        merged = dict(self.columns)
        merged.update(cols)
        return Table(merged, self.mask)


def pkfk_join(fact: Table, dim: Table, fact_key: str, dim_key: str,
              take: Mapping[str, str]) -> Table:
    """Gather dim columns into the fact table through the PK (sorted index).

    ``take`` maps new-column-name -> dim-column-name. Misses zero the mask.
    """
    dk = dim.col(dim_key)
    order = jnp.argsort(dk)
    sk = dk[order]
    pos = jnp.clip(jnp.searchsorted(sk, fact.col(fact_key)), 0, sk.shape[0] - 1)
    found = sk[pos] == fact.col(fact_key)
    dim_w = dim.weights()[order][pos]
    new_cols = {new: dim.col(src)[order][pos] for new, src in take.items()}
    out = fact.with_columns(**new_cols)
    return Table(out.columns, out.weights() * found.astype(jnp.float32) * dim_w)


def group_aggregate(table: Table, key: str, n_groups: int,
                    aggs: Mapping[str, Tuple[str, str]]) -> Dict[str, jax.Array]:
    """aggs: out_name -> (op, column); op in {sum, count, avg, max, min}.
    Masked rows contribute nothing. Returns dict of (n_groups,) arrays."""
    keys = jnp.clip(table.col(key), 0, n_groups - 1)
    w = table.weights()
    out: Dict[str, jax.Array] = {}
    cnt = jax.ops.segment_sum(w, keys, num_segments=n_groups)
    for name, (op, col) in aggs.items():
        if op == "count":
            out[name] = cnt
            continue
        v = table.col(col).astype(jnp.float32)
        if op in ("sum", "avg"):
            s = jax.ops.segment_sum(v * w, keys, num_segments=n_groups)
            out[name] = s if op == "sum" else s / jnp.maximum(cnt, 1.0)
        elif op == "max":
            big = jnp.where(w > 0, v, -jnp.inf)
            out[name] = jax.ops.segment_max(big, keys, num_segments=n_groups)
        elif op == "min":
            small = jnp.where(w > 0, v, jnp.inf)
            out[name] = jax.ops.segment_min(small, keys, num_segments=n_groups)
        else:
            raise ValueError(f"unknown agg op {op!r}")
    out["_count"] = cnt
    return out
