"""In-memory analytics engine: the paper's five workloads in JAX.

W1 holistic aggregation (median)      aggregate.median_direct / dist_median
W2 distributive aggregation (count)   aggregate.count_* / dist_count
W3 hash join                          join.hash_join / dist_hash_join
W4 index nested-loop join             join.index_join (radix/sorted/hash)
W5 TPC-H                              tpch.run_query (q1, q3, q5, q6, q18)

Queries are authored as logical plans (plan.py), lowered by the
cost-based planner (planner.lower) into an EXPLICIT physical plan
(physical.py: relational operators plus first-class Exchange/Compact
data-movement nodes, improved by aggregate push-down, route-once
exchange dedup/elision, and occupancy-aware compaction), and executed by
thin walkers over the columnar operators (columnar.py) — single-device
or under a placement-policy mesh backend (engine.py) — without changing
the plan. EVERY workload flows through that one IR/planner/cache:
dist_count, dist_median and dist_hash_join are thin wrappers over
logical plans (the holistic median is a "median" Aggregate op —
generalized to arbitrary-rank "quantile:R" — and the distributed join is
cost-chosen between broadcast and key-partitioned lowerings). Concurrent
multi-query serving (admission queue -> batcher -> morsel scheduler over
socket-pinned pools) lives in the service/ subpackage.
"""
from repro.analytics import datasets, physical, plan
from repro.analytics.aggregate import (count_direct, count_partitioned,
                                       median_direct)
from repro.analytics.engine import dist_count, dist_hash_join, dist_median
from repro.analytics.join import hash_join, index_join
from repro.analytics.planner import (CompiledPlan, ExecutionContext,
                                     compile_plan, execute_plan, explain,
                                     explain_analyze, explain_physical,
                                     load_cost_profile, lower,
                                     plan_cache_info)
from repro.analytics.telemetry import (StatsRegistry, disable_telemetry,
                                       enable_telemetry, refresh_profile,
                                       telemetry_enabled)
from repro.analytics.telemetry import recording as telemetry_recording
from repro.analytics.telemetry import registry as telemetry_registry
from repro.analytics.tracing import (FlightRecorder, Span, Trace, Tracer,
                                     disable_tracing, enable_tracing,
                                     tracer, tracing_enabled)
# the context manager is aliased so the package attribute ``tracing``
# stays the submodule (mirrors telemetry_recording)
from repro.analytics.tracing import tracing as tracing_scope
from repro.analytics.tpch import LOGICAL_QUERIES
from repro.analytics.tpch import generate as tpch_generate
from repro.analytics.tpch import run_query as tpch_run_query
