"""Physical plan IR: the explicit layer between logical plans and executors.

The paper's central claim is that data PLACEMENT AND MOVEMENT — not
compute — decide in-memory analytics performance on NUMA machines, and
that the profitable optimizations are cross-operator movement rewrites
(route once, aggregate before you ship, re-compact between hops). Those
rewrites need a representation where movement is a first-class node, not
an implementation detail buried inside an interpreter. This module is
that representation: ``planner.lower(plan, ctx)`` turns a logical plan
(plan.py) into a tree of the nodes below, every strategy decision —
join algorithm, aggregate layout, exchange kind, compaction point —
resolved to a plain field, and the executors in planner.py become thin
walkers that dispatch on node type.

Relational nodes (produce a Table per shard):

  PScan(table)                        base-table slice (row-sharded under
                                      a mesh, whole table locally)
  PFilter / PProject                  mask / derived columns (no movement)
  PJoin(probe, build, ..., strategy,  PK-FK join; ``strategy`` "sorted" |
        dist)                         "kernel"; ``dist`` records the
                                      distributed form ("broadcast" |
                                      "partitioned") for explain
  Exchange(child, kind, key, ...)     FIRST-CLASS DATA MOVEMENT:
                                        broadcast  all-gather a build side
                                        hash       all-to-all route rows to
                                                   their key's owner shard
                                        gather     converge all rows (the
                                                   PREFERRED policy plan)
                                      ``moved_rows`` is the estimated
                                      per-shard wire volume explain()
                                      reports; ``method`` picks the owner
                                      function ("hash" = multiplicative
                                      hash for clustered key spaces,
                                      "modulo" = the legacy dense-id map).
  Compact(child, capacity)            occupancy-aware re-compaction of a
                                      routed buffer: stable-partition the
                                      alive rows to the front and cut the
                                      buffer back to ``capacity`` rows, so
                                      chained partitioned joins stop
                                      growing padding multiplicatively
                                      (engine.compact_routed_rows).

Aggregation nodes (produce a replicated dict of (n_groups,) arrays):

  PPartialAggregate(child, ...)       per-shard (n_groups, C) stacked
                                      partial sums — the push-down half of
                                      a split distributive Aggregate
  PAggregate(child, ..., layout,      grouped/scalar aggregation; ``merge``
             merge, med_strategy)     names the distributed combine:
                                        None            single device
                                        "scalar"        psum'd globals
                                        "psum"          FIRST_TOUCH all-
                                                        reduce of partials
                                        "reduce_scatter" LOCAL_ALLOC
                                        "owner"         INTERLEAVE record
                                                        routing (child is a
                                                        hash Exchange)
                                        "pushdown"      partials routed by
                                                        group owner (child
                                                        is Exchange over
                                                        PPartialAggregate)
                                        "placed"        route-once: rows
                                                        already co-located
                                                        by the group key,
                                                        merge is a psum of
                                                        disjoint tables
                                        "gather"        PREFERRED converge
  PTopK / PAttach                     order-by-limit / group-result gather

Every node is a frozen dataclass — hashable and structurally comparable —
so executor memoization deduplicates structurally identical subtrees by
construction (two joins against the same build side share ONE routed
exchange), and the physical plan can live alongside the compiled
executable as the plan-cache value.

``rows`` is the node's PHYSICAL output rows per shard (buffer slots,
padding included); ``est`` is the estimated ALIVE rows per shard. The gap
between the two is what Compact reclaims, and what the rewrite rules in
this module (`maybe_pushdown`, `elide_exchange` via `placed_key`,
`maybe_compact`) consult.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple, Union

from repro.analytics import plan as L


# ---------------------------------------------------------------------------
# nodes
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PScan:
    table: str
    rows: int                 # physical rows per shard (padded under a mesh)
    est: int                  # estimated alive rows per shard


@dataclass(frozen=True)
class PFilter:
    """Row mask. ``pushed`` marks a filter the planner's
    Filter-below-Exchange peephole moved beneath a hash Exchange (it
    logically sat above the consuming join): rows it kills become dead
    padding BEFORE they reach the wire."""
    child: "PNode"
    pred: L.Expr
    rows: int
    est: int
    pushed: bool = False


@dataclass(frozen=True)
class PProject:
    child: "PNode"
    cols: Tuple[Tuple[str, L.Expr], ...]
    rows: int
    est: int


@dataclass(frozen=True)
class Exchange:
    """First-class data movement. ``kind``: "broadcast" (all-gather a build
    side), "hash" (all-to-all route rows to owner(``key``)), "gather"
    (converge all rows), "allreduce" (FIRST_TOUCH's psum of replicated
    (n_groups, C) partial tables: reduce-scatter + all-gather), or
    "reduce_scatter" (LOCAL_ALLOC's owner-sharded merge: the first half
    only). For hash exchanges ``capacity`` is the per-destination slot
    budget (output buffer = n_shards * capacity rows) and ``method`` the
    owner function; ``key=None`` marks a partial-sums exchange (rows are
    group ids, always modulo-owned). ``moved_rows`` is the estimated
    per-shard wire volume reported by explain(). "gather", "allreduce"
    and "reduce_scatter" execute FUSED inside the consuming PAggregate —
    the node exists so every policy's wire volume is priced on one
    axis. ``impl`` picks the routing layout pass for key-routing hash
    exchanges: "argsort" (stable argsort by owner) or "radix" (the
    radix-partition histogram kernel's prefix-sum layout,
    engine.radix_route_table_rows) — chosen by planner.lower per
    Exchange (exchange_costs) and bit-identical by construction."""
    child: "PNode"
    kind: str       # broadcast | hash | gather | allreduce | reduce_scatter
    key: Optional[str] = None
    capacity: int = 0
    method: str = "modulo"                  # hash | modulo owner function
    rows: int = 0
    est: int = 0
    moved_rows: int = 0
    impl: str = "argsort"                   # argsort | radix layout pass


@dataclass(frozen=True)
class Compact:
    """Occupancy-aware re-compaction of a routed buffer: keep the alive
    rows (stable order) in the first ``capacity`` slots, drop the rest of
    the padding. Alive rows beyond capacity are counted into the plan's
    ``_overflow`` (never silently dropped)."""
    child: "PNode"
    capacity: int
    rows: int                               # == capacity
    est: int


@dataclass(frozen=True)
class PJoin:
    """PK-FK join. ``morsel_split`` marks a LOCAL sorted-strategy probe
    phase the planner judged large enough to split into per-pool morsels
    (probe rows >= CostProfile.morsel_split_rows): the serving scheduler
    may then slice the probe side row-range-wise while the build side's
    pooled sort index is replicated once per worker pool
    (planner.probe_split / JoinIndexPool.replica). Purely advisory — the
    executors ignore it, so serial execution is untouched."""
    probe: "PNode"
    build: "PNode"
    probe_key: str
    build_key: str
    take: Tuple[Tuple[str, str], ...]
    strategy: str                           # sorted | kernel
    dist: Optional[str] = None              # None | broadcast | partitioned
    rows: int = 0
    est: int = 0
    morsel_split: bool = False              # probe phase is morsel-splittable


@dataclass(frozen=True)
class PPartialAggregate:
    """Per-shard (n_groups, C) stacked partial sums of the distributive
    aggregates — the below-the-exchange half of a pushed-down Aggregate."""
    child: "PNode"
    key: Optional[str]
    n_groups: int
    aggs: Tuple[Tuple[str, Tuple[str, str]], ...]
    layout: str                             # xla | dense | partitioned
    rows: int = 0                           # == n_groups
    est: int = 0


@dataclass(frozen=True)
class PAggregate:
    """Grouped (or scalar, ``key=None``) aggregation with every physical
    decision resolved: ``layout`` the local stacked-sums lowering,
    ``merge`` the distributed combine (see module docstring),
    ``med_strategy`` the holistic order-statistic plan ("replicate" |
    "route" | "placed" when the child is already co-located by the group
    key, so selection runs on the owner shard with no fresh Exchange |
    None when no median/quantile/distinct aggs)."""
    child: "PNode"
    key: Optional[str]
    n_groups: int
    aggs: Tuple[Tuple[str, Tuple[str, str]], ...]
    layout: str
    merge: Optional[str] = None
    med_strategy: Optional[str] = None
    rows: int = 0
    est: int = 0


@dataclass(frozen=True)
class PTopK:
    """Order-by-limit. ``dist`` records the distributed lowering:
    "replicated" selects on the merged (replicated) group table — free of
    movement of its own but only because the table was already replicated
    upstream; "candidates" selects each shard's local top-k over the group
    slots it owns and converges only k rows per shard through the child
    gather Exchange (k * n_shards candidate rows on the wire instead of
    the whole group table). None = single-device plan."""
    child: "PNode"
    col: str
    k: int
    index_name: str
    dist: Optional[str] = None              # None | replicated | candidates
    rows: int = 0
    est: int = 0


@dataclass(frozen=True)
class PAttach:
    child: "PNode"
    source: "PNode"
    key: str
    cols: Tuple[Tuple[str, str], ...]
    rows: int = 0
    est: int = 0


PNode = Union[PScan, PFilter, PProject, Exchange, Compact, PJoin,
              PPartialAggregate, PAggregate, PTopK, PAttach]


@dataclass(frozen=True)
class PhysicalPlan:
    """A physical root plus output selection and the mesh width it was
    lowered for (n_shards == 1 means a single-device plan)."""
    root: PNode
    outputs: Optional[Tuple[str, ...]] = None
    n_shards: int = 1


# ---------------------------------------------------------------------------
# traversal
# ---------------------------------------------------------------------------
def children(node: PNode) -> Tuple[PNode, ...]:
    if isinstance(node, PScan):
        return ()
    if isinstance(node, (PFilter, PProject, Exchange, Compact,
                         PPartialAggregate, PAggregate, PTopK)):
        return (node.child,)
    if isinstance(node, PJoin):
        return (node.probe, node.build)
    if isinstance(node, PAttach):
        return (node.child, node.source)
    raise TypeError(f"not a physical node: {node!r}")


def walk(node: PNode):
    """Yield every node of the subtree, root first (duplicates for shared
    structure — use walk_unique for movement accounting)."""
    yield node
    for c in children(node):
        yield from walk(c)


def walk_unique(node: PNode):
    """Yield each DISTINCT node once (structural identity) — the executor
    memoizes on structural equality, so this is what actually runs: two
    joins against the same build side share one routed Exchange."""
    seen = set()
    for n in walk(node):
        if n not in seen:
            seen.add(n)
            yield n


def exchanges(root: PNode) -> Tuple[Exchange, ...]:
    """Distinct Exchange nodes of a physical tree, plan order."""
    return tuple(n for n in walk_unique(root) if isinstance(n, Exchange))


def moved_rows(root: PNode) -> int:
    """Total estimated per-shard rows on the wire: the sum over DISTINCT
    exchanges (structural dedup = the route-once guarantee)."""
    return sum(e.moved_rows for e in exchanges(root))


# ---------------------------------------------------------------------------
# placement analysis (the route-once rewrite's oracle)
# ---------------------------------------------------------------------------
def placed_key(node: PNode) -> Optional[Tuple[str, str]]:
    """(key, owner_method) by which ``node``'s rows are already hash-placed
    across shards, or None.

    A hash Exchange places its output by its key; Filter/Project/Compact
    preserve placement (rows never move) unless a Project overwrites the
    key column; a partitioned PJoin's output rows ARE its routed probe
    rows, so the join preserves the probe side's placement. This is what
    lets the route-once rule skip an Exchange whose work an upstream
    Exchange already did."""
    while True:
        if isinstance(node, Exchange):
            if node.kind == "hash" and node.key is not None:
                return (node.key, node.method)
            return None
        if isinstance(node, Compact):
            node = node.child
        elif isinstance(node, PFilter):
            node = node.child
        elif isinstance(node, PProject):
            placed = placed_key(node.child)
            if placed is not None and any(n == placed[0]
                                          for n, _ in node.cols):
                return None          # key column overwritten
            return placed
        elif isinstance(node, PJoin):
            if node.dist is None:
                return None          # local join: no shard placement
            # a distributed join's output rows ARE its probe rows — a
            # partitioned join placed them via its probe Exchange, and a
            # broadcast join never moved them, so either way the probe
            # side's placement survives
            placed = placed_key(node.probe)
            if placed is not None and any(n == placed[0]
                                          for n, _ in node.take):
                return None          # take overwrote the key column
            return placed
        else:
            return None


def has_routed_buffer(node: PNode) -> bool:
    """True when ``node``'s ROWS include routed capacity padding (a hash
    Exchange over table rows feeds them), so occupancy-sensitive aggregate
    layouts (the range-partitioned fused kernel) must not be chosen on
    them. The walk stops at aggregation nodes: a PAggregate/PTopK output
    is a fresh replicated group table — an exchange buried below it never
    reaches the CURRENT row space (an Attach gathers only its columns)."""
    if isinstance(node, (PAggregate, PPartialAggregate, PTopK)):
        return False
    if isinstance(node, Exchange) and node.kind == "hash" \
            and node.key is not None:
        return True
    return any(has_routed_buffer(c) for c in children(node))


# ---------------------------------------------------------------------------
# rewrite rules (applied by planner.lower as it builds the tree)
# ---------------------------------------------------------------------------
def ceil128(n: int) -> int:
    """128-row tile rounding with a one-tile floor — THE slot-budget
    quantum: engine.routing_capacity and the Compact budgets both round
    through this one helper so routing capacities and compaction budgets
    can never desynchronize."""
    return max(128, -(-int(n) // 128) * 128)


def maybe_compact(child: PNode, margin: float, enabled: bool,
                  selectivity: float = 1.0) -> PNode:
    """Rule 3 — occupancy-aware compaction: before re-routing a buffer
    whose physical rows exceed its occupancy budget (``margin`` x
    estimated alive rows, 128-row tiles), insert a Compact so the next
    hash Exchange sizes its capacity from the COMPACTED rows. Without
    this, each hop of a chained partitioned join pads its successor's
    routing input by another capacity_factor (the ROADMAP padding-growth
    bug). ``margin`` is the occupancy-estimate headroom (COMPACT_MARGIN
    or the ExecutionContext.compact override), distinct from the routing
    capacity_factor, which absorbs per-destination routing skew.

    ``selectivity`` folds the (telemetry-refreshed) filter-selectivity
    estimate of the buffer's stacked PFilters into the budget — a buffer
    known to be mostly dead after filtering compacts tighter. The
    effective margin is CLAMPED at 1.0 x est: a mis-estimated selectivity
    may waste headroom, but it can never shrink the budget below the est
    the routing capacities were sized from (alive rows beyond the budget
    still surface as _overflow, never vanish)."""
    if not enabled:
        return child
    eff = max(margin * min(max(selectivity, 0.0), 1.0), 1.0)
    cap = ceil128(eff * max(child.est, 1))
    if cap >= child.rows:
        return child                 # buffer already tight: nothing to cut
    return Compact(child, capacity=cap, rows=cap, est=child.est)


def pushdown_profitable(n_groups: int, child_rows: int) -> bool:
    """Rule 1's cost test — aggregate push-down ships one partial-sums row
    per group instead of one row per record, so it wins exactly when the
    group domain is smaller than the per-shard input. Callers price
    ``child_rows`` as the estimated ALIVE input (est discounted by the
    profile's filter_selectivity per stacked PFilter), so a drifted
    selectivity refreshed by telemetry moves the crossover."""
    return n_groups < child_rows


def filters_below(node: PNode) -> int:
    """Number of PFilter nodes stacked directly below ``node`` (through
    Project/Compact wrappers). The Exchange moved-rows estimate consults
    this: a filter's est is NOT discounted (capacity/compact budgets must
    stay occupancy-safe), so each filter on the path instead multiplies
    the priced wire payload by the profile's filter_selectivity. The walk
    stops at any node that produces fresh rows (scan, join, exchange,
    aggregate)."""
    count = 0
    while True:
        if isinstance(node, PFilter):
            count += 1
            node = node.child
        elif isinstance(node, (PProject, Compact)):
            node = node.child
        else:
            return count


def routes_once(child: PNode, key: Optional[str]) -> bool:
    """Rule 2's test — True when ``child``'s rows are already co-located
    by ``key`` (an upstream hash Exchange on the same column did the
    work), so the Exchange a grouped INTERLEAVE Aggregate would insert can
    be elided: the records route ONE time for the join and the aggregate
    alike. The owner method does not matter here — any placement that
    co-locates a group's rows makes the disjoint-table psum merge exact."""
    if key is None:
        return False
    placed = placed_key(child)
    return placed is not None and placed[0] == key


# ---------------------------------------------------------------------------
# rendering (the explain() physical tree)
# ---------------------------------------------------------------------------
def describe(plan: Union[PhysicalPlan, PNode], indent: int = 0,
             annotate=None) -> str:
    """Deterministic physical-tree rendering: one line per node with its
    resolved strategy, buffer rows, and — for Exchange/Compact — the
    movement numbers. String-stable for fixed table shapes (golden-
    snapshot tested), so plans can be diffed across PRs. ``annotate``,
    when given, is a callable node -> str whose non-empty result is
    appended to that node's line (telemetry.explain_analyze uses it to
    print observed-vs-estimated rows per Decision)."""
    if isinstance(plan, PhysicalPlan):
        head = f"PhysicalPlan shards={plan.n_shards}"
        return head + "\n" + describe(plan.root, 1, annotate)
    pad = "  " * indent
    kids = children(plan)
    if isinstance(plan, PScan):
        line = f"PScan {plan.table} rows={plan.rows}"
    elif isinstance(plan, PFilter):
        line = f"PFilter {L.expr_str(plan.pred)}"
        if plan.pushed:
            line += " pushed=below-exchange"
    elif isinstance(plan, PProject):
        cols = ", ".join(f"{n}={L.expr_str(e)}" for n, e in plan.cols)
        line = f"PProject {cols}"
    elif isinstance(plan, Exchange):
        det = f"Exchange {plan.kind}"
        if plan.key is not None:
            det += f" key={plan.key} method={plan.method} impl={plan.impl}"
        elif plan.kind == "hash":
            det += " key=<group-partials>"
        if plan.capacity:
            det += f" capacity={plan.capacity}"
        line = f"{det} rows={plan.rows} moved~{plan.moved_rows}"
    elif isinstance(plan, Compact):
        line = (f"Compact capacity={plan.capacity} rows={plan.rows} "
                f"(from {plan.child.rows})")
    elif isinstance(plan, PJoin):
        det = f"PJoin {plan.probe_key}={plan.build_key} {plan.strategy}"
        if plan.dist:
            det += f" dist={plan.dist}"
        if plan.morsel_split:
            det += " morsel_split"
        line = f"{det} rows={plan.rows}"
    elif isinstance(plan, PPartialAggregate):
        line = (f"PPartialAggregate by {plan.key} groups={plan.n_groups} "
                f"layout={plan.layout}")
    elif isinstance(plan, PAggregate):
        aggs = ", ".join(f"{n}={op}({c})" for n, (op, c) in plan.aggs)
        det = f"PAggregate by {plan.key} groups={plan.n_groups} {aggs} " \
              f"layout={plan.layout}"
        if plan.merge:
            det += f" merge={plan.merge}"
        if plan.med_strategy:
            det += f" med={plan.med_strategy}"
        line = det
    elif isinstance(plan, PTopK):
        line = f"PTopK {plan.k} by {plan.col}"
        if plan.dist:
            line += f" dist={plan.dist}"
    elif isinstance(plan, PAttach):
        line = f"PAttach {dict(plan.cols)} via {plan.key}"
    else:
        raise TypeError(f"not a physical node: {plan!r}")
    if annotate is not None:
        extra = annotate(plan)
        if extra:
            line += " " + extra
    out = pad + line
    for c in kids:
        out += "\n" + describe(c, indent + 1, annotate)
    return out
