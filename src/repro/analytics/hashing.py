"""Hashing + partition-layout utilities shared by the analytics operators.

``multiply_shift`` is the classic universal hash (Dietzfelbinger); on TPU it
is one vector multiply + shift — the same choice state-of-the-art CPU joins
use, so FLOP parity with the paper's codebase is preserved.

``pad_partitions`` converts the (contiguous-but-ragged) output of
radix_partition into the dense (P, padT) layout the Pallas kernels consume.
Capacity follows a capacity-factor convention (like the MoE dispatch);
overflow is counted and surfaced, never silently dropped.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

_KNUTH = jnp.uint32(2654435761)


def multiply_shift(keys: jax.Array, bits: int = 32) -> jax.Array:
    """32-bit multiplicative hash; returns uint32 with high bits well-mixed."""
    h = keys.astype(jnp.uint32) * _KNUTH
    if bits < 32:
        h = jax.lax.shift_right_logical(h, jnp.uint32(32 - bits))
    return h


def partition_of(keys: jax.Array, n_partitions: int) -> jax.Array:
    """Partition id from the TOP radix bits of the hash (uniform split)."""
    bits = max(1, int(n_partitions - 1).bit_length())
    h = multiply_shift(keys, 32)
    return (jax.lax.shift_right_logical(h, jnp.uint32(32 - bits))
            .astype(jnp.int32) % n_partitions)


def pad_partitions(sorted_keys: jax.Array, sorted_vals: jax.Array,
                   starts: jax.Array, counts: jax.Array, n_partitions: int,
                   pad_t: int, *, pad_key: int = -1
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Dense (P, padT) layout from partition-contiguous arrays.

    ``sorted_vals`` may carry trailing measure dims — (N,) or (N, C) — so a
    stacked multi-aggregate matrix rides through the same gather as its keys.
    Returns (keys (P, padT), vals (P, padT[, C]), overflow: total records
    beyond capacity). Padded slots carry ``pad_key`` and zero values."""
    idx = starts[:, None] + jnp.arange(pad_t)[None, :]          # (P, padT)
    valid = jnp.arange(pad_t)[None, :] < jnp.minimum(counts, pad_t)[:, None]
    idx = jnp.clip(idx, 0, sorted_keys.shape[0] - 1)
    keys = jnp.where(valid, sorted_keys[idx], pad_key)
    vmask = valid.reshape(valid.shape + (1,) * (sorted_vals.ndim - 1))
    vals = jnp.where(vmask, sorted_vals[idx], 0)
    overflow = jnp.maximum(counts - pad_t, 0).sum()
    return keys, vals, overflow
