"""Synthetic datasets from the paper (Section 4.2), scaled by a factor.

Paper defaults: 100M records, group-by cardinality 1M for aggregations;
join tables 16M (build) : 256M (probe) — the Blanas'11 decision-support
ratio. All generators are numpy (host side — this is the data pipeline's
source, sharded across hosts by ``repro.data.pipeline``), deterministic
under a seed.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple

import numpy as np

PAPER_N_RECORDS = 100_000_000
PAPER_CARDINALITY = 1_000_000
PAPER_BUILD = 16_000_000
PAPER_PROBE = 256_000_000


@dataclass(frozen=True)
class AggDataset:
    keys: np.ndarray    # (N,) int32 group keys in [0, cardinality)
    vals: np.ndarray    # (N,) float32 measures
    cardinality: int
    name: str


def moving_cluster(n: int, cardinality: int, *, window_frac: float = 0.1,
                   seed: int = 0) -> AggDataset:
    """Keys drawn from a window that slides across the key space (streaming/
    spatial locality pattern)."""
    rng = np.random.RandomState(seed)
    w = max(1, int(cardinality * window_frac))
    offset = (np.arange(n, dtype=np.int64) * max(1, cardinality - w)) // max(1, n - 1)
    keys = (offset + rng.randint(0, w, n)) % cardinality
    return AggDataset(keys.astype(np.int32), rng.rand(n).astype(np.float32),
                      cardinality, "moving_cluster")


def sequential(n: int, cardinality: int, *, seed: int = 0) -> AggDataset:
    """Equal-length runs of incrementally increasing keys (transactional)."""
    rng = np.random.RandomState(seed)
    keys = (np.arange(n, dtype=np.int64) * cardinality // n).astype(np.int32)
    return AggDataset(keys, rng.rand(n).astype(np.float32), cardinality,
                      "sequential")


def zipf(n: int, cardinality: int, *, exponent: float = 0.5,
         seed: int = 0) -> AggDataset:
    """Zipf(e)-distributed keys via inverse-CDF sampling (paper: e = 0.5)."""
    rng = np.random.RandomState(seed)
    ranks = np.arange(1, cardinality + 1, dtype=np.float64)
    probs = ranks ** -exponent
    cdf = np.cumsum(probs)
    cdf /= cdf[-1]
    u = rng.rand(n)
    keys = np.searchsorted(cdf, u).astype(np.int32)
    # randomize which key ids are the heavy ones
    perm = rng.permutation(cardinality).astype(np.int32)
    return AggDataset(perm[keys], rng.rand(n).astype(np.float32),
                      cardinality, "zipf")


def heavy_hitter(n: int, cardinality: int, *, heavy_frac: float = 0.25,
                 seed: int = 0) -> AggDataset:
    """One key receives ``heavy_frac`` of all records; rest uniform."""
    rng = np.random.RandomState(seed)
    keys = rng.randint(0, cardinality, n).astype(np.int32)
    heavy = rng.rand(n) < heavy_frac
    keys[heavy] = rng.randint(0, cardinality)
    return AggDataset(keys, rng.rand(n).astype(np.float32), cardinality,
                      "heavy_hitter")


AGG_DATASETS = {
    "moving_cluster": moving_cluster,
    "sequential": sequential,
    "zipf": zipf,
    "heavy_hitter": heavy_hitter,
}


@dataclass(frozen=True)
class JoinDataset:
    build_keys: np.ndarray   # (R,) unique int32
    build_vals: np.ndarray   # (R,) float32
    probe_keys: np.ndarray   # (S,) int32, drawn from build keys (FK)
    probe_vals: np.ndarray   # (S,) float32
    name: str


def blanas_join(n_build: int, n_probe: int, *, seed: int = 0) -> JoinDataset:
    """PK-FK join tables at the paper's 1:16 ratio (Blanas'11)."""
    rng = np.random.RandomState(seed)
    build_keys = rng.permutation(n_build * 4)[:n_build].astype(np.int32)
    probe_keys = build_keys[rng.randint(0, n_build, n_probe)]
    return JoinDataset(build_keys, rng.rand(n_build).astype(np.float32),
                       probe_keys, rng.rand(n_probe).astype(np.float32),
                       "blanas_1_16")
