"""Shared subprocess snippet measuring the distributed-join lowerings.

Both consumers of broadcast-vs-partitioned timings build their child
process from THIS template — `benchmarks/fig7_index_join.py` (two-point
small/large-build comparison) and `scripts/calibrate_costs.py --dist`
(crossover sweep fitting ``dist_route_factor``). One copy matters: the
fitted routing-overhead constant is only meaningful if the calibration
measures exactly what the benchmark (and the planner's cost model)
prices, so the bench function, plan shape, and table generation must
never drift apart.

The child prints one JSON object: {str(build_n): {"broadcast": us,
"partitioned": us}} for each swept build size, joining a fixed-size probe
against it under each forced ``dist_join`` strategy.
"""

SWEEP_CODE = """
import json, time, numpy as np, jax, jax.numpy as jnp
from repro.analytics import plan as L
from repro.analytics import planner
from repro.core.config import PlacementPolicy

mesh = jax.make_mesh(({devices},), ("data",))
rng = np.random.RandomState(7)
probe_n = {probe}

def bench(fn, *args):
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(3):
        t0 = time.perf_counter(); jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[1] * 1e6

lplan = L.LogicalPlan(
    L.scan("probe").join(L.scan("build"), "pk", "bk", {{"_v": "bv"}})
     .aggregate(None, 1, count=("count", "_v"), checksum=("sum", "_v")),
    ("count", "checksum"))
res = {{}}
for build_n in {builds}:
    tables = {{
        "probe": {{"pk": jnp.asarray(
            rng.randint(0, build_n, probe_n).astype(np.int32))}},
        "build": {{"bk": jnp.asarray(rng.permutation(build_n)
                                     .astype(np.int32)),
                   "bv": jnp.asarray(rng.rand(build_n)
                                     .astype(np.float32))}}}}
    row = {{}}
    for strat in ("broadcast", "partitioned"):
        ctx = planner.ExecutionContext(executor="xla", mesh=mesh,
                                       policy=PlacementPolicy.FIRST_TOUCH,
                                       dist_join=strat)
        cp = planner.compile_plan(lplan, tables, ctx)
        row[strat] = bench(cp, tables)
    res[str(build_n)] = row
print(json.dumps(res))
"""


def sweep_code(*, probe: int, builds, devices: int) -> str:
    """The runnable child-process source for one (probe, builds) sweep."""
    return SWEEP_CODE.format(probe=probe, builds=sorted(builds),
                             devices=devices)
