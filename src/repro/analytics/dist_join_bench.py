"""Shared subprocess snippet measuring the distributed-join lowerings.

Both consumers of broadcast-vs-partitioned timings build their child
process from THIS template — `benchmarks/fig7_index_join.py` (two-point
small/large-build comparison) and `scripts/calibrate_costs.py --dist`
(crossover sweep fitting ``dist_route_factor``). One copy matters: the
fitted routing-overhead constant is only meaningful if the calibration
measures exactly what the benchmark (and the planner's cost model)
prices, so the bench function, plan shape, and table generation must
never drift apart.

The child prints one JSON object: {str(build_n): {"broadcast": us,
"partitioned": us}} for each swept build size, joining a fixed-size probe
against it under each forced ``dist_join`` strategy.

Two further snippets measure the PR-5 physical-plan movement rewrites on
the same subprocess-mesh harness: ``pushdown_code`` (one distributed
group-by, aggregate push-down forced on vs off, wall-clock + the physical
plan's estimated moved rows) and ``chain_code`` (two chained partitioned
joins, occupancy-aware Compact on vs off, wall-clock + the largest routed
buffer either plan materializes).

``exchange_code`` (PR 9) measures the hash Exchange ROUTING LAYOUT pass:
the same partitioned join with ``exchange_impl`` forced to the stable
argsort and to the radix-histogram layout at a sweep of probe sizes,
plus the cost model's own static pick and the plan's estimated moved
rows at each point. Shared by ``fig7_index_join.run_dist`` (two forced
rows + the pick) and ``calibrate_costs.py --exchange`` (crossover sweep
fitting ``radix_route_factor``) for the same one-copy reason as the
join sweep.
"""

# ONE timing helper shared (textually prepended) by every child template:
# warmup dispatch, then the median of timed iterations, results blocked.
# A change here changes every consumer in lockstep — the fitted
# dist_route_factor is only meaningful if calibration and benchmark time
# the same way.
BENCH_SNIPPET = """
import time as _time
import jax as _jax

def bench(fn, *args, iters=5):
    _jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = _time.perf_counter(); _jax.block_until_ready(fn(*args))
        ts.append(_time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e6
"""

SWEEP_CODE = BENCH_SNIPPET + """
import json, numpy as np, jax, jax.numpy as jnp
from repro.analytics import plan as L
from repro.analytics import planner
from repro.core.config import PlacementPolicy

mesh = jax.make_mesh(({devices},), ("data",))
rng = np.random.RandomState(7)
probe_n = {probe}

lplan = L.LogicalPlan(
    L.scan("probe").join(L.scan("build"), "pk", "bk", {{"_v": "bv"}})
     .aggregate(None, 1, count=("count", "_v"), checksum=("sum", "_v")),
    ("count", "checksum"))
res = {{}}
for build_n in {builds}:
    tables = {{
        "probe": {{"pk": jnp.asarray(
            rng.randint(0, build_n, probe_n).astype(np.int32))}},
        "build": {{"bk": jnp.asarray(rng.permutation(build_n)
                                     .astype(np.int32)),
                   "bv": jnp.asarray(rng.rand(build_n)
                                     .astype(np.float32))}}}}
    row = {{}}
    for strat in ("broadcast", "partitioned"):
        ctx = planner.ExecutionContext(executor="xla", mesh=mesh,
                                       policy=PlacementPolicy.FIRST_TOUCH,
                                       dist_join=strat)
        cp = planner.compile_plan(lplan, tables, ctx)
        row[strat] = bench(cp, tables)
    res[str(build_n)] = row
print(json.dumps(res))
"""


def sweep_code(*, probe: int, builds, devices: int) -> str:
    """The runnable child-process source for one (probe, builds) sweep."""
    return SWEEP_CODE.format(probe=probe, builds=sorted(builds),
                             devices=devices)


EXCHANGE_CODE = BENCH_SNIPPET + """
import json, numpy as np, jax, jax.numpy as jnp
from repro.analytics import plan as L
from repro.analytics import physical as PH
from repro.analytics import planner
from repro.core.config import PlacementPolicy

mesh = jax.make_mesh(({devices},), ("data",))
rng = np.random.RandomState(17)
build_n = {build}

lplan = L.LogicalPlan(
    L.scan("probe").join(L.scan("build"), "pk", "bk", {{"_v": "bv"}})
     .aggregate(None, 1, count=("count", "_v"), checksum=("sum", "_v")),
    ("count", "checksum"))

def probe_exchange(phys):
    # the LARGEST keyed hash Exchange is the probe-side routing pass
    return max((n for n in PH.walk_unique(phys.root)
                if isinstance(n, PH.Exchange) and n.key is not None),
               key=lambda n: n.rows)

res = {{}}
for probe_n in {probes}:
    tables = {{
        "probe": {{"pk": jnp.asarray(
            rng.randint(0, build_n, probe_n).astype(np.int32))}},
        "build": {{"bk": jnp.asarray(rng.permutation(build_n)
                                     .astype(np.int32)),
                   "bv": jnp.asarray(rng.rand(build_n)
                                     .astype(np.float32))}}}}
    row = {{}}
    for impl in ("argsort", "radix"):
        ctx = planner.ExecutionContext(executor="xla", mesh=mesh,
                                       policy=PlacementPolicy.FIRST_TOUCH,
                                       dist_join="partitioned",
                                       exchange_impl=impl)
        cp = planner.compile_plan(lplan, tables, ctx)
        row[impl] = bench(cp, tables)
    ctx = planner.ExecutionContext(executor="xla", mesh=mesh,
                                   policy=PlacementPolicy.FIRST_TOUCH,
                                   dist_join="partitioned",
                                   exchange_impl="cost")
    ex = probe_exchange(planner.compile_plan(lplan, tables, ctx).physical)
    row["cost_picks"] = ex.impl
    row["moved_rows"] = ex.moved_rows
    res[str(probe_n)] = row
print(json.dumps(res))
"""


def exchange_code(*, build: int, probes, devices: int) -> str:
    """Child source measuring one partitioned join with the Exchange
    routing layout forced to argsort and to radix at each probe size,
    plus the cost model's static pick and the plan's estimated moved
    rows at that point."""
    return EXCHANGE_CODE.format(build=build, probes=sorted(probes),
                                devices=devices)


PUSHDOWN_CODE = BENCH_SNIPPET + """
import json, numpy as np, jax, jax.numpy as jnp
from repro.analytics import plan as L
from repro.analytics import physical as PH
from repro.analytics import planner
from repro.core.config import PlacementPolicy

mesh = jax.make_mesh(({devices},), ("data",))
rng = np.random.RandomState(11)
N, G = {rows}, {groups}
tables = {{"t": {{"k": jnp.asarray(rng.randint(0, G, N).astype(np.int32)),
                  "v": jnp.asarray(rng.rand(N).astype(np.float32)),
                  "w": jnp.asarray(rng.rand(N).astype(np.float32))}}}}
lplan = L.LogicalPlan(
    L.scan("t").aggregate("k", G, s=("sum", "v"), s2=("sum", "w"),
                          a=("avg", "v"), c=("count", "v")),
    ("s", "s2", "a", "c", "_overflow"))

res = {{}}
for tag, pd in (("pushdown", True), ("no_pushdown", False)):
    ctx = planner.ExecutionContext(executor="xla", mesh=mesh,
                                   policy=PlacementPolicy.INTERLEAVE,
                                   agg_pushdown=pd)
    cp = planner.compile_plan(lplan, tables, ctx)
    out = cp(tables)
    assert int(np.asarray(out["_overflow"])) == 0, tag
    res[tag] = {{"us": bench(cp, tables),
                 "moved_rows": PH.moved_rows(cp.physical.root)}}
print(json.dumps(res))
"""


def pushdown_code(*, rows: int, groups: int, devices: int) -> str:
    """Child source measuring one distributed group-by with aggregate
    push-down forced on vs off (same plan, same mesh): wall-clock plus the
    physical plan's estimated per-shard moved rows."""
    return PUSHDOWN_CODE.format(rows=rows, groups=groups, devices=devices)


CHAIN_CODE = BENCH_SNIPPET + """
import json, numpy as np, jax, jax.numpy as jnp
from repro.analytics import plan as L
from repro.analytics import physical as PH
from repro.analytics import planner
from repro.core.config import PlacementPolicy

mesh = jax.make_mesh(({devices},), ("data",))
rng = np.random.RandomState(13)
N, D = {rows}, {dim}
tables = {{
    "fact": {{"k1": jnp.asarray(rng.randint(0, D, N).astype(np.int32)),
              "k2": jnp.asarray(rng.randint(0, D, N).astype(np.int32))}},
    "d1": {{"pk1": jnp.asarray(rng.permutation(D).astype(np.int32)),
            "v1": jnp.asarray(rng.rand(D).astype(np.float32))}},
    "d2": {{"pk2": jnp.asarray(rng.permutation(D).astype(np.int32)),
            "v2": jnp.asarray(rng.rand(D).astype(np.float32))}}}}
node = L.scan("fact").join(L.scan("d1"), "k1", "pk1", {{"_v1": "v1"}})
node = node.join(L.scan("d2"), "k2", "pk2", {{"_v2": "v2"}})
lplan = L.LogicalPlan(
    node.aggregate(None, 1, c=("count", "_v2"), s=("sum", "_v2")),
    ("c", "s", "_overflow"))

def max_buffer(phys):
    return max(n.rows for n in PH.walk_unique(phys.root)
               if isinstance(n, PH.Exchange) and n.key is not None)

res = {{}}
for tag, compact in (("compact", None), ("no_compact", False)):
    ctx = planner.ExecutionContext(executor="xla", mesh=mesh,
                                   policy=PlacementPolicy.INTERLEAVE,
                                   dist_join="partitioned", compact=compact)
    cp = planner.compile_plan(lplan, tables, ctx)
    out = cp(tables)
    assert int(np.asarray(out["_overflow"])) == 0, tag
    res[tag] = {{"us": bench(cp, tables),
                 "max_buffer_rows": max_buffer(cp.physical)}}
print(json.dumps(res))
"""


def chain_code(*, rows: int, dim: int, devices: int) -> str:
    """Child source measuring two chained partitioned joins with the
    occupancy-aware Compact pass on vs off: wall-clock plus the largest
    routed-buffer rows either plan materializes."""
    return CHAIN_CODE.format(rows=rows, dim=dim, devices=devices)


TOPK_CODE = BENCH_SNIPPET + """
import json, numpy as np, jax, jax.numpy as jnp
from repro.analytics import plan as L
from repro.analytics import physical as PH
from repro.analytics import planner, telemetry
from repro.core.config import PlacementPolicy

mesh = jax.make_mesh(({devices},), ("data",))
rng = np.random.RandomState(19)
N, G, K = {rows}, {groups}, {k}
tables = {{"t": {{"k": jnp.asarray(rng.randint(0, G, N).astype(np.int32)),
                  "v": jnp.asarray(rng.rand(N).astype(np.float32))}}}}
lplan = L.LogicalPlan(
    L.scan("t").aggregate("k", G, c=("count", "v"), s=("sum", "v"))
     .top_k("c", K, "top_idx"), ("c", "top_idx"))

res = {{}}
outs = {{}}
for mode in ("replicated", "candidates"):
    ctx = planner.ExecutionContext(executor="xla", mesh=mesh,
                                   policy=PlacementPolicy.INTERLEAVE,
                                   dist_topk=mode)
    cp = planner.compile_plan(lplan, tables, ctx)
    outs[mode] = cp(tables)
    res[mode] = bench(cp, tables)
    if mode == "candidates":
        res["moved_rows"] = cp.physical.root.child.moved_rows  # k*(n-1)
        with telemetry.recording() as reg:
            tcp = planner.compile_plan(lplan, tables, ctx)
            tcp(tables)
        ps = reg.get(tcp.cache_key)
        nodes = ps.node_list()
        ex = tcp.physical.root.child
        ns = [s for i, s in ps.nodes.items() if nodes[i] is ex][0]
        res["observed_moved"] = ns.last["moved"]   # k*(n-1)*n total
# both lowerings are bit-identical — counts and TopK indices are exact
for key in ("c", "top_idx"):
    assert np.array_equal(np.asarray(outs["replicated"][key]),
                          np.asarray(outs["candidates"][key])), key
res["cost_picks"] = planner.choose_dist_topk(
    G, K, {devices}, planner.ExecutionContext())
res["wire_budget"] = K * {devices}
print(json.dumps(res))
"""


def topk_code(*, rows: int, groups: int, k: int, devices: int) -> str:
    """Child source measuring one distributed order-by-limit with the
    TopK lowering forced to replicated and to candidates (bit-identity
    asserted in-process): wall-clock for both, the candidate Exchange's
    estimated and telemetry-observed wire rows, and the cost pick."""
    return TOPK_CODE.format(rows=rows, groups=groups, k=k, devices=devices)
