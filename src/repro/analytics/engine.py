"""Distributed analytics operators under the paper's placement policies.

This is the reproduction's centerpiece: the SAME logical query (W1/W2/W3)
executes under each memory placement policy (paper Section 3.3), and the
policies change only the *placement/communication plan*, never the query
code — the paper's application-agnostic thesis, realized as shard_map plans:

  FIRST_TOUCH  every shard aggregates into its own FULL-width table
               (the node that first touches a group owns a whole copy);
               merge = all-reduce over the table. Memory O(G)/shard,
               collective O(G * n) wire bytes. The OS-default analogue.
  LOCAL_ALLOC  same local tables, but the merge is a reduce-scatter: each
               shard ends up owning G/n of the result where its output
               "allocation" lives. Half the wire bytes of FIRST_TOUCH.
  INTERLEAVE   the table is bucket-interleaved across shards up front;
               records are routed to their owning shard (all-to-all of the
               DATA, O(N) wire bytes, independent of G) and aggregated once.
               Memory O(G/n)/shard. The paper's winner for shared state.
  PREFERRED    all records converge on one submesh slice (all-gather);
               models the paper's Preferred-x + its congestion.

For HOLISTIC aggregation (W1, median) partials cannot be merged, so
FIRST_TOUCH/LOCAL_ALLOC degrade to full record replication (all-gather of
data) — reproducing the paper's observation that holistic functions are the
memory system's worst case — while INTERLEAVE routes each group's records
to one owner and sorts locally.

Since PR 4 none of the workloads carries its own shard_map plan: W1/W2/W3
are logical plans lowered through the planner's distributed backend, and
this module provides the per-policy physical primitives those lowerings
(and the TPC-H plans) share — partial-table merging, record routing,
partitioned join routing, and distributed selection.

The AutoNUMA analogue (`auto_rebalance`) appends a policy-ideal resharding
of the result state after the query — pure extra collective traffic when
the plan was already local (paper Fig 5a), a rescue when the plan was
PREFERRED.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analytics.columnar import (concat_slices, segment_distinct,
                                      segment_median, segment_quantile,
                                      stacked_group_sums)
from repro.analytics.hashing import partition_of
from repro.analytics.physical import ceil128
from repro.kernels.radix_partition.ops import block_histograms
from repro.core.config import PlacementPolicy


# ---------------------------------------------------------------------------
# record routing (the all-to-all building block of INTERLEAVE)
# ---------------------------------------------------------------------------
def route_records(keys: jax.Array, vals: jax.Array, n_shards: int,
                  owner: jax.Array, capacity: int
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Bucket local records by owning shard into a dense (n, capacity) send
    layout. Returns (keys_out, vals_out, overflow). Padding key = -1.

    ``vals`` may carry trailing measure dims — (N,) or (N, C) — so a stacked
    multi-aggregate matrix rides through the same routing as its keys (the
    planner's INTERLEAVE Aggregate backend)."""
    if keys.shape[0] == 0:  # degenerate empty shard: all-padding send layout
        k_out = jnp.full((n_shards, capacity), -1, keys.dtype)
        v_out = jnp.zeros((n_shards, capacity) + vals.shape[1:], vals.dtype)
        return k_out, v_out, jnp.zeros((), jnp.int32)
    order = jnp.argsort(owner, stable=True)
    sk, sv, so = keys[order], vals[order], owner[order]
    counts = jnp.bincount(owner, length=n_shards)
    starts = jnp.cumsum(counts) - counts
    idx = starts[:, None] + jnp.arange(capacity)[None, :]
    valid = jnp.arange(capacity)[None, :] < jnp.minimum(counts, capacity)[:, None]
    idx = jnp.clip(idx, 0, max(keys.shape[0] - 1, 0))
    k_out = jnp.where(valid, sk[idx], -1)
    vmask = valid.reshape(valid.shape + (1,) * (sv.ndim - 1))
    v_out = jnp.where(vmask, sv[idx], 0)
    overflow = jnp.maximum(counts - capacity, 0).sum()
    return k_out, v_out, overflow


def route_owner(keys: jax.Array, alive: jax.Array, n: int,
                method: str = "modulo") -> jax.Array:
    """Owner shard for routing one row set: alive rows co-locate by key;
    dead rows — scan padding, masked rows, the padding of an upstream
    routed buffer — spread round-robin instead. Dead rows contribute
    nothing wherever they land, but co-located (e.g. all key -1 -> shard
    n-1, or all clipped to key 0 -> shard 0) they would mass on ONE
    destination and eat its capacity, surfacing overflow for records that
    do not exist. One copy of this rule serves every routed lowering.

    ``method`` picks the owner function. "modulo" (key % n) is ideal for
    DENSE id domains — group ids, permuted PKs — and is what the
    interleaved republish slot math (owner g = g % n, slot g // n)
    requires. "hash" takes the TOP radix bits of the multiplicative hash
    (hashing.partition_of — the same choice the join kernels make; the
    LOW hash bits are degenerate for power-of-two strides, where
    key * KNUTH stays a multiple of the stride): the right choice for
    CLUSTERED key spaces (sequential/moving-window keys, strided ids),
    where key % n would mass whole key runs — or every key of one stride
    class — onto a few shards."""
    spread = jnp.arange(keys.shape[0], dtype=jnp.int32) % n
    if method == "hash":
        owned = partition_of(keys, n)
    elif method == "modulo":
        owned = (keys % n).astype(jnp.int32)
    else:
        raise ValueError(f"unknown routing method {method!r}")
    return jnp.where(alive, owned, spread)


def routing_capacity(n_rows: int, n_shards: int,
                     capacity_factor: float) -> int:
    """Per-destination slot budget for routing ``n_rows`` local records to
    ``n_shards`` owners: the balanced share times ``capacity_factor``,
    rounded up to a 128-row tile (one copy of the formula every routed
    lowering shares; the tile rounding itself is physical.ceil128, shared
    with the Compact occupancy budgets)."""
    return ceil128(int(capacity_factor * n_rows / n_shards))


def route_table_rows(cols, weights: jax.Array, owner: jax.Array,
                     n_shards: int, capacity: int, axis: str):
    """All-to-all route a struct-of-arrays row set to its owner shards.

    Generalizes ``route_records`` to a whole table: ONE argsort-by-owner
    layout pass shared by every column, then one all-to-all per column.
    Integer columns pad with -1 (the key sentinel: padding never matches a
    real join key and is excluded from order statistics), floats with 0;
    ``weights`` rides along so routed padding rows carry zero selection
    weight. Returns (cols, weights, overflow) — the received buffers hold
    n_shards * capacity rows per shard; rows beyond a destination's
    capacity are counted in overflow (local, caller psums)."""
    n_rows = weights.shape[0]
    if n_rows == 0:
        return _empty_routed(cols, weights, n_shards, capacity)
    order = jnp.argsort(owner, stable=True)
    counts = jnp.bincount(owner, length=n_shards)
    starts = jnp.cumsum(counts) - counts
    slot = jnp.arange(capacity)
    idx = jnp.clip(starts[:, None] + slot[None, :], 0, max(n_rows - 1, 0))
    valid = slot[None, :] < jnp.minimum(counts, capacity)[:, None]

    def exchange(a, fill):
        sent = jnp.where(valid, a[order][idx], fill)
        return jax.lax.all_to_all(sent, axis, split_axis=0, concat_axis=0,
                                  tiled=True).reshape(-1)

    out = {c: exchange(a, -1 if jnp.issubdtype(a.dtype, jnp.integer) else 0)
           for c, a in cols.items()}
    w = exchange(weights, 0)
    overflow = jnp.maximum(counts - capacity, 0).sum()
    return out, w, overflow


def _empty_routed(cols, weights: jax.Array, n_shards: int, capacity: int):
    """Receive-side buffers for the degenerate empty shard (n_rows == 0).

    Under shard_map the row count is a static per-shard shape, so EVERY
    shard is empty when one is; each peer would only ever send padding, so
    the all-to-all is elided and the fully-padded receive buffers are built
    locally. Keeping this out of the main path also keeps the argsort /
    radix layout math free of ``n_rows - 1 == -1`` clip bounds."""
    size = n_shards * capacity
    out = {c: jnp.full((size,),
                       -1 if jnp.issubdtype(a.dtype, jnp.integer) else 0,
                       a.dtype)
           for c, a in cols.items()}
    w = jnp.zeros((size,), weights.dtype)
    return out, w, jnp.zeros((), jnp.int32)


def radix_route_table_rows(cols, weights: jax.Array, owner: jax.Array,
                           n_shards: int, capacity: int, axis: str, *,
                           block: int = 256, mode: Optional[str] = None):
    """All-to-all route a row set via the radix-partition histogram kernel.

    Same contract and BIT-IDENTICAL send layout as ``route_table_rows``,
    built without the argsort: per-block owner histograms come from
    ``block_histograms`` (kernel-mode resolved — the seed's Pallas MXU
    one-hot reduce on TPU, its oracle elsewhere), an exclusive prefix over
    blocks gives each block's base slot per destination, and a within-block
    running count gives each row's stable rank among its owner's rows. Rows
    then scatter straight into the (n_shards, capacity) send buffer at
    ``owner * capacity + rank`` — rank order equals position order, so the
    layout matches the stable argsort exactly and downstream reductions are
    bit-identical across the two paths. Rows ranked past ``capacity`` drop
    into the surfaced overflow count, exactly as the argsort path's
    ``valid`` mask does.

    ``owner`` is padded with zeros to a ``block`` multiple for the kernel
    (padding sits at the END, so real rows' ranks are unaffected) and the
    destination-0 count is corrected before the prefix sum. ``n_bins`` is
    the owner-domain [0, n_shards) rounded up to a power of two, as the
    digit mask requires."""
    n_rows = weights.shape[0]
    if n_rows == 0:
        return _empty_routed(cols, weights, n_shards, capacity)
    n_bins = 1 << max(1, (n_shards - 1).bit_length())
    pad = -n_rows % block
    owner = owner.astype(jnp.int32)
    owner_p = jnp.pad(owner, (0, pad)) if pad else owner
    hist = block_histograms(owner_p, n_bins=n_bins, shift=0, block=block,
                            mode=mode)                  # (n_blocks, n_bins)
    counts_all = hist.sum(axis=0)
    if pad:
        counts_all = counts_all.at[0].add(-pad)
    counts = counts_all[:n_shards]
    # Stable rank of each row among its destination's rows, without a sort:
    # exclusive block prefix (base slot of each block per bin) + exclusive
    # within-block running count of the row's own bin.
    block_base = jnp.cumsum(hist, axis=0) - hist        # (n_blocks, n_bins)
    ob = owner_p.reshape(-1, block)                     # (n_blocks, block)
    oh = (ob[:, :, None] ==
          jnp.arange(n_bins, dtype=jnp.int32)[None, None, :]).astype(jnp.int32)
    within = jnp.cumsum(oh, axis=1) - 1                 # (blocks, block, bins)
    rank_in_block = jnp.take_along_axis(within, ob[:, :, None], axis=2)[..., 0]
    base = jnp.take_along_axis(block_base, ob, axis=1)
    rank = (base + rank_in_block).reshape(-1)[:n_rows]
    pos = jnp.where(rank < capacity, owner * capacity + rank,
                    n_shards * capacity)                # OOB -> dropped

    def exchange(a, fill):
        sent = jnp.full((n_shards * capacity,), fill, a.dtype)
        sent = sent.at[pos].set(a, mode="drop").reshape(n_shards, capacity)
        return jax.lax.all_to_all(sent, axis, split_axis=0, concat_axis=0,
                                  tiled=True).reshape(-1)

    out = {c: exchange(a, -1 if jnp.issubdtype(a.dtype, jnp.integer) else 0)
           for c, a in cols.items()}
    w = exchange(weights, 0)
    overflow = jnp.maximum(counts - capacity, 0).sum()
    return out, w, overflow


def compact_routed_rows(cols, weights: jax.Array, capacity: int):
    """Occupancy-aware re-compaction of a routed buffer (the physical
    planner's ``Compact`` operator).

    A routed buffer holds n_shards * capacity slots but only ~its share of
    the ALIVE rows; feeding it to another routing pass sizes the next
    capacity from the padded length, so chained partitioned joins grow
    their buffers by a capacity_factor per hop. Compacting between hops
    stable-partitions the alive rows (weight > 0) to the front — original
    relative order preserved, so downstream float reductions stay
    deterministic — and cuts the buffer back to ``capacity`` rows. Alive
    rows beyond capacity are COUNTED into the returned overflow (the
    caller folds it into the plan's ``_overflow``), never dropped
    silently. Returns (cols, weights, overflow int32)."""
    alive = weights > 0
    order = jnp.argsort(jnp.where(alive, 0, 1).astype(jnp.int32),
                        stable=True)
    idx = order[:capacity]
    kept = {c: jnp.asarray(a)[idx] for c, a in cols.items()}
    w = weights[idx]
    n_alive = alive.sum()
    overflow = jnp.maximum(n_alive - capacity, 0).astype(jnp.int32)
    return kept, w, overflow


def pushdown_group_sums(partial: jax.Array, n_groups: int, axis: str,
                        n: int, *, capacity_factor: float = 2.0,
                        capacity: Optional[int] = None
                        ) -> Tuple[jax.Array, jax.Array]:
    """Aggregate push-down merge: exchange per-shard PARTIAL sums instead
    of records.

    ``partial`` is the local (n_groups, C) stacked-sums table. Each group
    row g routes to its modulo owner (g % n) — deterministic and balanced
    by construction, since every shard ships the same group ids — the
    owner adds its received contributions, and the merged rows republish
    in natural group order (the same slot math as interleave_group_sums).
    Per-shard wire volume is O(n_groups) rows where routing the records
    costs O(n_rows): the win the physical planner's push-down rewrite
    prices. ``capacity`` overrides the slot budget (the planner passes
    its Exchange node's capacity, as in interleave_group_sums). Returns
    ((n_groups, C) replicated, overflow) — overflow is 0 by construction
    for capacity_factor >= 1 (each destination receives exactly its owned
    groups from each source)."""
    G = n_groups
    g = jnp.arange(G, dtype=jnp.int32)
    owner = g % n
    cap = (capacity if capacity is not None
           else routing_capacity(G, n, capacity_factor))
    k_out, v_out, route_ovf = route_records(g, partial, n, owner, cap)
    k_in = jax.lax.all_to_all(k_out, axis, split_axis=0, concat_axis=0,
                              tiled=True)
    v_in = jax.lax.all_to_all(v_out, axis, split_axis=0, concat_axis=0,
                              tiled=True)
    n_slots = (G + (-G % n)) // n
    slot = jnp.where(k_in >= 0, k_in // n, n_slots)      # OOB drop slot
    local = jax.ops.segment_sum(v_in.reshape((-1,) + v_in.shape[2:]),
                                slot.reshape(-1), num_segments=n_slots + 1)
    gathered = jax.lax.all_gather(local[:n_slots], axis, tiled=True)
    full = gathered[(g % n) * n_slots + g // n]
    overflow = jax.lax.psum(route_ovf, axis)
    return full, overflow


# ---------------------------------------------------------------------------
# morsel-sliced distributive aggregation (the serving scheduler's unit)
# ---------------------------------------------------------------------------
# A morsel is a contiguous row range of a scan — the intra-node work-split
# analog of the paper's kernel load balancing: the serving scheduler
# (analytics/service/scheduler.py) dispatches morsels to socket-pinned
# worker pools and merges the per-morsel partial tables in MORSEL ORDER, so
# the merged result is deterministic for a fixed morsel size regardless of
# which pool executed which morsel (or in what order work stealing
# completed them).

def morsel_slices(n_rows: int, morsel_rows: Optional[int]
                  ) -> List[Tuple[int, int]]:
    """[lo, hi) row ranges covering n_rows; the last morsel takes the
    remainder when n_rows is not divisible by morsel_rows. None = one
    morsel (whole scan)."""
    if morsel_rows is not None and morsel_rows < 1:
        raise ValueError("morsel_rows must be >= 1")
    if morsel_rows is None or morsel_rows >= n_rows:
        return [(0, n_rows)]
    return [(lo, min(lo + morsel_rows, n_rows))
            for lo in range(0, n_rows, morsel_rows)]


def morsel_slice_columns(cols, lo, length: int):
    """Slice every column of a scan to one morsel's rows [lo, lo+length).

    ``length`` is static (jit specializes per morsel width — with a fixed
    morsel size only the tail morsel adds a second compilation) while
    ``lo`` stays a traced scalar, so one executable serves every aligned
    morsel of a scan."""
    return {c: jax.lax.dynamic_slice_in_dim(jnp.asarray(a), lo, length)
            for c, a in cols.items()}


def morsel_group_sums(keys: jax.Array, vals: jax.Array, n_groups: int, *,
                      layout: str = "xla", mode: Optional[str] = None,
                      n_partitions: int = 64, capacity_factor: float = 2.0
                      ) -> Tuple[jax.Array, jax.Array]:
    """Partial (n_groups, C) sums over ONE morsel's (already-sliced) rows.

    A named delegation to the shared stacked-group-sums recipe: the morsel
    path exercises the SAME physical layouts the planner chooses between,
    and the (sums, int32 overflow) pair is exactly what
    merge_morsel_partials folds."""
    return stacked_group_sums(
        keys, vals, n_groups, layout=layout, mode=mode,
        n_partitions=n_partitions, capacity_factor=capacity_factor)


def merge_morsel_partials(partials: Sequence[Tuple[Any, jax.Array]]
                          ) -> Tuple[Any, jax.Array]:
    """Merge per-morsel partials in morsel order.

    Two partial shapes flow through here:

    * distributive aggregates — (sums, overflow) pairs, left-folded by
      addition. The fold order is part of the result's float semantics:
      merging in sequence-number order (not completion order) keeps
      served answers deterministic under work stealing.
    * split-probe pipelines — ((columns_dict, mask), overflow): each
      morsel returns its slice of the pre-aggregate intermediate table,
      and concatenating the slices in sequence order reconstructs the
      serial table bit-for-bit (every on-path operator is per-row, so
      row lo..hi of the serial run IS morsel (lo, hi)'s output).
    """
    if not partials:
        raise ValueError("no morsel partials to merge")
    head = partials[0][0]
    if isinstance(head, tuple) and len(head) == 2 and isinstance(
            head[0], dict):
        merged = concat_slices([p[0] for p in partials])
        overflow = partials[0][1]
        for _, o in partials[1:]:
            overflow = overflow + o
        return merged, overflow
    sums, overflow = partials[0]
    for s, o in partials[1:]:
        sums = sums + s
        overflow = overflow + o
    return sums, overflow


# ---------------------------------------------------------------------------
# per-policy physical backends for the logical-plan Aggregate (planner.py)
# ---------------------------------------------------------------------------
# These run INSIDE an open shard_map over ``axis``: each shard holds a row
# slice of the table and the policy decides only the placement/communication
# plan of the shared group table — never the query semantics. FIRST_TOUCH /
# LOCAL_ALLOC merge per-shard partial tables (all-reduce vs reduce-scatter +
# all-gather); INTERLEAVE routes the records to bucket-interleaved owners
# before aggregating; PREFERRED converges all records on every shard (models
# the paper's Preferred-x congestion). All four return the same full-width
# replicated table, so one downstream plan serves every policy.

def merge_partial_table(table: jax.Array, policy: PlacementPolicy,
                        axis: str, n: int) -> jax.Array:
    """Merge per-shard partial (G, C) group tables into the full table.

    FIRST_TOUCH owns whole replicas -> all-reduce; LOCAL_ALLOC owns the
    output slice where it was allocated -> reduce-scatter, then an
    all-gather republishes the slices (G is padded to a multiple of n for
    the tiled collectives)."""
    if policy == PlacementPolicy.FIRST_TOUCH:
        return jax.lax.psum(table, axis)
    if policy == PlacementPolicy.LOCAL_ALLOC:
        G = table.shape[0]
        pad = -G % n
        padded = jnp.pad(table, ((0, pad),) + ((0, 0),) * (table.ndim - 1))
        shard = jax.lax.psum_scatter(padded, axis, scatter_dimension=0,
                                     tiled=True)
        return jax.lax.all_gather(shard, axis, tiled=True)[:G]
    raise ValueError(f"merge_partial_table does not implement {policy}")


def interleave_group_sums(keys: jax.Array, vals: jax.Array, n_groups: int,
                          axis: str, n: int, aggregate_fn, *,
                          capacity_factor: float = 2.0,
                          capacity: Optional[int] = None
                          ) -> Tuple[jax.Array, jax.Array]:
    """INTERLEAVE backend: route records to bucket-interleaved owners
    (all-to-all of the DATA, O(N) wire bytes), aggregate once on the owner,
    then republish. ``aggregate_fn(slot_ids, vals, n_slots) -> (sums, ovf)``
    is the shard-local aggregation (the planner passes the cost-chosen
    lowering, so the fused kernel path composes with this placement plan).
    NOTE: the routed (n, cap) buffer parks every padding slot on one extra
    drop slot with zero values, so ``aggregate_fn`` must use a layout whose
    result does not depend on row OCCUPANCY — xla segment ops or the dense
    chunked kernel, not the range-partitioned layout, whose per-partition
    capacity the massed padding rows would consume (dropping real records
    and reporting phantom overflow). ``capacity`` overrides the
    per-destination slot budget — the physical planner passes its
    Exchange node's capacity so the executed routing can never drift from
    the rendered plan. Returns ((n_groups, C) replicated, overflow)."""
    G_pad = n_groups + (-n_groups % n)
    if vals.ndim > 1:
        # column 0 of a stacked matrix carries the selection weights
        owner = route_owner(keys, vals[:, 0] > 0, n)
    else:
        owner = keys % n
    cap = (capacity if capacity is not None
           else routing_capacity(keys.shape[0], n, capacity_factor))
    k_out, v_out, route_ovf = route_records(keys, vals, n, owner, cap)
    k_in = jax.lax.all_to_all(k_out, axis, split_axis=0, concat_axis=0,
                              tiled=True)
    v_in = jax.lax.all_to_all(v_out, axis, split_axis=0, concat_axis=0,
                              tiled=True)
    # owned group g lives in local slot g // n (keys % n == my shard index)
    n_slots = G_pad // n
    slot = jnp.where(k_in >= 0, k_in // n, n_slots)      # OOB drop slot
    local, agg_ovf = aggregate_fn(slot.reshape(-1),
                                  v_in.reshape((-1,) + v_in.shape[2:]),
                                  n_slots + 1)
    gathered = jax.lax.all_gather(local[:n_slots], axis, tiled=True)
    g = jnp.arange(n_groups)
    full = gathered[(g % n) * n_slots + g // n]
    overflow = jax.lax.psum(route_ovf + agg_ovf, axis)
    return full, overflow


def gather_rows(arrs, axis: str):
    """PREFERRED backend building block: converge every shard's rows
    (all-gather of the data, the paper's congestion worst case)."""
    return jax.tree_util.tree_map(
        lambda a: jax.lax.all_gather(a, axis, tiled=True), arrs)


# ---------------------------------------------------------------------------
# W2: distributive COUNT under each policy
# ---------------------------------------------------------------------------
def dist_count(mesh: Mesh, policy: PlacementPolicy, cardinality: int, *,
               axis: str = "data", capacity_factor: float = 2.0,
               auto_rebalance: bool = False) -> Callable:
    """Build the policy's distributed COUNT plan.

    Returns fn(keys (N,) sharded over ``axis``) -> (G,) counts, replicated
    in natural group order under every policy.

    W2 no longer carries its own shard_map plan: the count is expressed as
    a logical ``Aggregate`` and lowered through the planner's distributed
    backend — the same per-policy collectives (merge_partial_table /
    interleave_group_sums / gather_rows) that serve the TPC-H plans, so
    there is exactly one copy of each placement strategy in the repo. This
    thin wrapper exists for the fig5 benchmark and callers that want the
    bare-operator signature. The AutoNUMA analogue is composed as a
    post-pass: a policy-ideal resharding of the already-merged table (pure
    extra collective traffic when the plan was already local, paper Fig
    5a)."""
    # planner imports engine's merge primitives; import lazily to avoid the
    # module cycle
    from repro.analytics import plan as L
    from repro.analytics import planner

    n = mesh.shape[axis]
    lplan = L.LogicalPlan(
        L.scan("keys").aggregate("k", cardinality, count=("count", "k")),
        ("count",))
    ctx = planner.ExecutionContext(executor="xla", mesh=mesh, policy=policy,
                                   axis=axis, capacity_factor=capacity_factor)
    rebalance = shard_map(
        lambda t: _rebalance_to_interleave(t, n, axis), mesh=mesh,
        in_specs=P(), out_specs=P(), check_rep=False)

    def fn(keys):
        counts = planner.execute_plan(lplan, {"keys": {"k": keys}},
                                      ctx)["count"]
        if auto_rebalance:  # AutoNUMA: reshard toward interleave post hoc
            counts = rebalance(counts)
        return counts

    return fn


def _rebalance_to_interleave(table: jax.Array, n: int, axis: str) -> jax.Array:
    """AutoNUMA analogue: migrate a replicated table toward interleaved
    ownership — pure extra collective traffic on an already-merged result.

    The input is the REPLICATED merged table (one identical copy per
    shard), so the reduce-scatter sums n copies; dividing AFTER the
    scatter keeps the migration value-preserving ((n*x)/n is exact for
    exactly-representable x, e.g. integer counts, where float32(x/n)
    summed n times is not — n=6 turns a count of 7 into 6.9999995). The
    leading dim is padded to a multiple of n for the tiled collectives
    (as in merge_partial_table) and sliced back after the gather."""
    G = table.shape[0]
    pad = -G % n
    padded = jnp.pad(table, ((0, pad),) + ((0, 0),) * (table.ndim - 1))
    shard = jax.lax.psum_scatter(padded, axis, scatter_dimension=0,
                                 tiled=True) / n
    return jax.lax.all_gather(shard, axis, tiled=True)[:G]


# ---------------------------------------------------------------------------
# holistic MEDIAN backends (per-policy lowerings of the Aggregate op)
# ---------------------------------------------------------------------------
# These run INSIDE an open shard_map, like the distributive backends above.
# A median cannot be merged from partials (paper Section 2), so the
# replication-based policies degrade to full record gathering — the paper's
# "holistic functions are the memory system's worst case" — while
# INTERLEAVE routes each group's records to one owner and selects locally
# (distributed selection). Both return natural-group-order replicated
# results so one downstream plan serves every policy.

def _select(k, v, n_groups, rank):
    """One sort-based selection: the median when ``rank`` is None, the
    exact distinct count when ``rank`` is the string "distinct", the
    interpolated ``rank`` quantile otherwise (all exclude keys < 0).
    ``rank`` is what plan.holistic_selector returns for the agg op."""
    if rank is None:
        return segment_median(k, v, n_groups)
    if rank == "distinct":
        return segment_distinct(k, v, n_groups)
    return segment_quantile(k, v, n_groups, rank)


def replicated_group_median(keys: jax.Array, cols, w: jax.Array,
                            n_groups: int, axis: str, ranks=None):
    """FIRST_TOUCH / LOCAL_ALLOC / PREFERRED holistic lowering: gather
    every shard's records (all-gather of the DATA) and run one local
    sort-based selection per value column. ``cols``: {name: (N,) values} —
    the keys/weights are gathered ONCE for all of them. ``ranks`` maps a
    column name to a quantile rank in (0, 1); absent/None means the
    median (the selection machinery is the same — a quantile is just a
    different selection index). Returns ({name: (n_groups,) order
    statistics}, counts), replicated."""
    ranks = ranks or {}
    ak = jax.lax.all_gather(keys, axis, tiled=True)
    aw = jax.lax.all_gather(w, axis, tiled=True)
    k_eff = jnp.where(aw > 0, ak, -1)
    meds, counts = {}, None
    for name, v in cols.items():
        av = jax.lax.all_gather(v, axis, tiled=True)
        meds[name], counts = _select(k_eff, av, n_groups, ranks.get(name))
    return meds, counts


def interleave_group_median(keys: jax.Array, cols, w: jax.Array,
                            n_groups: int, axis: str, n: int, *,
                            capacity_factor: float = 2.0, ranks=None):
    """INTERLEAVE holistic lowering: route each group's records to its
    bucket-interleaved owner (all-to-all, O(N) wire bytes), select the
    order statistic locally on the owner, then republish in natural group
    order. ``cols``: {name: (N,) values}; every value column rides ONE
    routing pass (one argsort-by-owner layout, keys/weights exchanged
    once). ``ranks`` as in replicated_group_median (None entry = median).
    Returns ({name: (n_groups,) order stats}, counts, overflow),
    replicated."""
    ranks = ranks or {}
    k_eff = jnp.where(w > 0, keys, -1).astype(jnp.int32)
    owner = route_owner(k_eff, k_eff >= 0, n)
    cap = routing_capacity(keys.shape[0], n, capacity_factor)
    # positional names: aggregate output names could collide with "k"
    send = {"k": k_eff}
    send.update({f"v{i}": v for i, v in enumerate(cols.values())})
    routed, w_in, ovf = route_table_rows(send, w, owner, n, cap, axis)
    n_slots = -(-n_groups // n)
    local_ids = jnp.where((routed["k"] >= 0) & (w_in > 0),
                          routed["k"] // n, -1)
    g = jnp.arange(n_groups)                       # owner of g is g % n
    pos = (g % n) * n_slots + g // n
    meds, counts = {}, None
    for i, name in enumerate(cols):
        med, cnt = _select(local_ids, routed[f"v{i}"], n_slots,
                           ranks.get(name))
        meds[name] = jax.lax.all_gather(med, axis, tiled=True)[pos]
        counts = jax.lax.all_gather(cnt, axis, tiled=True)[pos]
    return meds, counts, jax.lax.psum(ovf, axis)


def placed_group_median(keys: jax.Array, cols, w: jax.Array,
                        n_groups: int, axis: str, ranks=None):
    """Route-once holistic lowering: the child is ALREADY placed by the
    group key (e.g. a partitioned join routed every group's alive records
    to one owner shard), so each order statistic selects locally on
    whichever shard holds the group — no fresh Exchange. Exact because
    placement means exactly ONE shard holds ALL of a group's alive rows:
    its local selection over the full value set equals the global one,
    and every other shard sees an empty group (zero count) and is masked
    out of the merge. The merge is a psum of owner-only values — cheaper
    than re-routing O(N) records by a wide margin (O(G) wire rows).
    ``cols``/``ranks`` as in replicated_group_median. Returns
    ({name: (n_groups,) order stats}, counts), replicated."""
    ranks = ranks or {}
    k_eff = jnp.where(w > 0, keys, -1).astype(jnp.int32)
    meds, counts = {}, None
    for name, v in cols.items():
        sel = ranks.get(name)
        stat, cnt = _select(k_eff, v, n_groups, sel)
        cnt_all = jax.lax.psum(cnt, axis)
        if sel == "distinct":
            # a distinct count is 0 (not NaN) on non-owner shards: the
            # psum alone reconstructs the owner's exact count
            meds[name] = jax.lax.psum(stat, axis)
        else:
            stat_all = jax.lax.psum(jnp.where(cnt > 0, stat, 0.0), axis)
            meds[name] = jnp.where(cnt_all > 0, stat_all, jnp.nan)
        counts = cnt_all
    return meds, counts


# ---------------------------------------------------------------------------
# W1: holistic MEDIAN under each policy
# ---------------------------------------------------------------------------
def dist_median(mesh: Mesh, policy: PlacementPolicy, cardinality: int, *,
                axis: str = "data", capacity_factor: float = 2.0) -> Callable:
    """fn(keys, vals) -> (G,) per-group medians, replicated in natural
    group order under every policy.

    W1 no longer carries its own shard_map plan: the median is expressed
    as a logical ``Aggregate`` with an order-statistic ("median") agg and
    lowered through the planner's distributed backend onto the holistic
    primitives above — FIRST_TOUCH / LOCAL_ALLOC / PREFERRED degrade to
    full record replication, INTERLEAVE runs the routed distributed
    selection. One copy of each placement strategy serves W1 and every
    TPC-H median plan alike; this thin wrapper keeps the bare-operator
    signature for the fig5 benchmark."""
    from repro.analytics import plan as L
    from repro.analytics import planner

    lplan = L.LogicalPlan(
        L.scan("t").aggregate("k", cardinality, med=("median", "v")),
        ("med",))
    ctx = planner.ExecutionContext(executor="xla", mesh=mesh, policy=policy,
                                   axis=axis, capacity_factor=capacity_factor)

    def fn(keys, vals):
        return planner.execute_plan(lplan, {"t": {"k": keys, "v": vals}},
                                    ctx)["med"]

    return fn


# ---------------------------------------------------------------------------
# W3: hash join under each policy
# ---------------------------------------------------------------------------
def dist_hash_join(mesh: Mesh, policy: PlacementPolicy, *,
                   axis: str = "data", capacity_factor: float = 2.0) -> Callable:
    """fn(build_keys, build_vals, probe_keys) -> (count, checksum).

    W3 no longer carries its own shard_map plan: the join is a logical
    ``Join`` + global ``Aggregate`` lowered through the planner's
    distributed backend. The placement policy fixes the physical join
    strategy the cost model would otherwise choose: INTERLEAVE routes both
    sides by join-key hash (partitioned join, the paper's winner for large
    build sides); the replication-based policies broadcast the build side
    (all-gather, as a first-touching shard would fault it in). PREFERRED's
    record convergence lives in its Aggregate lowering."""
    from repro.analytics import plan as L
    from repro.analytics import planner

    probe = L.scan("probe").join(L.scan("build"), "pk", "bk", {"_v": "bv"})
    lplan = L.LogicalPlan(
        probe.aggregate(None, 1, count=("count", "_v"),
                        checksum=("sum", "_v")),
        ("count", "checksum"))
    dist_join = ("partitioned" if policy == PlacementPolicy.INTERLEAVE
                 else "broadcast")
    # dist_route="modulo": the retired W3 shard_map plan routed by key % n,
    # and the pinned fixture (tests/fixtures/w1w3_retired_plans.npz) checks
    # the float checksums BIT-exactly — identical data movement, identical
    # per-shard reduction order. New plans default to hash-based routing.
    ctx = planner.ExecutionContext(executor="xla", mesh=mesh, policy=policy,
                                   axis=axis, capacity_factor=capacity_factor,
                                   dist_join=dist_join, dist_route="modulo")

    def fn(bk, bv, pk):
        out = planner.execute_plan(
            lplan, {"probe": {"pk": pk}, "build": {"bk": bk, "bv": bv}}, ctx)
        return out["count"][0], out["checksum"][0]

    return fn
