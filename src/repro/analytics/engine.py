"""Distributed analytics operators under the paper's placement policies.

This is the reproduction's centerpiece: the SAME logical query (W1/W2/W3)
executes under each memory placement policy (paper Section 3.3), and the
policies change only the *placement/communication plan*, never the query
code — the paper's application-agnostic thesis, realized as shard_map plans:

  FIRST_TOUCH  every shard aggregates into its own FULL-width table
               (the node that first touches a group owns a whole copy);
               merge = all-reduce over the table. Memory O(G)/shard,
               collective O(G * n) wire bytes. The OS-default analogue.
  LOCAL_ALLOC  same local tables, but the merge is a reduce-scatter: each
               shard ends up owning G/n of the result where its output
               "allocation" lives. Half the wire bytes of FIRST_TOUCH.
  INTERLEAVE   the table is bucket-interleaved across shards up front;
               records are routed to their owning shard (all-to-all of the
               DATA, O(N) wire bytes, independent of G) and aggregated once.
               Memory O(G/n)/shard. The paper's winner for shared state.
  PREFERRED    all records converge on one submesh slice (all-gather);
               models the paper's Preferred-x + its congestion.

For HOLISTIC aggregation (W1, median) partials cannot be merged, so
FIRST_TOUCH/LOCAL_ALLOC degrade to full record replication (all-gather of
data) — reproducing the paper's observation that holistic functions are the
memory system's worst case — while INTERLEAVE routes each group's records
to one owner and sorts locally.

The AutoNUMA analogue (`auto_rebalance`) appends a policy-ideal resharding
of the result state after the query — pure extra collective traffic when
the plan was already local (paper Fig 5a), a rescue when the plan was
PREFERRED.
"""
from __future__ import annotations

import functools
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analytics.columnar import stacked_group_sums
from repro.core.config import PlacementPolicy


# ---------------------------------------------------------------------------
# record routing (the all-to-all building block of INTERLEAVE)
# ---------------------------------------------------------------------------
def route_records(keys: jax.Array, vals: jax.Array, n_shards: int,
                  owner: jax.Array, capacity: int
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Bucket local records by owning shard into a dense (n, capacity) send
    layout. Returns (keys_out, vals_out, overflow). Padding key = -1.

    ``vals`` may carry trailing measure dims — (N,) or (N, C) — so a stacked
    multi-aggregate matrix rides through the same routing as its keys (the
    planner's INTERLEAVE Aggregate backend)."""
    order = jnp.argsort(owner, stable=True)
    sk, sv, so = keys[order], vals[order], owner[order]
    counts = jnp.bincount(owner, length=n_shards)
    starts = jnp.cumsum(counts) - counts
    idx = starts[:, None] + jnp.arange(capacity)[None, :]
    valid = jnp.arange(capacity)[None, :] < jnp.minimum(counts, capacity)[:, None]
    idx = jnp.clip(idx, 0, keys.shape[0] - 1)
    k_out = jnp.where(valid, sk[idx], -1)
    vmask = valid.reshape(valid.shape + (1,) * (sv.ndim - 1))
    v_out = jnp.where(vmask, sv[idx], 0)
    overflow = jnp.maximum(counts - capacity, 0).sum()
    return k_out, v_out, overflow


# ---------------------------------------------------------------------------
# morsel-sliced distributive aggregation (the serving scheduler's unit)
# ---------------------------------------------------------------------------
# A morsel is a contiguous row range of a scan — the intra-node work-split
# analog of the paper's kernel load balancing: the serving scheduler
# (analytics/service/scheduler.py) dispatches morsels to socket-pinned
# worker pools and merges the per-morsel partial tables in MORSEL ORDER, so
# the merged result is deterministic for a fixed morsel size regardless of
# which pool executed which morsel (or in what order work stealing
# completed them).

def morsel_slices(n_rows: int, morsel_rows: Optional[int]
                  ) -> List[Tuple[int, int]]:
    """[lo, hi) row ranges covering n_rows; the last morsel takes the
    remainder when n_rows is not divisible by morsel_rows. None = one
    morsel (whole scan)."""
    if morsel_rows is not None and morsel_rows < 1:
        raise ValueError("morsel_rows must be >= 1")
    if morsel_rows is None or morsel_rows >= n_rows:
        return [(0, n_rows)]
    return [(lo, min(lo + morsel_rows, n_rows))
            for lo in range(0, n_rows, morsel_rows)]


def morsel_slice_columns(cols, lo, length: int):
    """Slice every column of a scan to one morsel's rows [lo, lo+length).

    ``length`` is static (jit specializes per morsel width — with a fixed
    morsel size only the tail morsel adds a second compilation) while
    ``lo`` stays a traced scalar, so one executable serves every aligned
    morsel of a scan."""
    return {c: jax.lax.dynamic_slice_in_dim(jnp.asarray(a), lo, length)
            for c, a in cols.items()}


def morsel_group_sums(keys: jax.Array, vals: jax.Array, n_groups: int, *,
                      layout: str = "xla", mode: Optional[str] = None,
                      n_partitions: int = 64, capacity_factor: float = 2.0
                      ) -> Tuple[jax.Array, jax.Array]:
    """Partial (n_groups, C) sums over ONE morsel's (already-sliced) rows.

    A named delegation to the shared stacked-group-sums recipe: the morsel
    path exercises the SAME physical layouts the planner chooses between,
    and the (sums, int32 overflow) pair is exactly what
    merge_morsel_partials folds."""
    return stacked_group_sums(
        keys, vals, n_groups, layout=layout, mode=mode,
        n_partitions=n_partitions, capacity_factor=capacity_factor)


def merge_morsel_partials(partials: Sequence[Tuple[jax.Array, jax.Array]]
                          ) -> Tuple[jax.Array, jax.Array]:
    """Left-fold per-morsel (sums, overflow) partials in morsel order.

    The fold order is part of the result's float semantics: merging in
    sequence-number order (not completion order) keeps served answers
    deterministic under work stealing."""
    if not partials:
        raise ValueError("no morsel partials to merge")
    sums, overflow = partials[0]
    for s, o in partials[1:]:
        sums = sums + s
        overflow = overflow + o
    return sums, overflow


# ---------------------------------------------------------------------------
# per-policy physical backends for the logical-plan Aggregate (planner.py)
# ---------------------------------------------------------------------------
# These run INSIDE an open shard_map over ``axis``: each shard holds a row
# slice of the table and the policy decides only the placement/communication
# plan of the shared group table — never the query semantics. FIRST_TOUCH /
# LOCAL_ALLOC merge per-shard partial tables (all-reduce vs reduce-scatter +
# all-gather); INTERLEAVE routes the records to bucket-interleaved owners
# before aggregating; PREFERRED converges all records on every shard (models
# the paper's Preferred-x congestion). All four return the same full-width
# replicated table, so one downstream plan serves every policy.

def merge_partial_table(table: jax.Array, policy: PlacementPolicy,
                        axis: str, n: int) -> jax.Array:
    """Merge per-shard partial (G, C) group tables into the full table.

    FIRST_TOUCH owns whole replicas -> all-reduce; LOCAL_ALLOC owns the
    output slice where it was allocated -> reduce-scatter, then an
    all-gather republishes the slices (G is padded to a multiple of n for
    the tiled collectives)."""
    if policy == PlacementPolicy.FIRST_TOUCH:
        return jax.lax.psum(table, axis)
    if policy == PlacementPolicy.LOCAL_ALLOC:
        G = table.shape[0]
        pad = -G % n
        padded = jnp.pad(table, ((0, pad),) + ((0, 0),) * (table.ndim - 1))
        shard = jax.lax.psum_scatter(padded, axis, scatter_dimension=0,
                                     tiled=True)
        return jax.lax.all_gather(shard, axis, tiled=True)[:G]
    raise ValueError(f"merge_partial_table does not implement {policy}")


def interleave_group_sums(keys: jax.Array, vals: jax.Array, n_groups: int,
                          axis: str, n: int, aggregate_fn, *,
                          capacity_factor: float = 2.0
                          ) -> Tuple[jax.Array, jax.Array]:
    """INTERLEAVE backend: route records to bucket-interleaved owners
    (all-to-all of the DATA, O(N) wire bytes), aggregate once on the owner,
    then republish. ``aggregate_fn(slot_ids, vals, n_slots) -> (sums, ovf)``
    is the shard-local aggregation (the planner passes the cost-chosen
    lowering, so the fused kernel path composes with this placement plan).
    NOTE: the routed (n, cap) buffer parks every padding slot on one extra
    drop slot with zero values, so ``aggregate_fn`` must use a layout whose
    result does not depend on row OCCUPANCY — xla segment ops or the dense
    chunked kernel, not the range-partitioned layout, whose per-partition
    capacity the massed padding rows would consume (dropping real records
    and reporting phantom overflow). Returns ((n_groups, C) replicated,
    overflow)."""
    G_pad = n_groups + (-n_groups % n)
    owner = keys % n
    cap = int(capacity_factor * keys.shape[0] / n)
    cap = max(128, -(-cap // 128) * 128)
    k_out, v_out, route_ovf = route_records(keys, vals, n, owner, cap)
    k_in = jax.lax.all_to_all(k_out, axis, split_axis=0, concat_axis=0,
                              tiled=True)
    v_in = jax.lax.all_to_all(v_out, axis, split_axis=0, concat_axis=0,
                              tiled=True)
    # owned group g lives in local slot g // n (keys % n == my shard index)
    n_slots = G_pad // n
    slot = jnp.where(k_in >= 0, k_in // n, n_slots)      # OOB drop slot
    local, agg_ovf = aggregate_fn(slot.reshape(-1),
                                  v_in.reshape((-1,) + v_in.shape[2:]),
                                  n_slots + 1)
    gathered = jax.lax.all_gather(local[:n_slots], axis, tiled=True)
    g = jnp.arange(n_groups)
    full = gathered[(g % n) * n_slots + g // n]
    overflow = jax.lax.psum(route_ovf + agg_ovf, axis)
    return full, overflow


def gather_rows(arrs, axis: str):
    """PREFERRED backend building block: converge every shard's rows
    (all-gather of the data, the paper's congestion worst case)."""
    return jax.tree_util.tree_map(
        lambda a: jax.lax.all_gather(a, axis, tiled=True), arrs)


# ---------------------------------------------------------------------------
# W2: distributive COUNT under each policy
# ---------------------------------------------------------------------------
def dist_count(mesh: Mesh, policy: PlacementPolicy, cardinality: int, *,
               axis: str = "data", capacity_factor: float = 2.0,
               auto_rebalance: bool = False) -> Callable:
    """Build the policy's distributed COUNT plan.

    Returns fn(keys (N,) sharded over ``axis``) -> (G,) counts, replicated
    in natural group order under every policy.

    W2 no longer carries its own shard_map plan: the count is expressed as
    a logical ``Aggregate`` and lowered through the planner's distributed
    backend — the same per-policy collectives (merge_partial_table /
    interleave_group_sums / gather_rows) that serve the TPC-H plans, so
    there is exactly one copy of each placement strategy in the repo. This
    thin wrapper exists for the fig5 benchmark and callers that want the
    bare-operator signature. The AutoNUMA analogue is composed as a
    post-pass: a policy-ideal resharding of the already-merged table (pure
    extra collective traffic when the plan was already local, paper Fig
    5a)."""
    # planner imports engine's merge primitives; import lazily to avoid the
    # module cycle
    from repro.analytics import plan as L
    from repro.analytics import planner

    n = mesh.shape[axis]
    lplan = L.LogicalPlan(
        L.scan("keys").aggregate("k", cardinality, count=("count", "k")),
        ("count",))
    ctx = planner.ExecutionContext(executor="xla", mesh=mesh, policy=policy,
                                   axis=axis, capacity_factor=capacity_factor)
    rebalance = shard_map(
        lambda t: _rebalance_to_interleave(t, n, axis), mesh=mesh,
        in_specs=P(), out_specs=P(), check_rep=False)

    def fn(keys):
        counts = planner.execute_plan(lplan, {"keys": {"k": keys}},
                                      ctx)["count"]
        if auto_rebalance:  # AutoNUMA: reshard toward interleave post hoc
            counts = rebalance(counts)
        return counts

    return fn


def _rebalance_to_interleave(table: jax.Array, n: int, axis: str) -> jax.Array:
    """AutoNUMA analogue: migrate a replicated table toward interleaved
    ownership — pure extra collective traffic on an already-merged result.

    The input is the REPLICATED merged table (one identical copy per
    shard), so the reduce-scatter sums n copies; dividing AFTER the
    scatter keeps the migration value-preserving ((n*x)/n is exact for
    exactly-representable x, e.g. integer counts, where float32(x/n)
    summed n times is not — n=6 turns a count of 7 into 6.9999995). The
    leading dim is padded to a multiple of n for the tiled collectives
    (as in merge_partial_table) and sliced back after the gather."""
    G = table.shape[0]
    pad = -G % n
    padded = jnp.pad(table, ((0, pad),) + ((0, 0),) * (table.ndim - 1))
    shard = jax.lax.psum_scatter(padded, axis, scatter_dimension=0,
                                 tiled=True) / n
    return jax.lax.all_gather(shard, axis, tiled=True)[:G]


# ---------------------------------------------------------------------------
# W1: holistic MEDIAN under each policy
# ---------------------------------------------------------------------------
def dist_median(mesh: Mesh, policy: PlacementPolicy, cardinality: int, *,
                axis: str = "data", capacity_factor: float = 2.0) -> Callable:
    """fn(keys, vals) -> per-group medians (ownership per policy)."""
    n = mesh.shape[axis]
    G = cardinality

    def _local_median(keys, vals, n_groups):
        order_v = jnp.argsort(vals, stable=True)
        k1, v1 = keys[order_v], vals[order_v]
        order_k = jnp.argsort(k1, stable=True)
        sk, sv = k1[order_k], v1[order_k]
        counts = jax.ops.segment_sum(
            jnp.ones_like(keys, jnp.float32),
            jnp.clip(keys, 0, n_groups - 1), num_segments=n_groups)
        # discard padding records (key < 0) from counts
        pad = jax.ops.segment_sum(
            jnp.where(keys < 0, 1.0, 0.0),
            jnp.zeros_like(keys), num_segments=n_groups)
        counts = counts - pad  # padding clipped to group 0
        starts = jnp.cumsum(counts) - counts
        # padded records sorted first (key -1): shift starts by total pad
        starts = starts + pad[0]
        c, s = counts.astype(jnp.int32), starts.astype(jnp.int32)
        lo = jnp.clip(s + jnp.maximum((c - 1) // 2, 0), 0, sv.shape[0] - 1)
        hi = jnp.clip(s + jnp.maximum(c // 2, 0), 0, sv.shape[0] - 1)
        med = (sv[lo] + sv[hi]) * 0.5
        return jnp.where(c > 0, med, jnp.nan)

    def replicate_all(keys, vals):                       # FT / LOCAL / PREF
        ak = jax.lax.all_gather(keys, axis, tiled=True)
        av = jax.lax.all_gather(vals, axis, tiled=True)
        return _local_median(ak, av, G)

    def interleave(keys, vals):
        owner = keys % n
        cap = int(capacity_factor * keys.shape[0] / n)
        cap = max(128, -(-cap // 128) * 128)
        k_out, v_out, _ = route_records(keys, vals, n, owner, cap)
        k_in = jax.lax.all_to_all(k_out, axis, 0, 0, tiled=True)
        v_in = jax.lax.all_to_all(v_out, axis, 0, 0, tiled=True)
        local_ids = jnp.where(k_in >= 0, k_in // n, -1).reshape(-1)
        return _local_median(local_ids, v_in.reshape(-1), G // n)

    if policy == PlacementPolicy.INTERLEAVE:
        fn, out_spec = interleave, P(axis)
    else:
        fn, out_spec = replicate_all, P(None)
    return shard_map(fn, mesh=mesh, in_specs=(P(axis), P(axis)),
                     out_specs=out_spec, check_rep=False)


# ---------------------------------------------------------------------------
# W3: hash join under each policy
# ---------------------------------------------------------------------------
def dist_hash_join(mesh: Mesh, policy: PlacementPolicy, *,
                   axis: str = "data", capacity_factor: float = 2.0) -> Callable:
    """fn(build_keys, build_vals, probe_keys) -> (count, checksum).

    FIRST_TOUCH / LOCAL_ALLOC: broadcast join — the build side is
    all-gathered (replicated, as a first-touching shard would fault it in),
    probes stay local. INTERLEAVE: both sides routed by key hash
    (partitioned join). PREFERRED: everything gathered (worst case)."""
    n = mesh.shape[axis]

    def _local_join(bk, bv, pk):
        order = jnp.argsort(bk)
        sk, sv = bk[order], bv[order]
        pos = jnp.clip(jnp.searchsorted(sk, pk), 0, sk.shape[0] - 1)
        found = (sk[pos] == pk) & (pk >= 0)
        vals = jnp.where(found, sv[pos], 0.0)
        return found.sum(), vals.sum()

    def broadcast(bk, bv, pk):
        abk = jax.lax.all_gather(bk, axis, tiled=True)
        abv = jax.lax.all_gather(bv, axis, tiled=True)
        c, s = _local_join(abk, abv, pk)
        return jax.lax.psum(c, axis), jax.lax.psum(s, axis)

    def interleave(bk, bv, pk):
        cap_b = max(128, -(-int(capacity_factor * bk.shape[0] / n) // 128) * 128)
        cap_p = max(128, -(-int(capacity_factor * pk.shape[0] / n) // 128) * 128)
        owner_b = (bk % n).astype(jnp.int32)
        owner_p = (pk % n).astype(jnp.int32)
        kb, vb, _ = route_records(bk, bv, n, owner_b, cap_b)
        kp, _, _ = route_records(pk, jnp.ones_like(pk, jnp.float32), n,
                                 owner_p, cap_p)
        kb = jax.lax.all_to_all(kb, axis, 0, 0, tiled=True).reshape(-1)
        vb = jax.lax.all_to_all(vb, axis, 0, 0, tiled=True).reshape(-1)
        kp = jax.lax.all_to_all(kp, axis, 0, 0, tiled=True).reshape(-1)
        kb = jnp.where(kb < 0, -1, kb)
        c, s = _local_join(kb, vb, kp)
        return jax.lax.psum(c, axis), jax.lax.psum(s, axis)

    def preferred(bk, bv, pk):
        abk = jax.lax.all_gather(bk, axis, tiled=True)
        abv = jax.lax.all_gather(bv, axis, tiled=True)
        apk = jax.lax.all_gather(pk, axis, tiled=True)
        return _local_join(abk, abv, apk)

    fn = {PlacementPolicy.FIRST_TOUCH: broadcast,
          PlacementPolicy.LOCAL_ALLOC: broadcast,
          PlacementPolicy.INTERLEAVE: interleave,
          PlacementPolicy.PREFERRED: preferred}[policy]
    return shard_map(fn, mesh=mesh,
                     in_specs=(P(axis), P(axis), P(axis)),
                     out_specs=(P(), P()), check_rep=False)
