"""W1 (holistic MEDIAN) and W2 (distributive COUNT) aggregation operators.

Two implementations per operator:
  *_direct       XLA-native (segment ops / sort) — oracle + small inputs.
  *_partitioned  the TPU-optimized pipeline: radix partition (Pallas
                 histogram) -> dense partition layout -> partition-local
                 kernel (hash_aggregate) or sort. This mirrors the paper's
                 state-of-the-art CPU pipeline (partition -> per-thread
                 table) with VMEM playing the role of the per-thread cache.

Holistic aggregation cannot be computed from partials (paper Section 2) —
median requires all of a group's values co-located; the sort-based
formulation is the TPU-idiomatic equivalent of the paper's per-group
vectors (documented adaptation, DESIGN.md Section 8).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analytics.hashing import pad_partitions, partition_of
from repro.kernels.hash_aggregate import hash_aggregate
from repro.kernels.radix_partition import radix_partition


# ---------------------------------------------------------------------------
# W2: distributive COUNT
# ---------------------------------------------------------------------------
def count_direct(keys: jax.Array, cardinality: int) -> jax.Array:
    """SELECT groupkey, COUNT(*) GROUP BY groupkey — XLA segment sum."""
    return jax.ops.segment_sum(jnp.ones_like(keys, jnp.float32), keys,
                               num_segments=cardinality)


@functools.partial(jax.jit, static_argnames=("cardinality", "n_partitions",
                                             "capacity_factor", "mode"))
def count_partitioned(keys: jax.Array, cardinality: int, *,
                      n_partitions: int = 64, capacity_factor: float = 2.0,
                      mode: Optional[str] = None
                      ) -> Tuple[jax.Array, jax.Array]:
    """Partitioned COUNT via range partitioning + the hash_aggregate kernel.

    Range partitioning on dense group ids makes the partition-local slot
    (key % range) collision-free — the kernel result is EXACT whenever no
    partition overflows its capacity (overflow is returned, never dropped
    silently). Returns (counts (cardinality,), overflow)."""
    N = keys.shape[0]
    range_size = -(-cardinality // n_partitions)          # ceil
    bins = max(128, -(-range_size // 128) * 128)          # kernel lane pad
    part = jnp.clip(keys // range_size, 0, n_partitions - 1)
    order = jnp.argsort(part, stable=True)
    sk = keys[order]
    counts_p = jnp.bincount(part, length=n_partitions)
    starts = jnp.cumsum(counts_p) - counts_p
    pad_t = int(max(256, -(-int(N // n_partitions * capacity_factor) // 256) * 256))
    pk, _, overflow = pad_partitions(sk, jnp.ones_like(sk, jnp.float32),
                                     starts, counts_p, n_partitions, pad_t)
    local = jnp.where(pk < 0, bins - 1, pk % range_size)  # padding -> dead bin
    vals = jnp.where(pk < 0, 0.0, 1.0)
    table = hash_aggregate(local, vals, n_bins=bins, mode=mode)  # (P, bins)
    flat = table[:, :range_size].reshape(-1)[:cardinality]
    # padding records landed in bins-1 which lies outside range_size unless
    # range_size == bins; mask that corner case exactly:
    if range_size == bins:
        pad_per_part = (pad_t - jnp.minimum(counts_p, pad_t)).astype(jnp.float32)
        flat = flat - jnp.zeros_like(flat).at[
            jnp.arange(n_partitions) * range_size + (bins - 1)
        ].add(pad_per_part)[:cardinality]
    return flat, overflow


# ---------------------------------------------------------------------------
# W1: holistic MEDIAN
# ---------------------------------------------------------------------------
def median_direct(keys: jax.Array, vals: jax.Array,
                  cardinality: int) -> jax.Array:
    """SELECT groupkey, MEDIAN(val) GROUP BY groupkey.

    Sort by (key, val) — stable two-pass sort — then pick the middle
    element(s) of each group run. Empty groups return NaN."""
    order_v = jnp.argsort(vals, stable=True)
    k1, v1 = keys[order_v], vals[order_v]
    order_k = jnp.argsort(k1, stable=True)
    sk, sv = k1[order_k], v1[order_k]
    counts = jax.ops.segment_sum(jnp.ones_like(keys, jnp.float32), keys,
                                 num_segments=cardinality)
    starts = jnp.cumsum(counts) - counts
    c = counts.astype(jnp.int32)
    s = starts.astype(jnp.int32)
    lo = s + jnp.maximum((c - 1) // 2, 0)
    hi = s + jnp.maximum(c // 2, 0)
    lo = jnp.clip(lo, 0, sv.shape[0] - 1)
    hi = jnp.clip(hi, 0, sv.shape[0] - 1)
    med = (sv[lo] + sv[hi]) * 0.5
    return jnp.where(c > 0, med, jnp.nan)


@functools.partial(jax.jit, static_argnames=("cardinality",))
def median_jit(keys: jax.Array, vals: jax.Array, cardinality: int) -> jax.Array:
    return median_direct(keys, vals, cardinality)
