"""W1 (holistic MEDIAN) and W2 (distributive COUNT) aggregation operators.

Two implementations per operator:
  *_direct       XLA-native (segment ops / sort) — oracle + small inputs.
  *_partitioned  the TPU-optimized pipeline: radix partition (Pallas
                 histogram) -> dense partition layout -> partition-local
                 kernel (hash_aggregate) or sort. This mirrors the paper's
                 state-of-the-art CPU pipeline (partition -> per-thread
                 table) with VMEM playing the role of the per-thread cache.

Holistic aggregation cannot be computed from partials (paper Section 2) —
median requires all of a group's values co-located; the sort-based
formulation is the TPU-idiomatic equivalent of the paper's per-group
vectors (documented adaptation, DESIGN.md Section 8).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analytics.columnar import stacked_group_sums


# ---------------------------------------------------------------------------
# W2: distributive COUNT
# ---------------------------------------------------------------------------
def count_direct(keys: jax.Array, cardinality: int) -> jax.Array:
    """SELECT groupkey, COUNT(*) GROUP BY groupkey — XLA segment sum."""
    return jax.ops.segment_sum(jnp.ones_like(keys, jnp.float32), keys,
                               num_segments=cardinality)


@functools.partial(jax.jit, static_argnames=("cardinality", "n_partitions",
                                             "capacity_factor", "mode"))
def count_partitioned(keys: jax.Array, cardinality: int, *,
                      n_partitions: int = 64, capacity_factor: float = 2.0,
                      mode: Optional[str] = None
                      ) -> Tuple[jax.Array, jax.Array]:
    """Partitioned COUNT via range partitioning + the hash_aggregate kernel.

    Range partitioning on dense group ids makes the partition-local slot
    (key % range) collision-free — the kernel result is EXACT whenever no
    partition overflows its capacity (overflow is returned, never dropped
    silently). Returns (counts (cardinality,), overflow).

    Thin wrapper: a COUNT is a fused sweep over a single all-ones weights
    column, so this delegates to the shared range-partitioned recipe in
    ``columnar.stacked_group_sums`` (COUNT always rides in column 0 of the
    stacked matrix — padded slots carry zero weight, so no dead-bin
    correction is needed)."""
    clipped = jnp.clip(keys, 0, cardinality - 1).astype(jnp.int32)
    ones = jnp.ones(keys.shape + (1,), jnp.float32)
    sums, overflow = stacked_group_sums(
        clipped, ones, cardinality, layout="partitioned", mode=mode,
        n_partitions=n_partitions, capacity_factor=capacity_factor)
    return sums[:, 0], overflow


# ---------------------------------------------------------------------------
# W1: holistic MEDIAN
# ---------------------------------------------------------------------------
def median_direct(keys: jax.Array, vals: jax.Array,
                  cardinality: int) -> jax.Array:
    """SELECT groupkey, MEDIAN(val) GROUP BY groupkey.

    Sort by (key, val) — stable two-pass sort — then pick the middle
    element(s) of each group run. Empty groups return NaN."""
    order_v = jnp.argsort(vals, stable=True)
    k1, v1 = keys[order_v], vals[order_v]
    order_k = jnp.argsort(k1, stable=True)
    sk, sv = k1[order_k], v1[order_k]
    counts = jax.ops.segment_sum(jnp.ones_like(keys, jnp.float32), keys,
                                 num_segments=cardinality)
    starts = jnp.cumsum(counts) - counts
    c = counts.astype(jnp.int32)
    s = starts.astype(jnp.int32)
    lo = s + jnp.maximum((c - 1) // 2, 0)
    hi = s + jnp.maximum(c // 2, 0)
    lo = jnp.clip(lo, 0, sv.shape[0] - 1)
    hi = jnp.clip(hi, 0, sv.shape[0] - 1)
    med = (sv[lo] + sv[hi]) * 0.5
    return jnp.where(c > 0, med, jnp.nan)


@functools.partial(jax.jit, static_argnames=("cardinality",))
def median_jit(keys: jax.Array, vals: jax.Array, cardinality: int) -> jax.Array:
    return median_direct(keys, vals, cardinality)
