"""Execution telemetry: observed Exchange/Compact/Join stats fed back
into the cost model (ROADMAP item 3 — adaptive execution).

The planner prices every data movement STATICALLY (``Exchange.est`` /
``moved_rows``, Compact margins, ``dist_route_factor``) from table shapes
alone — filter selectivity, key skew, and padding occupancy are invisible
to it. This module closes the loop:

  1. **Recording.** When telemetry is enabled (``enable_telemetry()`` or
     the ``recording()`` context manager), both executors emit per-node
     observed stats — alive rows into/out of every Exchange and Compact,
     rows that actually crossed shards, routing overflow, join input/
     output alive rows, occupied groups per aggregate — as extra traced
     outputs of the compiled plan (reserved key ``"_stats"``). The
     dispatch handle (``planner.CompiledPlan``) materializes them after
     each call into the bounded, thread-safe ``StatsRegistry``, keyed by
     plan-cache key + physical node id. Disabled (the default), zero
     traced operations are added and the jit is byte-identical to the
     untracked one — the flag is part of the plan-cache key.

  2. **Drift detection.** Each recorded execution compares observed
     alive/moved rows against the node's static estimate; entries outside
     the ``DRIFT_BAND`` (or any overflow) mark the plan as drifting.
     ``drift_report()`` lists every drifting node; ``refresh_profile()``
     rewrites the drifting ``CostProfile`` entries (``dist_route_factor``
     from observed/estimated moved rows, ``compact_margin`` from observed
     Compact occupancy) — dense-group-limit drift is reported but never
     auto-refreshed (the limit is a VMEM model, not a row estimate).

  3. **Re-planning.** On a plan-cache HIT of a drifting plan,
     ``planner.compile_plan`` re-lowers with the OBSERVED per-join alive
     rows substituted for the static shape estimates. If the cost model
     now flips a Decision (e.g. broadcast -> partitioned once the probe
     filter's true selectivity is known), the cache entry is replaced;
     results stay bit-identical because only the lowering changes, never
     the relational answer.

``explain_analyze(plan, tables, ctx)`` runs a plan under telemetry and
renders the physical tree with estimated-vs-observed rows per node —
the executable twin of ``planner.explain_physical``.

Wall-clock is recorded at PLAN grain (per dispatch): inside a jit the
operators fuse, so per-operator wall time is not observable — the
per-node row counters are the per-operator signal, the wall histogram
the per-plan one.

Everything here is stdlib + physical-IR only; the planner imports this
module, never the reverse (``explain_analyze`` imports the planner
lazily at call time).
"""
from __future__ import annotations

import math
import threading
from collections import OrderedDict, deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.analytics import physical as PH

# observed/estimated ratio outside [1/DRIFT_BAND, DRIFT_BAND] = drift
DRIFT_BAND = 1.25
# refresh clamps: one execution's ratio can rescale a constant by at most
# this factor in either direction (a single pathological batch cannot
# swing the profile to an extreme)
_REFRESH_CLAMP = 4.0


# ---------------------------------------------------------------------------
# enable flag
# ---------------------------------------------------------------------------
_ENABLED = False
_ENABLE_LOCK = threading.Lock()


def telemetry_enabled() -> bool:
    return _ENABLED


def enable_telemetry() -> None:
    global _ENABLED
    with _ENABLE_LOCK:
        _ENABLED = True


def disable_telemetry() -> None:
    global _ENABLED
    with _ENABLE_LOCK:
        _ENABLED = False


@contextmanager
def recording():
    """Enable recording for the duration of a block (not reference
    counted: nested blocks share the one global flag)."""
    prev = _ENABLED
    enable_telemetry()
    try:
        yield registry()
    finally:
        if not prev:
            disable_telemetry()


# ---------------------------------------------------------------------------
# per-node observed stats
# ---------------------------------------------------------------------------
@dataclass
class NodeStats:
    """Observed counters for one physical node of one cached plan.

    ``est`` maps stat name -> the static estimate it is compared against
    (GLOBAL rows — per-shard node fields are scaled by n_shards at
    registration). ``last`` holds the most recent execution's observed
    values, ``total`` their sum over executions (the conservation tests
    check ``last`` exactly; drift uses ``last`` so a corrected upstream
    decision clears stale drift immediately)."""
    kind: str                      # "exchange" | "compact" | "join" | ...
    detail: str                    # one-line node description
    est: Dict[str, int] = field(default_factory=dict)
    last: Dict[str, int] = field(default_factory=dict)
    total: Dict[str, int] = field(default_factory=dict)
    executions: int = 0

    def observe(self, vals: Dict[str, int]) -> None:
        self.executions += 1
        for k, v in vals.items():
            self.last[k] = int(v)
            self.total[k] = self.total.get(k, 0) + int(v)

    def drifts(self) -> List[Tuple[str, int, int, float]]:
        """(stat, est, observed, ratio) for every stat outside the band
        (overflow drifts whenever it is nonzero — an estimate that let a
        buffer overflow is mis-priced by definition)."""
        out = []
        if self.last.get("overflow", 0) > 0:
            out.append(("overflow", 0, self.last["overflow"], math.inf))
        for stat, est in self.est.items():
            obs = self.last.get(stat)
            if obs is None:
                continue
            ratio = (obs / est) if est > 0 else (math.inf if obs else 1.0)
            if not (1.0 / DRIFT_BAND) <= ratio <= DRIFT_BAND:
                out.append((stat, est, obs, ratio))
        return out


@dataclass
class PlanStats:
    """Registry value for one plan-cache key."""
    phys: PH.PhysicalPlan
    nodes: Dict[int, NodeStats] = field(default_factory=dict)
    executions: int = 0
    replans: int = 0
    pending_replan: bool = False
    wall_s: deque = field(default_factory=lambda: deque(maxlen=256))

    def node_list(self) -> List[PH.PNode]:
        return list(PH.walk_unique(self.phys.root))


def _node_estimates(node: PH.PNode, n: int) -> Tuple[str, Dict[str, int]]:
    """(kind, {stat: GLOBAL estimated rows}) for one physical node.

    Scaling per node kind mirrors the lowering's bookkeeping: hash
    Exchange / Compact ``est`` is per-shard alive rows; broadcast and
    gather Exchange ``est`` is already global (the whole gathered
    table)."""
    if isinstance(node, PH.Exchange):
        if node.kind == "hash":
            return "exchange", {"alive_in": node.est * n,
                                "moved": node.moved_rows * n}
        # broadcast/gather: est and moved_rows are global already
        return "exchange", {"alive_in": node.est,
                            "moved": node.moved_rows * n}
    if isinstance(node, PH.Compact):
        return "compact", {"alive_in": node.est * n}
    if isinstance(node, PH.PJoin) and node.dist is not None:
        probe = node.probe
        while isinstance(probe, (PH.Exchange, PH.Compact)):
            probe = probe.child
        build = node.build
        while isinstance(build, (PH.Exchange, PH.Compact)):
            build = build.child
        return "join", {"probe_alive": probe.est * n,
                        "build_alive": build.est * n}
    if isinstance(node, PH.PJoin):
        return "join", {}
    if isinstance(node, PH.PAggregate) and node.key is not None:
        return "aggregate", {"groups_occupied": node.n_groups}
    return type(node).__name__.lower(), {}


def _node_detail(node: PH.PNode) -> str:
    return PH.describe(node).splitlines()[0].strip()


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------
class StatsRegistry:
    """Bounded, thread-safe store of per-plan execution telemetry.

    Keys are plan-cache keys (hashable tuples); values PlanStats. LRU
    bounded so an always-on service with churning ad-hoc plans cannot
    grow it without bound."""

    def __init__(self, maxsize: int = 256):
        self.maxsize = maxsize
        self._lock = threading.Lock()
        self._plans: "OrderedDict[tuple, PlanStats]" = OrderedDict()
        self.replans = 0           # decision flips across all plans

    # -- recording ----------------------------------------------------------
    def record(self, key, phys: PH.PhysicalPlan,
               node_stats: Dict[int, Dict[str, int]],
               wall_s: float) -> None:
        """Fold one execution's observed stats in. ``node_stats`` maps
        node id (enumerate order of walk_unique over ``phys.root``) to
        {stat: observed int}."""
        n = max(phys.n_shards, 1)
        with self._lock:
            ps = self._plans.get(key)
            if ps is None or ps.phys != phys:
                # new plan, or a replan replaced the tree: node ids no
                # longer line up, start a fresh accumulator
                ps = PlanStats(phys)
                self._plans[key] = ps
            self._plans.move_to_end(key)
            while len(self._plans) > self.maxsize:
                self._plans.popitem(last=False)
            nodes = ps.node_list()
            ps.executions += 1
            ps.wall_s.append(float(wall_s))
            drifting = False
            for i, vals in node_stats.items():
                node = nodes[i]
                ns = ps.nodes.get(i)
                if ns is None:
                    kind, est = _node_estimates(node, n)
                    ns = NodeStats(kind, _node_detail(node), est)
                    ps.nodes[i] = ns
                ns.observe(vals)
                if ns.drifts():
                    drifting = True
            if drifting:
                ps.pending_replan = True

    # -- lookups ------------------------------------------------------------
    def get(self, key) -> Optional[PlanStats]:
        with self._lock:
            return self._plans.get(key)

    def plans(self) -> List[Tuple[tuple, PlanStats]]:
        with self._lock:
            return list(self._plans.items())

    def clear(self) -> None:
        with self._lock:
            self._plans.clear()
            self.replans = 0

    # -- re-planning protocol ----------------------------------------------
    def should_replan(self, key) -> bool:
        ps = self.get(key)
        return ps is not None and ps.pending_replan

    def note_replan_checked(self, key) -> None:
        ps = self.get(key)
        if ps is not None:
            ps.pending_replan = False

    def note_replanned(self, key, new_phys: PH.PhysicalPlan) -> None:
        with self._lock:
            ps = self._plans.get(key)
            self.replans += 1
            if ps is not None:
                # keep the execution/replan history, reset node stats to
                # the new tree (ids refer to the new walk order)
                fresh = PlanStats(new_phys)
                fresh.executions = ps.executions
                fresh.replans = ps.replans + 1
                fresh.wall_s = ps.wall_s
                self._plans[key] = fresh

    def observed_joins(self, key) -> Callable:
        """An ``observed(probe_key, build_key)`` lookup for re-lowering:
        the most recent OBSERVED global alive rows of each distributed
        join's inputs, consumed FIFO per key pair (re-lowering descends
        the same logical tree in the same order, so repeated joins over
        the same column pair line up; a plan pathological enough to break
        that alignment just re-derives the static choice)."""
        ps = self.get(key)
        fifo: Dict[Tuple[str, str], deque] = {}
        if ps is not None:
            nodes = ps.node_list()
            for i, ns in sorted(ps.nodes.items()):
                node = nodes[i]
                if (isinstance(node, PH.PJoin) and node.dist is not None
                        and "probe_alive" in ns.last):
                    fifo.setdefault(
                        (node.probe_key, node.build_key), deque()).append(
                            (ns.last["probe_alive"],
                             ns.last["build_alive"]))

        def observed(probe_key: str, build_key: str):
            q = fifo.get((probe_key, build_key))
            return q.popleft() if q else None

        return observed

    # -- reporting ----------------------------------------------------------
    def drift_report(self) -> List[Dict]:
        """Every drifting (plan, node, stat) triple, worst ratio first."""
        rows: List[Dict] = []
        for _key, ps in self.plans():
            for i, ns in ps.nodes.items():
                for stat, est, obs, ratio in ns.drifts():
                    rows.append({
                        "node": ns.detail, "kind": ns.kind, "stat": stat,
                        "estimated": est, "observed": obs,
                        "ratio": None if math.isinf(ratio) else
                        round(ratio, 4),
                        "executions": ns.executions,
                    })
        def sort_key(r):
            if r["ratio"] is None:
                return math.inf
            return max(r["ratio"], 1.0 / max(r["ratio"], 1e-9))
        rows.sort(key=sort_key, reverse=True)
        return rows

    def drift_summary(self) -> Dict[str, float]:
        """Max |observed/estimated| deviation ratio per Decision kind
        (>= 1.0; 1.0 = estimates exact). The benchmark-JSON drift rows."""
        worst: Dict[str, float] = {}
        for _key, ps in self.plans():
            for ns in ps.nodes.values():
                for stat, est in ns.est.items():
                    obs = ns.last.get(stat)
                    if obs is None:
                        continue
                    if est > 0:
                        r = obs / est
                        dev = max(r, 1.0 / r) if r > 0 else DRIFT_BAND * 2
                    else:
                        dev = DRIFT_BAND * 2 if obs else 1.0
                    worst[ns.kind] = max(worst.get(ns.kind, 1.0), dev)
        return worst

    def summary(self) -> Dict[str, int]:
        plans = self.plans()
        return {
            "plans_tracked": len(plans),
            "executions": sum(ps.executions for _k, ps in plans),
            "drifting_plans": sum(
                1 for _k, ps in plans
                if any(ns.drifts() for ns in ps.nodes.values())),
            "replans": self.replans,
        }


_REGISTRY = StatsRegistry()


def registry() -> StatsRegistry:
    return _REGISTRY


# ---------------------------------------------------------------------------
# profile refresh (drift -> corrected CostProfile entries)
# ---------------------------------------------------------------------------
def refresh_profile(profile=None, reg: Optional[StatsRegistry] = None):
    """A CostProfile with drifting entries rewritten from observed stats.

    * ``dist_route_factor`` — scaled by the worst observed/estimated
      moved-rows ratio over key-routing hash Exchanges: the static
      estimate prices every input row as movable, so a selective filter
      under a partitioned join shows up here as obs << est and the
      factor shrinks toward the traffic actually paid (and vice versa
      for overflowing/skewed routings).
    * ``compact_margin`` — sized so the worst observed Compact occupancy
      fits with DRIFT_BAND headroom; any Compact overflow grows it.
    * ``filter_selectivity`` — replaced by the observed alive_out/alive_in
      ratio of the PFilter whose log deviates most from the prior: the
      constant the Filter-below-Exchange rewrite discounts Exchange
      ``moved_rows`` by, so the wire estimate tracks what selective
      predicates actually let through.
    * ``dense_group_limit`` — NEVER auto-refreshed (a VMEM model, not a
      row estimate); occupancy drift on dense aggregates is visible in
      ``drift_report()`` instead.

    Returns the refreshed profile (``source="telemetry"``); install with
    ``planner.set_cost_profile``. Without any relevant drift the input
    profile is returned unchanged."""
    import dataclasses

    from repro.analytics import planner

    reg = reg or _REGISTRY
    profile = profile or planner.current_cost_profile()
    route_ratio: Optional[float] = None
    margin_need: Optional[float] = None
    sel_obs: Optional[float] = None
    prior_sel = max(profile.filter_selectivity, 1e-9)
    for _key, ps in reg.plans():
        n = max(ps.phys.n_shards, 1)
        nodes = ps.node_list()
        for i, ns in ps.nodes.items():
            node = nodes[i]
            if (isinstance(node, PH.Exchange) and node.kind == "hash"
                    and node.key is not None and "moved" in ns.last):
                est = max(ns.est.get("moved", 0), 1)
                r = ns.last["moved"] / est
                if route_ratio is None or abs(math.log(max(r, 1e-9))) > \
                        abs(math.log(max(route_ratio, 1e-9))):
                    route_ratio = r
            if (isinstance(node, PH.PFilter)
                    and ns.last.get("alive_in", 0) > 0):
                sel = ns.last.get("alive_out", 0) / ns.last["alive_in"]
                r = max(sel, 1e-9) / prior_sel
                if sel_obs is None or abs(math.log(r)) > abs(math.log(
                        max(sel_obs, 1e-9) / prior_sel)):
                    sel_obs = sel
            if isinstance(node, PH.Compact) and "alive_in" in ns.last:
                est = max(ns.est.get("alive_in", 0), 1)
                occ = ns.last["alive_in"] / est
                if ns.last.get("overflow", 0) > 0:
                    occ = max(occ, 1.0) * DRIFT_BAND
                need = occ * DRIFT_BAND
                margin_need = max(margin_need or 0.0, need)
    updates = {}
    if route_ratio is not None and not \
            (1.0 / DRIFT_BAND) <= route_ratio <= DRIFT_BAND:
        scale = min(max(route_ratio, 1.0 / _REFRESH_CLAMP), _REFRESH_CLAMP)
        updates["dist_route_factor"] = round(
            max(profile.dist_route_factor * scale, 0.01), 4)
    if sel_obs is not None and not \
            (1.0 / DRIFT_BAND) <= sel_obs / prior_sel <= DRIFT_BAND:
        scale = min(max(sel_obs / prior_sel, 1.0 / _REFRESH_CLAMP),
                    _REFRESH_CLAMP)
        updates["filter_selectivity"] = round(
            min(max(profile.filter_selectivity * scale, 0.01), 1.0), 4)
    if margin_need is not None:
        base = (profile.compact_margin
                if profile.compact_margin is not None else None)
        from repro.analytics.planner import COMPACT_MARGIN
        cur = base if base is not None else COMPACT_MARGIN
        new = min(max(margin_need, 1.0), _REFRESH_CLAMP)
        if not (1.0 / DRIFT_BAND) <= new / cur <= DRIFT_BAND:
            updates["compact_margin"] = round(new, 4)
    if not updates:
        return profile
    return dataclasses.replace(profile, source="telemetry", **updates)


# ---------------------------------------------------------------------------
# explain_analyze
# ---------------------------------------------------------------------------
def _annotation(ns: Optional[NodeStats]) -> str:
    if ns is None or not ns.last:
        return ""
    order = ("alive_in", "moved", "alive_out", "probe_alive", "build_alive",
             "out_alive", "groups_occupied", "overflow")
    obs = " ".join(f"{k}={ns.last[k]}" for k in order if k in ns.last)
    est = " ".join(f"{k}~{v}" for k, v in ns.est.items())
    return f"[obs {obs}" + (f" | est {est}]" if est else "]")


def _time_weight(node: PH.PNode) -> float:
    """Deterministic relative time weight of one physical node — the cost
    model's row terms (rows produced + wire rows, movement priced double).
    Inside a jit the operators fuse, so per-node wall time is NOT
    observable; the plan-level span's wall is apportioned by these static
    weights instead, which keeps the rendering golden-snapshotable."""
    w = float(max(getattr(node, "rows", 0), 0))
    if isinstance(node, PH.Exchange):
        w += 2.0 * max(node.moved_rows, 0)
    return max(w, 1.0)


def explain_analyze(plan, tables, ctx=None) -> str:
    """Execute ``plan`` under telemetry and render its physical tree with
    estimated-vs-observed rows per node — ``explain_physical`` made
    executable. Estimates are GLOBAL rows (per-shard node fields x
    n_shards); observations are the recorded totals of the run this call
    performed.

    The header carries the dispatch's wall time (the plan-level
    ``plan.execute`` grain tracing records); each node line carries its
    deterministic ``t~`` share of it (see ``_time_weight``). Deterministic
    for fixed tables up to the absolute wall, so golden-snapshotable with
    the wall normalized."""
    from repro.analytics import planner

    ctx = ctx or planner.ExecutionContext()
    with recording() as reg:
        compiled = planner.compile_plan(plan, tables, ctx)
        compiled(tables)
        ps = reg.get(compiled.cache_key)
    by_node: Dict[PH.PNode, NodeStats] = {}
    if ps is not None:
        nodes = ps.node_list()
        for i, ns in ps.nodes.items():
            by_node[nodes[i]] = ns
    wall = (ps.wall_s[-1] if ps is not None and ps.wall_s else 0.0)
    uniq = list(PH.walk_unique(compiled.physical.root))
    total_w = sum(_time_weight(n) for n in uniq) or 1.0
    pct = {n: 100.0 * _time_weight(n) / total_w for n in uniq}

    def annotate(n: PH.PNode) -> str:
        t = f"[t~{pct.get(n, 0.0):.1f}%]"
        obs = _annotation(by_node.get(n))
        return f"{t} {obs}" if obs else t

    out = PH.describe(compiled.physical, annotate=annotate)
    head, _, rest = out.partition("\n")
    return f"{head} wall={wall * 1e3:.2f}ms\n{rest}"
