"""Request-scoped tracing: the serving path's TIME observability.

PR 7's telemetry answers "where do the ROWS go" (observed Exchange/
Compact volumes fed back into the cost model); this module answers
"where does the TIME go". The paper's method is to measure phase-level
latency before reaching for a mechanism — allocator, placement, load
balancing — and the serving tier (queue -> batcher -> scheduler ->
pools) had only scattered ``time.monotonic()`` stamps with no
request-scoped story. The tracer threads one trace id (the request id,
or the dispatch id for plan-level work) through every phase:

  queue.wait        admission -> dequeue (AdmissionQueue.take_batch)
  batch.group       plan-cache-key grouping + dedup (QueryBatcher)
  dispatch.build    compile_plan + scheduler submit for one share
  retry.backoff     the sleep between failed dispatch attempts
  morsel.run        one morsel on one pool's worker (pid=pool, tid=worker)
  morsel.steal      instant: a pool stole the tail of another's backlog
  merge.partials    morsel-order partial merge (QueryTask._finish)
  result.deliver    terminal-result fan-out (_record)
  plan.compile      plan-cache miss: lowering + jit construction
  plan.execute      one CompiledPlan dispatch (per plan-cache key)

Discipline mirrors ``telemetry.StatsRegistry`` exactly:

  * one module-level flag (``enable_tracing`` / ``disable_tracing`` /
    the ``tracing()`` context manager); every instrumentation site is
    behind ``if tracing_enabled():`` — disabled (the default), the hot
    path performs ONE module-attribute read and allocates nothing
    (``Tracer.created`` counts every span/instant allocated, so the
    zero-overhead contract is assertable, and scripts/trace_gate.py
    asserts it);
  * the span ring is BOUNDED (``maxlen``) and thread-safe — an
    always-on service cannot grow it without bound;
  * service-level spans are recorded host-side only and the flag is NOT
    part of the plan-cache key — only telemetry's ``record`` flag
    re-jits, because only it adds traced operations.

Exports:

  * ``Trace.to_chrome_trace()`` — Chrome trace-event JSON (perfetto-
    loadable): ``ph:"X"`` complete events with pid/tid lanes per
    pool/worker plus ``ph:"M"`` metadata naming the lanes;
  * ``render_timeline()`` — a deterministic text timeline (golden-
    snapshotted like ``explain_analyze``);
  * ``FlightRecorder`` — a bounded ring of postmortem dumps: the recent
    span window snapshotted at the moment a fault trips (injector build
    fail / wait poison / pool kill, scheduler quarantine, overload shed,
    WorkerLeakError), so every injected chaos-grid fault yields an
    artifact.

Stdlib-only and leaf-level: planner/service import this module, never
the reverse.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# enable flag (the telemetry.py discipline)
# ---------------------------------------------------------------------------
_ENABLED = False
_ENABLE_LOCK = threading.Lock()


def tracing_enabled() -> bool:
    return _ENABLED


def enable_tracing() -> None:
    global _ENABLED
    with _ENABLE_LOCK:
        _ENABLED = True


def disable_tracing() -> None:
    global _ENABLED
    with _ENABLE_LOCK:
        _ENABLED = False


@contextmanager
def tracing():
    """Enable tracing for the duration of a block (not reference counted:
    nested blocks share the one global flag)."""
    prev = _ENABLED
    enable_tracing()
    try:
        yield tracer()
    finally:
        if not prev:
            disable_tracing()


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Span:
    """One finished span: a named [t0, t0+dur) interval on a (pid, tid)
    lane, tied to a request (``trace_id``) and optionally nested under a
    parent span. ``dur == 0.0`` marks an instant event."""

    name: str
    cat: str                      # phase family: queue|batch|service|...
    t0: float                     # time.monotonic seconds
    dur: float
    trace_id: int = -1            # request/dispatch id; -1 = unscoped
    span_id: int = -1
    parent_id: int = -1
    pid: str = "service"          # process lane (pool / service / plan)
    tid: str = "main"             # thread lane (worker name)
    args: Tuple[Tuple[str, Any], ...] = ()

    @property
    def t1(self) -> float:
        return self.t0 + self.dur

    @property
    def instant(self) -> bool:
        return self.dur == 0.0


@dataclass
class FlightDump:
    """One postmortem artifact: the recent-span window at the moment a
    fault tripped, plus whatever the trip site wanted on record."""

    reason: str
    at: float                     # time.monotonic of the trip
    args: Dict[str, Any] = field(default_factory=dict)
    spans: List[Span] = field(default_factory=list)


class FlightRecorder:
    """Bounded ring of FlightDumps (thread-safe). The tracer owns one;
    trip sites call ``tracer().flight_dump(reason, **args)``."""

    def __init__(self, max_dumps: int = 64):
        self._lock = threading.Lock()
        self._dumps: "deque[FlightDump]" = deque(maxlen=max_dumps)

    def add(self, dump: FlightDump) -> None:
        with self._lock:
            self._dumps.append(dump)

    def dumps(self) -> List[FlightDump]:
        with self._lock:
            return list(self._dumps)

    def clear(self) -> None:
        with self._lock:
            self._dumps.clear()


class _OpenSpan:
    __slots__ = ("name", "cat", "t0", "trace_id", "span_id", "parent_id",
                 "pid", "tid", "args")

    def __init__(self, name, cat, t0, trace_id, span_id, parent_id, pid,
                 tid, args):
        self.name = name
        self.cat = cat
        self.t0 = t0
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.pid = pid
        self.tid = tid
        self.args = args


class Tracer:
    """Thread-safe bounded span collector.

    Three entry styles, chosen by what the call site can know:

      * ``begin()`` / ``end()`` — spans opened and closed by the SAME
        logical operation (possibly on different threads; the span id is
        the handle). Unclosed spans stay visible in ``open_spans()`` —
        the trace gate fails on any.
      * ``add_complete()`` — retrospective spans synthesized from stamps
        that already exist (``QueryRequest.submit_t`` / ``dispatch_t``,
        ``QueryTask.submit_t`` / ``done_t``): no cross-thread open-span
        bookkeeping, no chance of a leak.
      * ``instant()`` — point events (steals, quarantines).

    ``created`` counts every span/instant ever allocated — the
    zero-overhead-when-disabled guard: a round served with tracing off
    must leave it unchanged.
    """

    def __init__(self, max_spans: int = 8192, flight_window: int = 128,
                 max_dumps: int = 64):
        self._lock = threading.Lock()
        self._spans: "deque[Span]" = deque(maxlen=max_spans)
        self._open: Dict[int, _OpenSpan] = {}
        self._next_id = 0
        self.flight_window = flight_window
        self.flight = FlightRecorder(max_dumps)
        self.created = 0              # spans+instants allocated, ever
        self.dropped = 0              # ring evictions

    # -- recording ----------------------------------------------------------
    def begin(self, name: str, cat: str, *, trace_id: int = -1,
              parent_id: int = -1, pid: str = "service",
              tid: Optional[str] = None, **args) -> int:
        t0 = time.monotonic()
        tid = tid or threading.current_thread().name
        with self._lock:
            sid = self._next_id
            self._next_id += 1
            self._open[sid] = _OpenSpan(name, cat, t0, trace_id, sid,
                                        parent_id, pid, tid,
                                        tuple(args.items()))
        return sid

    def end(self, span_id: int, **args) -> Optional[Span]:
        t1 = time.monotonic()
        with self._lock:
            op = self._open.pop(span_id, None)
            if op is None:
                return None
            span = Span(op.name, op.cat, op.t0, max(0.0, t1 - op.t0),
                        op.trace_id, op.span_id, op.parent_id, op.pid,
                        op.tid, op.args + tuple(args.items()))
            self._append_locked(span)
        return span

    def add_complete(self, name: str, cat: str, t0: float, t1: float, *,
                     trace_id: int = -1, parent_id: int = -1,
                     pid: str = "service", tid: Optional[str] = None,
                     **args) -> Span:
        """Record a retrospective span from existing monotonic stamps."""
        tid = tid or threading.current_thread().name
        with self._lock:
            sid = self._next_id
            self._next_id += 1
            span = Span(name, cat, t0, max(0.0, t1 - t0), trace_id, sid,
                        parent_id, pid, tid, tuple(args.items()))
            self._append_locked(span)
        return span

    def instant(self, name: str, cat: str, *, trace_id: int = -1,
                pid: str = "service", tid: Optional[str] = None,
                **args) -> Span:
        now = time.monotonic()
        tid = tid or threading.current_thread().name
        with self._lock:
            sid = self._next_id
            self._next_id += 1
            span = Span(name, cat, now, 0.0, trace_id, sid, -1, pid, tid,
                        tuple(args.items()))
            self._append_locked(span)
        return span

    def _append_locked(self, span: Span) -> None:
        if len(self._spans) == self._spans.maxlen:
            self.dropped += 1
        self._spans.append(span)
        self.created += 1

    # -- flight recorder ----------------------------------------------------
    def flight_dump(self, reason: str, **args) -> FlightDump:
        """Snapshot the recent span window (finished ring tail + every
        still-open span, rendered open-ended) as a postmortem artifact."""
        now = time.monotonic()
        with self._lock:
            recent = list(self._spans)[-self.flight_window:]
            for op in self._open.values():
                recent.append(Span(op.name, op.cat, op.t0,
                                   max(0.0, now - op.t0), op.trace_id,
                                   op.span_id, op.parent_id, op.pid, op.tid,
                                   op.args + (("open", True),)))
        dump = FlightDump(reason, now, dict(args), recent)
        self.flight.add(dump)
        return dump

    # -- lookups ------------------------------------------------------------
    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._spans)

    def open_spans(self) -> List[_OpenSpan]:
        with self._lock:
            return list(self._open.values())

    def trace(self) -> "Trace":
        return Trace(self.spans())

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._open.clear()
            self.dropped = 0
        self.flight.clear()


# ---------------------------------------------------------------------------
# export: chrome trace events + text timeline
# ---------------------------------------------------------------------------
class Trace:
    """An immutable snapshot of spans with the two export renderings."""

    def __init__(self, spans: List[Span]):
        self.spans = sorted(spans, key=lambda s: (s.t0, s.span_id))

    def phase_names(self) -> List[str]:
        return sorted({s.name for s in self.spans})

    def lanes(self) -> List[Tuple[str, str]]:
        return sorted({(s.pid, s.tid) for s in self.spans})

    def to_chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event JSON (load in perfetto / chrome://tracing).

        pid/tid labels (pool / worker names) become small integers with
        ``ph:"M"`` process_name / thread_name metadata naming the lanes;
        timestamps are microseconds relative to the earliest span."""
        pids: Dict[str, int] = {}
        tids: Dict[Tuple[str, str], int] = {}
        events: List[Dict[str, Any]] = []
        base = self.spans[0].t0 if self.spans else 0.0
        for s in self.spans:
            if s.pid not in pids:
                pids[s.pid] = len(pids) + 1
                events.append({"ph": "M", "name": "process_name",
                               "pid": pids[s.pid], "tid": 0,
                               "args": {"name": s.pid}})
            lane = (s.pid, s.tid)
            if lane not in tids:
                tids[lane] = len(tids) + 1
                events.append({"ph": "M", "name": "thread_name",
                               "pid": pids[s.pid], "tid": tids[lane],
                               "args": {"name": s.tid}})
            args = {k: v for k, v in s.args}
            if s.trace_id >= 0:
                args["trace_id"] = s.trace_id
            ev = {"name": s.name, "cat": s.cat,
                  "ph": "i" if s.instant else "X",
                  "ts": round((s.t0 - base) * 1e6, 3),
                  "pid": pids[s.pid], "tid": tids[lane], "args": args}
            if s.instant:
                ev["s"] = "t"          # thread-scoped instant
            else:
                ev["dur"] = round(s.dur * 1e6, 3)
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)

    def render_timeline(self, width: int = 40) -> str:
        """Deterministic text timeline: one row per span (start order),
        a bar over a [first span start, last span end] axis, and the
        lane + name + relative times. Deterministic for fixed span
        inputs, so golden-snapshotable (tests/fixtures/
        trace_timeline.txt)."""
        if not self.spans:
            return "trace: empty"
        t_lo = min(s.t0 for s in self.spans)
        t_hi = max(s.t1 for s in self.spans)
        extent = max(t_hi - t_lo, 1e-9)
        lane_w = max(len(f"{s.pid}/{s.tid}") for s in self.spans)
        name_w = max(len(s.name) for s in self.spans)
        lines = [f"trace {len(self.spans)} spans "
                 f"{len(self.lanes())} lanes "
                 f"span={extent * 1e3:.2f}ms"]
        for s in self.spans:
            lo = int((s.t0 - t_lo) / extent * width)
            hi = int((s.t1 - t_lo) / extent * width)
            lo = min(lo, width - 1)
            hi = min(max(hi, lo + 1), width)
            bar = "." * lo + ("|" if s.instant else "#" * (hi - lo))
            bar = bar.ljust(width, ".")
            rid = f" req={s.trace_id}" if s.trace_id >= 0 else ""
            lines.append(
                f"[{bar}] {f'{s.pid}/{s.tid}':<{lane_w}} "
                f"{s.name:<{name_w}} "
                f"{(s.t0 - t_lo) * 1e3:8.2f}ms "
                f"+{s.dur * 1e3:.2f}ms{rid}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# the process tracer
# ---------------------------------------------------------------------------
_TRACER = Tracer()


def tracer() -> Tracer:
    return _TRACER
