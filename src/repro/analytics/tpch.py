"""TPC-H-style workload (W5): generated tables + five representative queries.

Structure-faithful versions of Q1, Q3, Q5, Q6, Q18 (the join/aggregation
queries the paper highlights — Q5 and Q18 are its allocator case studies)
over synthetic tables at a scale factor: lineitem 6000*SF rows, orders
1500*SF, customer 150*SF, supplier 10*SF, nation 25, region 5. Dates are
day-number ints; strings are dictionary-encoded ints — the standard columnar
executor treatment.

Execution architecture (the paper's Fig 8/9 default-vs-tuned axis):

  * Every query takes ``tables`` — a {table: {column: jax.Array}} pytree —
    as a TRACED argument plus a static ``executor`` knob ("xla" | "kernel")
    that it threads into every group_aggregate (columnar.py documents the
    two plans). Column arrays are never baked into the compiled plan as
    constants, so one compilation serves any data of the same shape.
  * ``run_query`` compiles through a PLAN CACHE keyed by
    (query name, executor, sorted (table, column, shape, dtype) signature).
    First call per key traces + compiles; subsequent calls dispatch the
    cached executable. The seed behavior — ``jax.jit(lambda: q(data))()``,
    which re-traced and re-compiled on every call with the tables inlined
    as constants — is what the Fig 8 "default configuration" measures.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, Mapping, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analytics.columnar import Table, group_aggregate, pkfk_join

N_NATION, N_REGION = 25, 5
N_SEGMENTS = 5
DATE0, DATE1 = 0, 2557            # ~7 years of day numbers

Tables = Mapping[str, Mapping[str, jax.Array]]


@dataclass(frozen=True)
class TPCHData:
    tables: Dict[str, Dict[str, np.ndarray]]
    scale: float

    def table(self, name: str) -> Table:
        return Table({k: jnp.asarray(v) for k, v in self.tables[name].items()})

    @functools.cached_property
    def _jax_tables(self) -> Dict[str, Dict[str, jax.Array]]:
        return {t: {c: jnp.asarray(a) for c, a in cols.items()}
                for t, cols in self.tables.items()}

    def as_jax(self) -> Dict[str, Dict[str, jax.Array]]:
        """Device-resident {table: {column: array}} pytree (query input).

        Converted once per TPCHData — repeated run_query dispatch must not
        pay a host-to-device copy of the dataset per call."""
        return self._jax_tables


def generate(scale: float = 0.01, seed: int = 0) -> TPCHData:
    rng = np.random.RandomState(seed)
    n_li = max(1000, int(6_000_000 * scale))
    n_ord = max(250, int(1_500_000 * scale))
    n_cust = max(64, int(150_000 * scale))
    n_supp = max(16, int(10_000 * scale))

    nation = {
        "n_nationkey": np.arange(N_NATION, dtype=np.int32),
        "n_regionkey": rng.randint(0, N_REGION, N_NATION).astype(np.int32),
    }
    customer = {
        "c_custkey": np.arange(n_cust, dtype=np.int32),
        "c_nationkey": rng.randint(0, N_NATION, n_cust).astype(np.int32),
        "c_mktsegment": rng.randint(0, N_SEGMENTS, n_cust).astype(np.int32),
    }
    supplier = {
        "s_suppkey": np.arange(n_supp, dtype=np.int32),
        "s_nationkey": rng.randint(0, N_NATION, n_supp).astype(np.int32),
    }
    orders = {
        "o_orderkey": np.arange(n_ord, dtype=np.int32),
        "o_custkey": rng.randint(0, n_cust, n_ord).astype(np.int32),
        "o_orderdate": rng.randint(DATE0, DATE1, n_ord).astype(np.int32),
    }
    lineitem = {
        "l_orderkey": rng.randint(0, n_ord, n_li).astype(np.int32),
        "l_suppkey": rng.randint(0, n_supp, n_li).astype(np.int32),
        "l_quantity": rng.randint(1, 51, n_li).astype(np.float32),
        "l_extendedprice": (rng.rand(n_li) * 1e4).astype(np.float32),
        "l_discount": (rng.randint(0, 11, n_li) / 100).astype(np.float32),
        "l_tax": (rng.randint(0, 9, n_li) / 100).astype(np.float32),
        "l_returnflag": rng.randint(0, 3, n_li).astype(np.int32),
        "l_linestatus": rng.randint(0, 2, n_li).astype(np.int32),
        "l_shipdate": rng.randint(DATE0, DATE1, n_li).astype(np.int32),
    }
    return TPCHData({"nation": nation, "customer": customer,
                     "supplier": supplier, "orders": orders,
                     "lineitem": lineitem}, scale)


def _t(tables: Tables, name: str) -> Table:
    return Table(dict(tables[name]))


# ---------------------------------------------------------------------------
# queries (each returns a dict of result arrays; compiled via the plan cache)
# ---------------------------------------------------------------------------
def q1(tables: Tables, *, executor: str = "xla",
       cutoff: int = DATE1 - 90) -> Dict[str, jax.Array]:
    """Pricing summary: filter shipdate, group by (returnflag, linestatus).

    Seven aggregates over one key — the fused-kernel showcase: the tuned
    executor computes all of them in a single sweep of lineitem."""
    li = _t(tables, "lineitem")
    li = li.filter(li.col("l_shipdate") <= cutoff)
    g = li.col("l_returnflag") * 2 + li.col("l_linestatus")
    li = li.with_columns(
        _g=g,
        _disc_price=li.col("l_extendedprice") * (1 - li.col("l_discount")),
    )
    li = li.with_columns(_charge=li.col("_disc_price") * (1 + li.col("l_tax")))
    return group_aggregate(li, "_g", 6, {
        "sum_qty": ("sum", "l_quantity"),
        "sum_base_price": ("sum", "l_extendedprice"),
        "sum_disc_price": ("sum", "_disc_price"),
        "sum_charge": ("sum", "_charge"),
        "avg_qty": ("avg", "l_quantity"),
        "avg_price": ("avg", "l_extendedprice"),
        "count_order": ("count", "l_quantity"),
    }, executor=executor)


def q3(tables: Tables, *, executor: str = "xla", segment: int = 1,
       date: int = DATE1 // 2) -> Dict[str, jax.Array]:
    """Shipping priority: cust ⋈ orders ⋈ lineitem, top-10 revenue orders."""
    cust = _t(tables, "customer")
    cust = cust.filter(cust.col("c_mktsegment") == segment)
    orders = _t(tables, "orders")
    orders = orders.filter(orders.col("o_orderdate") < date)
    o = pkfk_join(orders, cust, "o_custkey", "c_custkey", {})
    li = _t(tables, "lineitem")
    li = li.filter(li.col("l_shipdate") > date)
    li = pkfk_join(li, o, "l_orderkey", "o_orderkey", {})
    li = li.with_columns(
        _rev=li.col("l_extendedprice") * (1 - li.col("l_discount")))
    n_ord = tables["orders"]["o_orderkey"].shape[0]
    agg = group_aggregate(li, "l_orderkey", n_ord,
                          {"revenue": ("sum", "_rev")}, executor=executor)
    top_rev, top_keys = jax.lax.top_k(agg["revenue"], 10)
    return {"revenue": top_rev, "o_orderkey": top_keys,
            "_overflow": agg["_overflow"]}


def q5(tables: Tables, *, executor: str = "xla", region: int = 2,
       date_lo: int = 0, date_hi: int = 365) -> Dict[str, jax.Array]:
    """Local supplier volume: 5-way join, group by nation.

    Four pkfk_joins — each build side's sorted index is built through the
    Table index cache (columnar.py), so filtered views re-use their parent's
    argsort instead of re-sorting at every call site."""
    nation = _t(tables, "nation")
    nation = nation.filter(nation.col("n_regionkey") == region)
    cust = pkfk_join(_t(tables, "customer"), nation, "c_nationkey",
                     "n_nationkey", {})
    orders = _t(tables, "orders")
    orders = orders.filter((orders.col("o_orderdate") >= date_lo)
                           & (orders.col("o_orderdate") < date_hi))
    o = pkfk_join(orders, cust, "o_custkey", "c_custkey",
                  {"_c_nation": "c_nationkey"})
    li = pkfk_join(_t(tables, "lineitem"), o, "l_orderkey", "o_orderkey",
                   {"_c_nation": "_c_nation"})
    li = pkfk_join(li, _t(tables, "supplier"), "l_suppkey", "s_suppkey",
                   {"_s_nation": "s_nationkey"})
    # local: supplier nation == customer nation
    li = li.filter(li.col("_s_nation") == li.col("_c_nation"))
    li = li.with_columns(
        _rev=li.col("l_extendedprice") * (1 - li.col("l_discount")))
    return group_aggregate(li, "_s_nation", N_NATION,
                           {"revenue": ("sum", "_rev")}, executor=executor)


def q6(tables: Tables, *, executor: str = "xla", date_lo: int = 0,
       date_hi: int = 365, disc: float = 0.06,
       qty: float = 24.0) -> Dict[str, jax.Array]:
    """Forecast revenue change: pure filter + scalar aggregate.

    A single masked reduction — already one fused pass, so both executors
    share the same plan (the knob is accepted for interface uniformity)."""
    del executor
    li = _t(tables, "lineitem")
    pred = ((li.col("l_shipdate") >= date_lo) & (li.col("l_shipdate") < date_hi)
            & (jnp.abs(li.col("l_discount") - disc) <= 0.011)
            & (li.col("l_quantity") < qty))
    li = li.filter(pred)
    w = li.weights()
    rev = (li.col("l_extendedprice") * li.col("l_discount") * w).sum()
    return {"revenue": rev[None]}


def q18(tables: Tables, *, executor: str = "xla",
        qty_threshold: float = 212.0) -> Dict[str, jax.Array]:
    """Large volume customer: big group-by on orderkey, HAVING, re-join."""
    li = _t(tables, "lineitem")
    n_ord = tables["orders"]["o_orderkey"].shape[0]
    per_order = group_aggregate(li, "l_orderkey", n_ord,
                                {"qty": ("sum", "l_quantity")},
                                executor=executor)
    big = per_order["qty"] > qty_threshold
    orders = _t(tables, "orders").with_columns(_qty=per_order["qty"])
    orders = Table(orders.columns, big.astype(jnp.float32),
                   orders.index_cache)
    o = pkfk_join(orders, _t(tables, "customer"), "o_custkey", "c_custkey",
                  {"_nat": "c_nationkey"})
    n_cust = tables["customer"]["c_custkey"].shape[0]
    out = group_aggregate(o, "o_custkey", n_cust, {"qty": ("sum", "_qty")},
                          executor=executor)
    # surface the per-order aggregation's overflow too: capacity overflow in
    # EITHER pass means the result is incomplete, and must never be silent
    out["_overflow"] = out["_overflow"] + per_order["_overflow"]
    return out


QUERIES: Dict[str, Callable[..., Dict[str, jax.Array]]] = {
    "q1": q1, "q3": q3, "q5": q5, "q6": q6, "q18": q18}


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------
PlanKey = Tuple[str, str, Tuple]
_PLAN_CACHE: Dict[PlanKey, Callable] = {}


def _signature(tables: Tables) -> Tuple:
    return tuple(sorted((t, c, tuple(a.shape), str(a.dtype))
                        for t, cols in tables.items()
                        for c, a in cols.items()))


def get_plan(name: str, executor: str, tables: Tables) -> Callable:
    """Compiled plan for (query, executor, table signature) — built once."""
    key: PlanKey = (name, executor, _signature(tables))
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        plan = jax.jit(functools.partial(QUERIES[name], executor=executor))
        _PLAN_CACHE[key] = plan
    return plan


def plan_cache_size() -> int:
    return len(_PLAN_CACHE)


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()


def run_query(name: str, data, *, executor: str = "xla"
              ) -> Dict[str, jax.Array]:
    """Execute a query through the plan cache.

    ``data`` is a TPCHData or a {table: {column: array}} mapping (jit
    accepts numpy columns directly). Tables are passed to the compiled plan
    as traced arguments; re-running on new data of the same shape re-uses
    the executable."""
    tables = data.as_jax() if isinstance(data, TPCHData) else data
    return get_plan(name, executor, tables)(tables)
