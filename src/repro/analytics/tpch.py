"""TPC-H-style workload (W5): generated tables + six representative queries.

Structure-faithful versions of Q1, Q3, Q5, Q6, Q18 (the join/aggregation
queries the paper highlights — Q5 and Q18 are its allocator case studies),
plus QM and QQ, order-statistic (median / arbitrary-rank quantile)
companions to Q1 exercising the holistic-aggregate lowerings,
over synthetic tables at a scale factor: lineitem 6000*SF rows, orders
1500*SF, customer 150*SF, supplier 10*SF, nation 25, region 5. Dates are
day-number ints; strings are dictionary-encoded ints — the standard columnar
executor treatment.

Execution architecture — the paper's "query stays fixed, strategy changes
underneath" thesis applied to our own API:

  * Each query is authored ONCE as a logical plan (plan.py dataclass IR;
    ``LOGICAL_QUERIES`` maps name -> LogicalPlan). ``run_query`` hands the
    plan to the cost-based physical planner (planner.py), which picks the
    per-Aggregate layout (XLA segment ops / dense fused kernel /
    range-partitioned fused kernel), the join strategy, and — when the
    ExecutionContext carries a (mesh, PlacementPolicy) — the distributed
    placement backend, all without touching the query definition.
  * ``run_query(name, data, executor=...)`` keeps the PR-1 signature: the
    string knob becomes ``ExecutionContext(executor=...)`` ("xla" naive
    plan, "kernel" tuned fused plan, "cost" planner's choice); pass
    ``context=`` for full control. Compiled plans live in the planner's
    bounded LRU cache keyed by (plan structure, context, shape signature) —
    tables stay TRACED arguments, so one compilation serves any data of
    the same shapes, and join build-side argsorts are pooled across calls
    by column-array identity (planner.JoinIndexPool) so re-running a query
    never re-sorts a build side.
  * The imperative functions (q1..q18, ``QUERIES``) are retained as the
    reference implementations the logical plans are parity-tested against,
    and as the re-trace-per-call "default configuration" the Fig 8
    benchmark measures.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analytics import planner
from repro.analytics.columnar import Table, group_aggregate, pkfk_join
from repro.analytics.plan import LogicalPlan, TableRows, col, scan

N_NATION, N_REGION = 25, 5
N_SEGMENTS = 5
DATE0, DATE1 = 0, 2557            # ~7 years of day numbers

Tables = Mapping[str, Mapping[str, jax.Array]]


@dataclass(frozen=True)
class TPCHData:
    tables: Dict[str, Dict[str, np.ndarray]]
    scale: float

    def table(self, name: str) -> Table:
        return Table({k: jnp.asarray(v) for k, v in self.tables[name].items()})

    @functools.cached_property
    def _jax_tables(self) -> Dict[str, Dict[str, jax.Array]]:
        return {t: {c: jnp.asarray(a) for c, a in cols.items()}
                for t, cols in self.tables.items()}

    def as_jax(self) -> Dict[str, Dict[str, jax.Array]]:
        """Device-resident {table: {column: array}} pytree (query input).

        Converted once per TPCHData — repeated run_query dispatch must not
        pay a host-to-device copy of the dataset per call."""
        return self._jax_tables


def generate(scale: float = 0.01, seed: int = 0) -> TPCHData:
    rng = np.random.RandomState(seed)
    n_li = max(1000, int(6_000_000 * scale))
    n_ord = max(250, int(1_500_000 * scale))
    n_cust = max(64, int(150_000 * scale))
    n_supp = max(16, int(10_000 * scale))

    nation = {
        "n_nationkey": np.arange(N_NATION, dtype=np.int32),
        "n_regionkey": rng.randint(0, N_REGION, N_NATION).astype(np.int32),
    }
    customer = {
        "c_custkey": np.arange(n_cust, dtype=np.int32),
        "c_nationkey": rng.randint(0, N_NATION, n_cust).astype(np.int32),
        "c_mktsegment": rng.randint(0, N_SEGMENTS, n_cust).astype(np.int32),
    }
    supplier = {
        "s_suppkey": np.arange(n_supp, dtype=np.int32),
        "s_nationkey": rng.randint(0, N_NATION, n_supp).astype(np.int32),
    }
    orders = {
        "o_orderkey": np.arange(n_ord, dtype=np.int32),
        "o_custkey": rng.randint(0, n_cust, n_ord).astype(np.int32),
        "o_orderdate": rng.randint(DATE0, DATE1, n_ord).astype(np.int32),
    }
    lineitem = {
        "l_orderkey": rng.randint(0, n_ord, n_li).astype(np.int32),
        "l_suppkey": rng.randint(0, n_supp, n_li).astype(np.int32),
        "l_quantity": rng.randint(1, 51, n_li).astype(np.float32),
        "l_extendedprice": (rng.rand(n_li) * 1e4).astype(np.float32),
        "l_discount": (rng.randint(0, 11, n_li) / 100).astype(np.float32),
        "l_tax": (rng.randint(0, 9, n_li) / 100).astype(np.float32),
        "l_returnflag": rng.randint(0, 3, n_li).astype(np.int32),
        "l_linestatus": rng.randint(0, 2, n_li).astype(np.int32),
        "l_shipdate": rng.randint(DATE0, DATE1, n_li).astype(np.int32),
    }
    return TPCHData({"nation": nation, "customer": customer,
                     "supplier": supplier, "orders": orders,
                     "lineitem": lineitem}, scale)


def _t(tables: Tables, name: str) -> Table:
    return Table(dict(tables[name]))


# ---------------------------------------------------------------------------
# queries (each returns a dict of result arrays; compiled via the plan cache)
# ---------------------------------------------------------------------------
def q1(tables: Tables, *, executor: str = "xla",
       cutoff: int = DATE1 - 90) -> Dict[str, jax.Array]:
    """Pricing summary: filter shipdate, group by (returnflag, linestatus).

    Seven aggregates over one key — the fused-kernel showcase: the tuned
    executor computes all of them in a single sweep of lineitem."""
    li = _t(tables, "lineitem")
    li = li.filter(li.col("l_shipdate") <= cutoff)
    g = li.col("l_returnflag") * 2 + li.col("l_linestatus")
    li = li.with_columns(
        _g=g,
        _disc_price=li.col("l_extendedprice") * (1 - li.col("l_discount")),
    )
    li = li.with_columns(_charge=li.col("_disc_price") * (1 + li.col("l_tax")))
    return group_aggregate(li, "_g", 6, {
        "sum_qty": ("sum", "l_quantity"),
        "sum_base_price": ("sum", "l_extendedprice"),
        "sum_disc_price": ("sum", "_disc_price"),
        "sum_charge": ("sum", "_charge"),
        "avg_qty": ("avg", "l_quantity"),
        "avg_price": ("avg", "l_extendedprice"),
        "count_order": ("count", "l_quantity"),
    }, executor=executor)


def q3(tables: Tables, *, executor: str = "xla", segment: int = 1,
       date: int = DATE1 // 2) -> Dict[str, jax.Array]:
    """Shipping priority: cust ⋈ orders ⋈ lineitem, top-10 revenue orders."""
    cust = _t(tables, "customer")
    cust = cust.filter(cust.col("c_mktsegment") == segment)
    orders = _t(tables, "orders")
    orders = orders.filter(orders.col("o_orderdate") < date)
    o = pkfk_join(orders, cust, "o_custkey", "c_custkey", {})
    li = _t(tables, "lineitem")
    li = li.filter(li.col("l_shipdate") > date)
    li = pkfk_join(li, o, "l_orderkey", "o_orderkey", {})
    li = li.with_columns(
        _rev=li.col("l_extendedprice") * (1 - li.col("l_discount")))
    n_ord = tables["orders"]["o_orderkey"].shape[0]
    agg = group_aggregate(li, "l_orderkey", n_ord,
                          {"revenue": ("sum", "_rev")}, executor=executor)
    top_rev, top_keys = jax.lax.top_k(agg["revenue"], 10)
    return {"revenue": top_rev, "o_orderkey": top_keys,
            "_overflow": agg["_overflow"]}


def q5(tables: Tables, *, executor: str = "xla", region: int = 2,
       date_lo: int = 0, date_hi: int = 365) -> Dict[str, jax.Array]:
    """Local supplier volume: 5-way join, group by nation.

    Four pkfk_joins — each build side's sorted index is built through the
    Table index cache (columnar.py), so filtered views re-use their parent's
    argsort instead of re-sorting at every call site."""
    nation = _t(tables, "nation")
    nation = nation.filter(nation.col("n_regionkey") == region)
    cust = pkfk_join(_t(tables, "customer"), nation, "c_nationkey",
                     "n_nationkey", {})
    orders = _t(tables, "orders")
    orders = orders.filter((orders.col("o_orderdate") >= date_lo)
                           & (orders.col("o_orderdate") < date_hi))
    o = pkfk_join(orders, cust, "o_custkey", "c_custkey",
                  {"_c_nation": "c_nationkey"})
    li = pkfk_join(_t(tables, "lineitem"), o, "l_orderkey", "o_orderkey",
                   {"_c_nation": "_c_nation"})
    li = pkfk_join(li, _t(tables, "supplier"), "l_suppkey", "s_suppkey",
                   {"_s_nation": "s_nationkey"})
    # local: supplier nation == customer nation
    li = li.filter(li.col("_s_nation") == li.col("_c_nation"))
    li = li.with_columns(
        _rev=li.col("l_extendedprice") * (1 - li.col("l_discount")))
    return group_aggregate(li, "_s_nation", N_NATION,
                           {"revenue": ("sum", "_rev")}, executor=executor)


def q6(tables: Tables, *, executor: str = "xla", date_lo: int = 0,
       date_hi: int = 365, disc: float = 0.06,
       qty: float = 24.0) -> Dict[str, jax.Array]:
    """Forecast revenue change: pure filter + scalar aggregate.

    A single masked reduction — already one fused pass, so both executors
    share the same plan (the knob is accepted for interface uniformity)."""
    del executor
    li = _t(tables, "lineitem")
    pred = ((li.col("l_shipdate") >= date_lo) & (li.col("l_shipdate") < date_hi)
            & (jnp.abs(li.col("l_discount") - disc) <= 0.011)
            & (li.col("l_quantity") < qty))
    li = li.filter(pred)
    w = li.weights()
    rev = (li.col("l_extendedprice") * li.col("l_discount") * w).sum()
    return {"revenue": rev[None]}


def q18(tables: Tables, *, executor: str = "xla",
        qty_threshold: float = 212.0) -> Dict[str, jax.Array]:
    """Large volume customer: big group-by on orderkey, HAVING, re-join."""
    li = _t(tables, "lineitem")
    n_ord = tables["orders"]["o_orderkey"].shape[0]
    per_order = group_aggregate(li, "l_orderkey", n_ord,
                                {"qty": ("sum", "l_quantity")},
                                executor=executor)
    big = per_order["qty"] > qty_threshold
    orders = _t(tables, "orders").with_columns(_qty=per_order["qty"])
    orders = Table(orders.columns, big.astype(jnp.float32),
                   orders.index_cache)
    o = pkfk_join(orders, _t(tables, "customer"), "o_custkey", "c_custkey",
                  {"_nat": "c_nationkey"})
    n_cust = tables["customer"]["c_custkey"].shape[0]
    out = group_aggregate(o, "o_custkey", n_cust, {"qty": ("sum", "_qty")},
                          executor=executor)
    # surface the per-order aggregation's overflow too: capacity overflow in
    # EITHER pass means the result is incomplete, and must never be silent
    out["_overflow"] = out["_overflow"] + per_order["_overflow"]
    return out


def qm(tables: Tables, *, executor: str = "xla",
       cutoff: int = DATE1 - 90) -> Dict[str, jax.Array]:
    """Order-statistic pricing summary: per-returnflag MEDIAN quantity and
    price next to distributive companions.

    The holistic sibling of Q1 (paper Section 2): medians cannot be merged
    from partials, so every executor lowers them onto the sort-based
    selection path (and, distributed, onto record replication or routed
    selection) while avg/count still ride the distributive sweep."""
    li = _t(tables, "lineitem")
    li = li.filter(li.col("l_shipdate") <= cutoff)
    return group_aggregate(li, "l_returnflag", 3, {
        "med_qty": ("median", "l_quantity"),
        "med_price": ("median", "l_extendedprice"),
        "avg_qty": ("avg", "l_quantity"),
        "count_order": ("count", "l_quantity"),
    }, executor=executor)


def qq(tables: Tables, *, executor: str = "xla",
       cutoff: int = DATE1 - 90) -> Dict[str, jax.Array]:
    """Quantile pricing summary: per-returnflag p90 price / p25 quantity
    tails next to their median and count.

    The arbitrary-rank generalization of QM: "quantile:R" ops ride the
    same sort-based selection machinery as median (one selection index per
    rank instead of the middle), so every lowering that serves medians —
    local, record replication, routed distributed selection — serves
    arbitrary quantiles unchanged."""
    li = _t(tables, "lineitem")
    li = li.filter(li.col("l_shipdate") <= cutoff)
    return group_aggregate(li, "l_returnflag", 3, {
        "p90_price": ("quantile:0.9", "l_extendedprice"),
        "p25_qty": ("quantile:0.25", "l_quantity"),
        "med_price": ("median", "l_extendedprice"),
        "count_order": ("count", "l_quantity"),
    }, executor=executor)


QUERIES: Dict[str, Callable[..., Dict[str, jax.Array]]] = {
    "q1": q1, "q3": q3, "q5": q5, "q6": q6, "q18": q18, "qm": qm, "qq": qq}


# ---------------------------------------------------------------------------
# logical plans: the same five queries authored once against the plan IR
# ---------------------------------------------------------------------------
def build_q1(cutoff: int = DATE1 - 90) -> LogicalPlan:
    li = scan("lineitem").filter(col("l_shipdate") <= cutoff)
    li = li.project(
        _g=col("l_returnflag") * 2 + col("l_linestatus"),
        _disc_price=col("l_extendedprice") * (1 - col("l_discount")))
    li = li.project(_charge=col("_disc_price") * (1 + col("l_tax")))
    root = li.aggregate(
        "_g", 6,
        sum_qty=("sum", "l_quantity"),
        sum_base_price=("sum", "l_extendedprice"),
        sum_disc_price=("sum", "_disc_price"),
        sum_charge=("sum", "_charge"),
        avg_qty=("avg", "l_quantity"),
        avg_price=("avg", "l_extendedprice"),
        count_order=("count", "l_quantity"))
    return LogicalPlan(root, ("sum_qty", "sum_base_price", "sum_disc_price",
                              "sum_charge", "avg_qty", "avg_price",
                              "count_order", "_count", "_overflow"))


def build_q3(segment: int = 1, date: int = DATE1 // 2) -> LogicalPlan:
    cust = scan("customer").filter(col("c_mktsegment").eq(segment))
    orders = scan("orders").filter(col("o_orderdate") < date)
    o = orders.join(cust, "o_custkey", "c_custkey")
    li = scan("lineitem").filter(col("l_shipdate") > date)
    li = li.join(o, "l_orderkey", "o_orderkey")
    li = li.project(_rev=col("l_extendedprice") * (1 - col("l_discount")))
    agg = li.aggregate("l_orderkey", TableRows("orders"),
                       revenue=("sum", "_rev"))
    return LogicalPlan(agg.top_k("revenue", 10, "o_orderkey"),
                       ("revenue", "o_orderkey", "_overflow"))


def build_q5(region: int = 2, date_lo: int = 0,
             date_hi: int = 365) -> LogicalPlan:
    nation = scan("nation").filter(col("n_regionkey").eq(region))
    cust = scan("customer").join(nation, "c_nationkey", "n_nationkey")
    orders = scan("orders").filter((col("o_orderdate") >= date_lo)
                                   & (col("o_orderdate") < date_hi))
    o = orders.join(cust, "o_custkey", "c_custkey",
                    {"_c_nation": "c_nationkey"})
    li = scan("lineitem").join(o, "l_orderkey", "o_orderkey",
                               {"_c_nation": "_c_nation"})
    li = li.join(scan("supplier"), "l_suppkey", "s_suppkey",
                 {"_s_nation": "s_nationkey"})
    li = li.filter(col("_s_nation").eq(col("_c_nation")))
    li = li.project(_rev=col("l_extendedprice") * (1 - col("l_discount")))
    root = li.aggregate("_s_nation", N_NATION, revenue=("sum", "_rev"))
    return LogicalPlan(root, ("revenue", "_count", "_overflow"))


def build_q6(date_lo: int = 0, date_hi: int = 365, disc: float = 0.06,
             qty: float = 24.0) -> LogicalPlan:
    pred = ((col("l_shipdate") >= date_lo) & (col("l_shipdate") < date_hi)
            & (abs(col("l_discount") - disc) <= 0.011)
            & (col("l_quantity") < qty))
    li = scan("lineitem").filter(pred)
    li = li.project(_x=col("l_extendedprice") * col("l_discount"))
    return LogicalPlan(li.aggregate(None, 1, revenue=("sum", "_x")),
                       ("revenue",))


def build_q18(qty_threshold: float = 212.0) -> LogicalPlan:
    per_order = scan("lineitem").aggregate(
        "l_orderkey", TableRows("orders"), qty=("sum", "l_quantity"))
    orders = scan("orders").attach(per_order, "o_orderkey", {"_qty": "qty"})
    orders = orders.filter(col("_qty") > qty_threshold)
    o = orders.join(scan("customer"), "o_custkey", "c_custkey",
                    {"_nat": "c_nationkey"})
    root = o.aggregate("o_custkey", TableRows("customer"),
                       qty=("sum", "_qty"))
    return LogicalPlan(root, ("qty", "_count", "_overflow"))


def build_qm(cutoff: int = DATE1 - 90) -> LogicalPlan:
    li = scan("lineitem").filter(col("l_shipdate") <= cutoff)
    root = li.aggregate(
        "l_returnflag", 3,
        med_qty=("median", "l_quantity"),
        med_price=("median", "l_extendedprice"),
        avg_qty=("avg", "l_quantity"),
        count_order=("count", "l_quantity"))
    return LogicalPlan(root, ("med_qty", "med_price", "avg_qty",
                              "count_order", "_count", "_overflow"))


def build_qq(cutoff: int = DATE1 - 90) -> LogicalPlan:
    li = scan("lineitem").filter(col("l_shipdate") <= cutoff)
    root = li.aggregate(
        "l_returnflag", 3,
        p90_price=("quantile:0.9", "l_extendedprice"),
        p25_qty=("quantile:0.25", "l_quantity"),
        med_price=("median", "l_extendedprice"),
        count_order=("count", "l_quantity"))
    return LogicalPlan(root, ("p90_price", "p25_qty", "med_price",
                              "count_order", "_count", "_overflow"))


LOGICAL_QUERIES: Dict[str, LogicalPlan] = {
    "q1": build_q1(), "q3": build_q3(), "q5": build_q5(), "q6": build_q6(),
    "q18": build_q18(), "qm": build_qm(), "qq": build_qq()}


# ---------------------------------------------------------------------------
# execution through the cost-based planner (plan cache lives in planner.py)
# ---------------------------------------------------------------------------
plan_cache_size = planner.plan_cache_size
plan_cache_info = planner.plan_cache_info
clear_plan_cache = planner.clear_plan_cache
configure_plan_cache = planner.configure_plan_cache


def get_plan(name: str, executor: str) -> Callable:
    """Callable running ``name``'s logical plan under ``executor``; the
    tables pytree is supplied at call time (plans are not data-specific —
    compilation is cached per shape signature inside execute_plan)."""
    ctx = planner.ExecutionContext(executor=executor)
    return lambda tbls: planner.execute_plan(LOGICAL_QUERIES[name], tbls, ctx)


def submit_query(service, name: str, data, *, executor: str = "xla",
                 context: Optional[planner.ExecutionContext] = None,
                 deadline_s: Optional[float] = None,
                 client_id: int = 0, priority: int = 1) -> Optional[int]:
    """Admit one of the five TPC-H logical plans into an AnalyticsService.

    The concurrent-serving counterpart of ``run_query``: same query names,
    same executor/context knobs AND the same defaults, but non-blocking —
    returns the request id (collect via ``service.drain()``), or None
    under backpressure. Served results on the whole-plan path are
    bit-identical to ``run_query`` with the same executor/context: both
    run the planner's compiled plan-cache entry on the same tables."""
    tables = data.as_jax() if isinstance(data, TPCHData) else data
    ctx = context or planner.ExecutionContext(executor=executor)
    return service.submit(LOGICAL_QUERIES[name], tables, context=ctx,
                          deadline_s=deadline_s, client_id=client_id,
                          priority=priority)


def run_query(name: str, data, *, executor: str = "xla",
              context: Optional[planner.ExecutionContext] = None
              ) -> Dict[str, jax.Array]:
    """Execute a query's logical plan through the cost-based planner.

    ``data`` is a TPCHData or a {table: {column: array}} mapping (jit
    accepts numpy columns directly). ``executor`` ("xla" | "kernel" |
    "cost") is shorthand for ``ExecutionContext(executor=...)``; a full
    ``context`` (mesh, placement policy, kernel mode, ...) overrides it.
    Tables are passed to the compiled plan as traced arguments; re-running
    on new data of the same shape re-uses the executable, and join
    build-side sort indexes are pooled across calls per dataset."""
    tables = data.as_jax() if isinstance(data, TPCHData) else data
    ctx = context or planner.ExecutionContext(executor=executor)
    return planner.execute_plan(LOGICAL_QUERIES[name], tables, ctx)
