"""TPC-H-style workload (W5): generated tables + five representative queries.

Structure-faithful versions of Q1, Q3, Q5, Q6, Q18 (the join/aggregation
queries the paper highlights — Q5 and Q18 are its allocator case studies)
over synthetic tables at a scale factor: lineitem 6000*SF rows, orders
1500*SF, customer 150*SF, supplier 10*SF, nation 25, region 5. Dates are
day-number ints; strings are dictionary-encoded ints — the standard columnar
executor treatment.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.analytics.columnar import Table, group_aggregate, pkfk_join

N_NATION, N_REGION = 25, 5
N_SEGMENTS = 5
DATE0, DATE1 = 0, 2557            # ~7 years of day numbers


@dataclass(frozen=True)
class TPCHData:
    tables: Dict[str, Dict[str, np.ndarray]]
    scale: float

    def table(self, name: str) -> Table:
        return Table({k: jnp.asarray(v) for k, v in self.tables[name].items()})


def generate(scale: float = 0.01, seed: int = 0) -> TPCHData:
    rng = np.random.RandomState(seed)
    n_li = max(1000, int(6_000_000 * scale))
    n_ord = max(250, int(1_500_000 * scale))
    n_cust = max(64, int(150_000 * scale))
    n_supp = max(16, int(10_000 * scale))

    nation = {
        "n_nationkey": np.arange(N_NATION, dtype=np.int32),
        "n_regionkey": rng.randint(0, N_REGION, N_NATION).astype(np.int32),
    }
    customer = {
        "c_custkey": np.arange(n_cust, dtype=np.int32),
        "c_nationkey": rng.randint(0, N_NATION, n_cust).astype(np.int32),
        "c_mktsegment": rng.randint(0, N_SEGMENTS, n_cust).astype(np.int32),
    }
    supplier = {
        "s_suppkey": np.arange(n_supp, dtype=np.int32),
        "s_nationkey": rng.randint(0, N_NATION, n_supp).astype(np.int32),
    }
    orders = {
        "o_orderkey": np.arange(n_ord, dtype=np.int32),
        "o_custkey": rng.randint(0, n_cust, n_ord).astype(np.int32),
        "o_orderdate": rng.randint(DATE0, DATE1, n_ord).astype(np.int32),
    }
    lineitem = {
        "l_orderkey": rng.randint(0, n_ord, n_li).astype(np.int32),
        "l_suppkey": rng.randint(0, n_supp, n_li).astype(np.int32),
        "l_quantity": rng.randint(1, 51, n_li).astype(np.float32),
        "l_extendedprice": (rng.rand(n_li) * 1e4).astype(np.float32),
        "l_discount": (rng.randint(0, 11, n_li) / 100).astype(np.float32),
        "l_tax": (rng.randint(0, 9, n_li) / 100).astype(np.float32),
        "l_returnflag": rng.randint(0, 3, n_li).astype(np.int32),
        "l_linestatus": rng.randint(0, 2, n_li).astype(np.int32),
        "l_shipdate": rng.randint(DATE0, DATE1, n_li).astype(np.int32),
    }
    return TPCHData({"nation": nation, "customer": customer,
                     "supplier": supplier, "orders": orders,
                     "lineitem": lineitem}, scale)


# ---------------------------------------------------------------------------
# queries (each returns a dict of result arrays; jit-compiled)
# ---------------------------------------------------------------------------
def q1(data: TPCHData, cutoff: int = DATE1 - 90) -> Dict[str, jax.Array]:
    """Pricing summary: filter shipdate, group by (returnflag, linestatus)."""
    li = data.table("lineitem").filter(
        data.table("lineitem").col("l_shipdate") <= cutoff)
    g = li.col("l_returnflag") * 2 + li.col("l_linestatus")
    li = li.with_columns(
        _g=g,
        _disc_price=li.col("l_extendedprice") * (1 - li.col("l_discount")),
    )
    li = li.with_columns(_charge=li.col("_disc_price") * (1 + li.col("l_tax")))
    return group_aggregate(li, "_g", 6, {
        "sum_qty": ("sum", "l_quantity"),
        "sum_base_price": ("sum", "l_extendedprice"),
        "sum_disc_price": ("sum", "_disc_price"),
        "sum_charge": ("sum", "_charge"),
        "avg_qty": ("avg", "l_quantity"),
        "avg_price": ("avg", "l_extendedprice"),
        "count_order": ("count", "l_quantity"),
    })


def q3(data: TPCHData, segment: int = 1,
       date: int = DATE1 // 2) -> Dict[str, jax.Array]:
    """Shipping priority: cust ⋈ orders ⋈ lineitem, top-10 revenue orders."""
    cust = data.table("customer")
    cust = cust.filter(cust.col("c_mktsegment") == segment)
    orders = data.table("orders")
    orders = orders.filter(orders.col("o_orderdate") < date)
    o = pkfk_join(orders, cust, "o_custkey", "c_custkey", {})
    li = data.table("lineitem")
    li = li.filter(li.col("l_shipdate") > date)
    li = pkfk_join(li, o, "l_orderkey", "o_orderkey", {})
    li = li.with_columns(
        _rev=li.col("l_extendedprice") * (1 - li.col("l_discount")))
    n_ord = data.tables["orders"]["o_orderkey"].shape[0]
    agg = group_aggregate(li, "l_orderkey", n_ord, {"revenue": ("sum", "_rev")})
    top_rev, top_keys = jax.lax.top_k(agg["revenue"], 10)
    return {"revenue": top_rev, "o_orderkey": top_keys}


def q5(data: TPCHData, region: int = 2, date_lo: int = 0,
       date_hi: int = 365) -> Dict[str, jax.Array]:
    """Local supplier volume: 5-way join, group by nation."""
    nation = data.table("nation")
    nation = nation.filter(nation.col("n_regionkey") == region)
    cust = pkfk_join(data.table("customer"), nation, "c_nationkey",
                     "n_nationkey", {})
    orders = data.table("orders")
    orders = orders.filter((orders.col("o_orderdate") >= date_lo)
                           & (orders.col("o_orderdate") < date_hi))
    o = pkfk_join(orders, cust, "o_custkey", "c_custkey",
                  {"_c_nation": "c_nationkey"})
    li = pkfk_join(data.table("lineitem"), o, "l_orderkey", "o_orderkey",
                   {"_c_nation": "_c_nation"})
    li = pkfk_join(li, data.table("supplier"), "l_suppkey", "s_suppkey",
                   {"_s_nation": "s_nationkey"})
    # local: supplier nation == customer nation
    li = li.filter(li.col("_s_nation") == li.col("_c_nation"))
    li = li.with_columns(
        _rev=li.col("l_extendedprice") * (1 - li.col("l_discount")))
    return group_aggregate(li, "_s_nation", N_NATION,
                           {"revenue": ("sum", "_rev")})


def q6(data: TPCHData, date_lo: int = 0, date_hi: int = 365,
       disc: float = 0.06, qty: float = 24.0) -> Dict[str, jax.Array]:
    """Forecast revenue change: pure filter + scalar aggregate."""
    li = data.table("lineitem")
    pred = ((li.col("l_shipdate") >= date_lo) & (li.col("l_shipdate") < date_hi)
            & (jnp.abs(li.col("l_discount") - disc) <= 0.011)
            & (li.col("l_quantity") < qty))
    li = li.filter(pred)
    w = li.weights()
    rev = (li.col("l_extendedprice") * li.col("l_discount") * w).sum()
    return {"revenue": rev[None]}


def q18(data: TPCHData, qty_threshold: float = 212.0) -> Dict[str, jax.Array]:
    """Large volume customer: big group-by on orderkey, HAVING, re-join."""
    li = data.table("lineitem")
    n_ord = data.tables["orders"]["o_orderkey"].shape[0]
    per_order = group_aggregate(li, "l_orderkey", n_ord,
                                {"qty": ("sum", "l_quantity")})
    big = per_order["qty"] > qty_threshold
    orders = data.table("orders").with_columns(_qty=per_order["qty"])
    orders = Table(orders.columns, big.astype(jnp.float32))
    o = pkfk_join(orders, data.table("customer"), "o_custkey", "c_custkey",
                  {"_nat": "c_nationkey"})
    n_cust = data.tables["customer"]["c_custkey"].shape[0]
    return group_aggregate(o, "o_custkey", n_cust, {"qty": ("sum", "_qty")})


QUERIES = {"q1": q1, "q3": q3, "q5": q5, "q6": q6, "q18": q18}


def run_query(name: str, data: TPCHData) -> Dict[str, jax.Array]:
    return jax.jit(lambda: QUERIES[name](data))()
