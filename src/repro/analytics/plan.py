"""Logical plan IR for the analytics engine (W5 and user-authored queries).

The paper's thesis is that NUMA tuning — placement, partitioning, allocator
strategy — must apply *without rewriting the application*: the query stays
fixed while the execution strategy changes underneath it.  This module is
the "query stays fixed" half: a small relational IR whose nodes carry only
*what* to compute.  Every node is a frozen (hashable, structurally
comparable) dataclass, so a whole plan doubles as a plan-cache key and can
be inspected by the physical planner (planner.py), which picks *how* to
compute each node — XLA segment ops vs the fused Pallas kernel, sorted
gather vs join_probe-kernel probes, single device vs a placement-policy
shard_map backend — from a cost model over static shape metadata.

Relational nodes (produce a Table: struct-of-arrays + selection mask):

  Scan(table)                       named base table
  Filter(child, pred)               AND a predicate into the mask
  Project(child, cols)              add derived columns (expression IR)
  Join(probe, build, pk, bk, take)  PK-FK join; ``take`` gathers build cols
  Attach(child, source, key, cols)  gather Aggregate outputs back into a
                                    table through a dense group-id column
                                    (the HAVING/re-join idiom of Q18)

Aggregation nodes (produce a dict of (n_groups,) arrays):

  Aggregate(child, key, n_groups, aggs)   grouped sum/avg/count/max/min/
                                          median; key=None is a global
                                          aggregate
  TopK(child, col, k, index_name)         order-by-limit over a group dict

``median`` is the HOLISTIC (order-statistic) aggregate: it cannot be
computed from mergeable partials (paper Section 2), so the physical
planner lowers it onto a local-sort selection — and, under a placement
policy, onto full record replication or routed distributed selection —
instead of the fused distributive sweeps.

Scalar expressions (Filter predicates / Project columns) are their own tiny
IR — Col / Lit / BinOp / UnOp — with operator sugar so builders read like
the imperative code they replace::

    from repro.analytics.plan import col, scan
    li = scan("lineitem").filter(col("l_shipdate") <= 1000)
    li = li.project(_rev=col("l_extendedprice") * (1 - col("l_discount")))
    q  = li.aggregate("l_returnflag", 3, revenue=("sum", "_rev"))

NOTE: ``==`` on plan/expression nodes is *structural equality* (needed for
cache keys); use ``Expr.eq()`` / ``Expr.ne()`` to build comparison
predicates.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Optional, Tuple, Union


# ---------------------------------------------------------------------------
# scalar expression IR
# ---------------------------------------------------------------------------
class _ExprOps:
    """Operator sugar shared by every expression node.

    ``__eq__`` stays structural (dataclass) so expressions remain valid
    dict keys; build equality predicates with ``.eq()`` / ``.ne()``.
    """

    # arithmetic ------------------------------------------------------------
    def __add__(self, o): return BinOp("add", self, wrap(o))
    def __radd__(self, o): return BinOp("add", wrap(o), self)
    def __sub__(self, o): return BinOp("sub", self, wrap(o))
    def __rsub__(self, o): return BinOp("sub", wrap(o), self)
    def __mul__(self, o): return BinOp("mul", self, wrap(o))
    def __rmul__(self, o): return BinOp("mul", wrap(o), self)
    def __truediv__(self, o): return BinOp("div", self, wrap(o))
    def __neg__(self): return UnOp("neg", self)
    def __abs__(self): return UnOp("abs", self)
    # comparisons / boolean -------------------------------------------------
    def __le__(self, o): return BinOp("le", self, wrap(o))
    def __lt__(self, o): return BinOp("lt", self, wrap(o))
    def __ge__(self, o): return BinOp("ge", self, wrap(o))
    def __gt__(self, o): return BinOp("gt", self, wrap(o))
    def __and__(self, o): return BinOp("and", self, wrap(o))
    def __or__(self, o): return BinOp("or", self, wrap(o))
    def eq(self, o): return BinOp("eq", self, wrap(o))
    def ne(self, o): return BinOp("ne", self, wrap(o))


@dataclass(frozen=True)
class Col(_ExprOps):
    name: str


@dataclass(frozen=True)
class Lit(_ExprOps):
    value: Union[int, float, bool]


@dataclass(frozen=True)
class BinOp(_ExprOps):
    op: str          # add sub mul div le lt ge gt eq ne and or
    lhs: "Expr"
    rhs: "Expr"


@dataclass(frozen=True)
class UnOp(_ExprOps):
    op: str          # abs neg not
    operand: "Expr"


Expr = Union[Col, Lit, BinOp, UnOp]


def wrap(v) -> Expr:
    """Coerce a python scalar to Lit; pass expressions through."""
    if isinstance(v, (Col, Lit, BinOp, UnOp)):
        return v
    if isinstance(v, (int, float, bool)):
        return Lit(v)
    raise TypeError(f"cannot use {type(v).__name__} in a plan expression")


def col(name: str) -> Col:
    return Col(name)


def lit(value) -> Lit:
    return Lit(value)


# ---------------------------------------------------------------------------
# cardinality references (resolved against table shapes at lowering time)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TableRows:
    """Group-domain size = row count of ``table`` (dense PK domains)."""
    table: str


Cardinality = Union[int, TableRows]


# ---------------------------------------------------------------------------
# plan nodes
# ---------------------------------------------------------------------------
class _NodeOps:
    """Fluent builders so logical plans read top-down."""

    def filter(self, pred: Expr) -> "Filter":
        return Filter(self, wrap(pred))

    def project(self, **cols: Expr) -> "Project":
        return Project(self, tuple((k, wrap(v)) for k, v in cols.items()))

    def join(self, build: "Node", probe_key: str, build_key: str,
             take: Mapping[str, str] = ()) -> "Join":
        return Join(self, build, probe_key, build_key,
                    tuple(dict(take).items()))

    def aggregate(self, key: Optional[str], n_groups: Cardinality,
                  **aggs: Tuple[str, str]) -> "Aggregate":
        return Aggregate(self, key, n_groups, tuple(aggs.items()))

    def attach(self, source: "Node", key: str,
               cols: Mapping[str, str]) -> "Attach":
        return Attach(self, source, key, tuple(dict(cols).items()))

    def top_k(self, col: str, k: int, index_name: str) -> "TopK":
        return TopK(self, col, k, index_name)


@dataclass(frozen=True)
class Scan(_NodeOps):
    table: str


@dataclass(frozen=True)
class Filter(_NodeOps):
    child: "Node"
    pred: Expr


@dataclass(frozen=True)
class Project(_NodeOps):
    child: "Node"
    cols: Tuple[Tuple[str, Expr], ...]


@dataclass(frozen=True)
class Join(_NodeOps):
    """PK-FK join: gather ``take`` (new_name -> build column) from the
    build side into the probe side; misses zero the probe row's mask."""
    probe: "Node"
    build: "Node"
    probe_key: str
    build_key: str
    take: Tuple[Tuple[str, str], ...] = ()


@dataclass(frozen=True)
class Aggregate(_NodeOps):
    """Grouped aggregation. ``aggs``: out_name -> (op, column); op in
    {sum, avg, count, max, min, median}. ``key=None`` is a single global
    group (returns (1,) arrays). Results always carry ``_count``; the
    executor accumulates ``_overflow`` across every Aggregate in the
    plan."""
    child: "Node"
    key: Optional[str]
    n_groups: Cardinality
    aggs: Tuple[Tuple[str, Tuple[str, str]], ...]


@dataclass(frozen=True)
class TopK(_NodeOps):
    """Top-``k`` groups of ``child`` (an aggregation) by ``col``; group ids
    are emitted under ``index_name``."""
    child: "Node"
    col: str
    k: int
    index_name: str


@dataclass(frozen=True)
class Attach(_NodeOps):
    """Gather columns of an Aggregate ``source`` into ``child`` rows through
    the dense group-id column ``key`` (new_name -> source output name)."""
    child: "Node"
    source: "Node"
    key: str
    cols: Tuple[Tuple[str, str], ...]


Node = Union[Scan, Filter, Project, Join, Aggregate, TopK, Attach]


@dataclass(frozen=True)
class LogicalPlan:
    """A root node plus the result keys to emit (None = everything)."""
    root: Node
    outputs: Optional[Tuple[str, ...]] = None


def scan(table: str) -> Scan:
    return Scan(table)


# ---------------------------------------------------------------------------
# IR validation
# ---------------------------------------------------------------------------
AGG_OPS = ("sum", "avg", "count", "max", "min", "median", "distinct")
# "quantile:R" (R a literal rank in (0, 1), e.g. "quantile:0.9") is also a
# valid agg op: the arbitrary-rank generalization of median, riding the
# same sort-based selection machinery (columnar.segment_quantile).
# "distinct" is the exact per-group distinct-value count; it shares the
# selection sort (columnar.segment_distinct counts run boundaries in the
# value-sorted order) and is holistic — distinct counts cannot be merged
# from partials, so it lowers like median/quantile, not like a sum.
_BIN_OPS = ("add", "sub", "mul", "div", "le", "lt", "ge", "gt", "eq", "ne",
            "and", "or")
_UN_OPS = ("abs", "neg", "not")


def parse_quantile(op: str) -> Optional[float]:
    """Rank of a "quantile:R" agg op, or None for every other op.

    Raises ValueError when the op IS a quantile but the rank is not a
    literal in the OPEN interval (0, 1) — rank 0/1 are min/max, which have
    exact distributive lowerings and must be spelled that way."""
    if not isinstance(op, str) or not op.startswith("quantile:"):
        return None
    try:
        rank = float(op.split(":", 1)[1])
    except ValueError:
        raise ValueError(f"malformed quantile op {op!r}; "
                         f"expected 'quantile:R' with R a float") from None
    if not 0.0 < rank < 1.0:
        raise ValueError(f"quantile rank must be in (0, 1), got {rank} "
                         f"(use 'min'/'max' for the endpoints)")
    return rank


def is_holistic(op: str) -> bool:
    """True for sort-backed ops whose result cannot be merged from
    partials (paper Section 2): median, arbitrary-rank quantiles, and
    exact distinct counts."""
    return (op in ("median", "distinct")
            or parse_quantile(op) is not None)


def holistic_selector(op: str):
    """The selection parameter a holistic op feeds to the shared
    sort-selection machinery: None for median (the middle rank),
    a float rank in (0, 1) for quantiles, and the string "distinct"
    for the distinct-count (run-boundary sum over the same sorted
    order). Only valid for ops where ``is_holistic`` is True."""
    if op == "median":
        return None
    if op == "distinct":
        return "distinct"
    rank = parse_quantile(op)
    if rank is None:
        raise ValueError(f"not a holistic agg op: {op!r}")
    return rank


def _validate_expr(e: Expr) -> None:
    if isinstance(e, (Col, Lit)):
        return
    if isinstance(e, UnOp):
        if e.op not in _UN_OPS:
            raise ValueError(f"unknown unary op {e.op!r} in plan expression")
        _validate_expr(e.operand)
        return
    if isinstance(e, BinOp):
        if e.op not in _BIN_OPS:
            raise ValueError(f"unknown binary op {e.op!r} in plan expression")
        _validate_expr(e.lhs)
        _validate_expr(e.rhs)
        return
    raise TypeError(f"not a plan expression: {e!r}")


def validate(plan: Union["LogicalPlan", Node]) -> None:
    """Structural validation of a plan before it reaches the planner.

    Checks what can be known without table shapes: aggregate ops are from
    AGG_OPS, Aggregates are non-empty with positive literal group domains,
    TopK/Attach consume an aggregation (a group dict, not a Table), every
    Table-consuming input (Filter/Project/Aggregate child, Join sides,
    Attach child) really is a Table node, and every expression uses known
    operators. Raises ValueError/TypeError on the first violation; the
    planner calls this once per plan-cache miss, so malformed plans fail
    fast instead of dying inside a jit trace."""
    table_nodes = (Scan, Filter, Project, Join, Attach)

    def want_table(node: Node, input_name: str, child: Node) -> None:
        if not isinstance(child, table_nodes):
            raise ValueError(
                f"{type(node).__name__} {input_name} must be a Table node "
                f"(Scan/Filter/Project/Join/Attach), got a group dict from "
                f"{type(child).__name__}")

    root = plan.root if isinstance(plan, LogicalPlan) else plan
    for node in walk(root):
        if isinstance(node, Aggregate):
            want_table(node, "child", node.child)
            if not node.aggs:
                raise ValueError("Aggregate needs at least one aggregate")
            for name, (op, _col) in node.aggs:
                if op not in AGG_OPS and parse_quantile(op) is None:
                    raise ValueError(
                        f"unknown agg op {op!r} for {name!r}; "
                        f"expected one of {AGG_OPS} or 'quantile:R'")
            if (not isinstance(node.n_groups, TableRows)
                    and int(node.n_groups) < 1):
                raise ValueError(f"Aggregate n_groups must be >= 1, "
                                 f"got {node.n_groups!r}")
        elif isinstance(node, TopK):
            if not isinstance(node.child, (Aggregate, TopK)):
                raise ValueError("TopK must consume an Aggregate/TopK "
                                 "(a group dict), not a Table node")
            if node.k < 1:
                raise ValueError(f"TopK k must be >= 1, got {node.k}")
        elif isinstance(node, Attach):
            want_table(node, "child", node.child)
            if not isinstance(node.source, Aggregate):
                raise ValueError("Attach source must be an Aggregate")
            if not node.cols:
                raise ValueError("Attach needs at least one column")
        elif isinstance(node, Filter):
            want_table(node, "child", node.child)
            _validate_expr(node.pred)
        elif isinstance(node, Project):
            want_table(node, "child", node.child)
            for _name, e in node.cols:
                _validate_expr(e)
        elif isinstance(node, Join):
            want_table(node, "probe side", node.probe)
            want_table(node, "build side", node.build)


# ---------------------------------------------------------------------------
# introspection helpers
# ---------------------------------------------------------------------------
def children(node: Node) -> Tuple[Node, ...]:
    if isinstance(node, Scan):
        return ()
    if isinstance(node, (Filter, Project, Aggregate, TopK)):
        return (node.child,)
    if isinstance(node, Join):
        return (node.probe, node.build)
    if isinstance(node, Attach):
        return (node.child, node.source)
    raise TypeError(f"not a plan node: {node!r}")


def walk(node: Node):
    """Yield every node of the subtree, root first."""
    yield node
    for c in children(node):
        yield from walk(c)


def base_scan(node: Node, column: str) -> Optional[Scan]:
    """The Scan whose base table still carries ``column`` unchanged, or None.

    Follows derivations that preserve column identity (Filter; Project /
    Join-take / Attach when they do not (re)define ``column``); this is what
    lets a build-side sort index computed on the base table serve every
    filtered view of it.
    """
    while True:
        if isinstance(node, Scan):
            return node
        if isinstance(node, Filter):
            node = node.child
        elif isinstance(node, Project):
            if any(n == column for n, _ in node.cols):
                return None
            node = node.child
        elif isinstance(node, Join):
            if any(n == column for n, _ in node.take):
                return None
            node = node.probe
        elif isinstance(node, Attach):
            if any(n == column for n, _ in node.cols):
                return None
            node = node.child
        else:
            return None


def expr_cols(e: Expr) -> frozenset:
    """The set of column names an expression reads — the oracle the
    planner's Filter-below-Exchange peephole consults to decide whether a
    predicate only touches pre-route (probe-side) columns."""
    if isinstance(e, Col):
        return frozenset((e.name,))
    if isinstance(e, Lit):
        return frozenset()
    if isinstance(e, UnOp):
        return expr_cols(e.operand)
    return expr_cols(e.lhs) | expr_cols(e.rhs)


def expr_str(e: Expr) -> str:
    if isinstance(e, Col):
        return e.name
    if isinstance(e, Lit):
        return repr(e.value)
    if isinstance(e, UnOp):
        return f"{e.op}({expr_str(e.operand)})"
    sym = {"add": "+", "sub": "-", "mul": "*", "div": "/", "le": "<=",
           "lt": "<", "ge": ">=", "gt": ">", "eq": "==", "ne": "!=",
           "and": "&", "or": "|"}[e.op]
    return f"({expr_str(e.lhs)} {sym} {expr_str(e.rhs)})"


def describe(plan: Union[LogicalPlan, Node], indent: int = 0) -> str:
    """Human-readable plan tree (used by planner.explain and examples)."""
    if isinstance(plan, LogicalPlan):
        return describe(plan.root)
    pad = "  " * indent
    if isinstance(plan, Scan):
        return f"{pad}Scan {plan.table}"
    if isinstance(plan, Filter):
        return (f"{pad}Filter {expr_str(plan.pred)}\n"
                + describe(plan.child, indent + 1))
    if isinstance(plan, Project):
        cols = ", ".join(f"{n}={expr_str(e)}" for n, e in plan.cols)
        return f"{pad}Project {cols}\n" + describe(plan.child, indent + 1)
    if isinstance(plan, Join):
        return (f"{pad}Join {plan.probe_key}={plan.build_key} "
                f"take={dict(plan.take)}\n"
                + describe(plan.probe, indent + 1) + "\n"
                + describe(plan.build, indent + 1))
    if isinstance(plan, Aggregate):
        aggs = ", ".join(f"{n}={op}({c})" for n, (op, c) in plan.aggs)
        return (f"{pad}Aggregate by {plan.key} [{plan.n_groups}] {aggs}\n"
                + describe(plan.child, indent + 1))
    if isinstance(plan, TopK):
        return (f"{pad}TopK {plan.k} by {plan.col}\n"
                + describe(plan.child, indent + 1))
    if isinstance(plan, Attach):
        return (f"{pad}Attach {dict(plan.cols)} via {plan.key}\n"
                + describe(plan.child, indent + 1) + "\n"
                + describe(plan.source, indent + 1))
    raise TypeError(f"not a plan node: {plan!r}")
