"""Cost-based physical planner for the logical plan IR (plan.py).

This is the "execution strategy changes underneath" half of the paper's
application-agnostic thesis: one logical plan, many physical realizations.
``execute_plan(plan, tables, ctx)`` lowers each logical node to a physical
operator chosen from static shape metadata and the ``ExecutionContext``:

  Aggregate   -> XLA segment ops | dense-chunked fused kernel |
                 range-partitioned fused kernel (``choose_aggregate``, a
                 documented cost model over (n_rows, n_groups, n_cols) —
                 fixes the ROADMAP note that large-domain single-aggregate
                 queries paid the range-partition argsort with no payoff)
  Join        -> sorted-index searchsorted gather (build argsorts hoisted
                 out of the compiled plan by ``JoinIndexPool``) | the
                 kernels/join_probe broadcast-compare kernel when the MXU
                 executes it (``choose_join``)
  whole plan  -> single-device | a placement-policy shard_map backend when
                 the context carries (mesh, PlacementPolicy): rows are
                 sharded over the mesh axis and distributive Aggregates
                 lower onto the engine.py collectives per policy
                 (all-reduce / reduce-scatter / record routing / converge),
                 so the paper's Section-3.3 placement plans execute the SAME
                 logical plans as the tuned kernel path.
  dist Join   -> broadcast (all-gather the build side) | key-partitioned
                 (route BOTH sides by join-key hash, the dist_hash_join
                 recipe), chosen by a wire-cost model (``dist_join_costs``)
                 over global row counts: broadcast moves n_build*(n-1)
                 rows, partitioned (n_probe+n_build)*(n-1)/n times the
                 measured routing overhead — so large build sides go
                 partitioned, small dimension tables keep broadcasting.
  median      -> holistic order statistic: local-sort selection on one
                 device; under a placement policy, full record replication
                 (FIRST_TOUCH/LOCAL_ALLOC/PREFERRED — holistic partials
                 cannot merge) or routed distributed selection (INTERLEAVE).

The cost model is deliberately simple — everything is expressed in
equivalent passes over the input rows:

  cost(xla)         = C                       (one segment op per stacked
                                               column; C = count + distinct
                                               sum/avg sources)
  cost(dense)       = 1.2 + 0.45 * C          (one fused sweep; per-column
                                               slope for the wider MXU dot;
                                               valid iff n_groups <=
                                               DENSE_GROUP_LIMIT)
  cost(partitioned) = cost(dense)
                      + 0.25 * log2(n_rows)   (the range-partition argsort)

so a single-aggregate query (C=2) always stays on segment ops, Q1's seven
aggregates (C=5) win with one fused sweep, and the partitioned layout is
chosen only when enough fused columns amortize the sort.

Compiled plans live in a bounded LRU cache keyed by (logical plan
structure, context key, table shape signature) — the logical plan IS the
cache key, no query names involved. ``plan_cache_info()`` /
``configure_plan_cache()`` expose and bound it. Join build-side argsort
indexes are pooled across calls keyed on column-array *identity* (so they
survive Table/pytree reconstruction) and enter the compiled plan as traced
arguments: repeated ``run_query`` calls on the same dataset never re-sort a
build side, fixing the per-call argsort the per-Table cache could not
amortize across traces.
"""
from __future__ import annotations

import functools
import json
import math
import threading
import weakref
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.analytics import plan as L
from repro.analytics.columnar import (DENSE_GROUP_LIMIT, Table,
                                      finalize_stacked, group_aggregate,
                                      pkfk_join, pkfk_join_kernel,
                                      segment_median, segment_order_stat,
                                      stacked_columns, stacked_group_sums)
from repro.analytics.engine import (gather_rows, interleave_group_median,
                                    interleave_group_sums,
                                    merge_partial_table,
                                    replicated_group_median, route_owner,
                                    route_table_rows, routing_capacity)
from repro.core.config import PlacementPolicy
from repro.kernels.common import kernel_mode


# ---------------------------------------------------------------------------
# execution context
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ExecutionContext:
    """Everything the planner may vary without touching the logical plan.

    ``executor``: "xla" forces segment ops, "kernel" forces the fused
    sweeps (the Fig 8/9 untuned/tuned axis), "cost" lets the cost model
    choose per Aggregate. ``join``: None = cost-based, or force "sorted" /
    "kernel". A (mesh, policy) pair selects the distributed placement
    backend; ``axis`` names the sharded mesh axis. ``dist_join``: None =
    the wire-cost model chooses per distributed Join, or force
    "broadcast" (all-gather the build side) / "partitioned" (route both
    sides by join-key hash)."""

    executor: str = "cost"
    mode: Optional[str] = None               # kernel lowering mode
    mesh: Optional[Mesh] = None
    policy: Optional[PlacementPolicy] = None
    axis: str = "data"
    join: Optional[str] = None
    n_partitions: int = 64
    capacity_factor: float = 2.0
    dist_join: Optional[str] = None

    def __post_init__(self):
        if self.executor not in ("xla", "kernel", "cost"):
            raise ValueError(f"unknown executor {self.executor!r}")
        if self.join not in (None, "sorted", "kernel"):
            raise ValueError(f"unknown join strategy {self.join!r}")
        if self.dist_join not in (None, "broadcast", "partitioned"):
            raise ValueError(
                f"unknown distributed join strategy {self.dist_join!r}")

    def cache_key(self) -> Tuple:
        mesh_key = None
        if self.mesh is not None:
            mesh_key = (tuple(self.mesh.shape.items()),
                        tuple(str(d) for d in self.mesh.devices.flat))
        return (self.executor, self.mode, mesh_key, self.policy, self.axis,
                self.join, self.n_partitions, self.capacity_factor,
                self.dist_join)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------
FUSED_FIXED = 1.2        # fused sweep: one-hot build + table merge overhead
FUSED_PER_COL = 0.45     # marginal pass-equivalent per stacked column
SORT_PASS_FACTOR = 0.25  # argsort pass-equivalents per log2(n_rows)
DIST_ROUTE_FACTOR = 1.5  # partitioned-join routing overhead per moved row
#   (the argsort-by-owner layout + capacity padding both sides pay, relative
#   to the raw all-gather bytes of the broadcast lowering; measured by
#   scripts/calibrate_costs.py --dist from the observed crossover)


@dataclass(frozen=True)
class CostProfile:
    """Pass-equivalent cost constants, either the hand-set defaults or a
    measured profile (scripts/calibrate_costs.py). Frozen/hashable so the
    active profile participates in the plan-cache key — plans compiled
    under one profile are never served after the constants change."""

    fused_fixed: float = FUSED_FIXED
    fused_per_col: float = FUSED_PER_COL
    sort_pass_factor: float = SORT_PASS_FACTOR
    dist_route_factor: float = DIST_ROUTE_FACTOR
    source: str = "builtin"


_COST_PROFILE = CostProfile()
_COST_PROFILE_LOCK = threading.Lock()


def current_cost_profile() -> CostProfile:
    return _COST_PROFILE


def set_cost_profile(profile: Optional[CostProfile]) -> CostProfile:
    """Install a cost profile (None restores the hand-set defaults)."""
    global _COST_PROFILE
    with _COST_PROFILE_LOCK:
        _COST_PROFILE = profile or CostProfile()
    return _COST_PROFILE


def load_cost_profile(path: str) -> CostProfile:
    """Install the measured constants written by scripts/calibrate_costs.py.

    The JSON carries {"fused_fixed", "fused_per_col", "sort_pass_factor"}
    (extra keys — backend, raw timings — are kept as provenance in
    ``source``); when present they replace the hand-set defaults for every
    subsequent planning decision."""
    with open(path) as f:
        raw = json.load(f)
    return set_cost_profile(CostProfile(
        fused_fixed=float(raw["fused_fixed"]),
        fused_per_col=float(raw["fused_per_col"]),
        sort_pass_factor=float(raw.get("sort_pass_factor", SORT_PASS_FACTOR)),
        dist_route_factor=float(raw.get("dist_route_factor",
                                        DIST_ROUTE_FACTOR)),
        source=str(raw.get("backend", path))))


def aggregate_costs(n_rows: int, n_groups: int, n_cols: int,
                    profile: Optional[CostProfile] = None
                    ) -> Dict[str, float]:
    """Pass-equivalent cost of each physical Aggregate layout (see module
    docstring for the formulas). ``n_cols`` counts the stacked matrix width:
    1 (COUNT/weights) + distinct sum/avg source columns. The constants come
    from ``profile`` — callers that cache on a profile snapshot must pass
    it explicitly so a concurrent recalibration cannot leak into a plan
    keyed under the old profile — or the active CostProfile."""
    p = profile or _COST_PROFILE
    fused = p.fused_fixed + p.fused_per_col * n_cols
    return {
        "xla": float(n_cols),
        "dense": fused if n_groups <= DENSE_GROUP_LIMIT else math.inf,
        "partitioned": fused + p.sort_pass_factor * math.log2(max(n_rows, 2)),
    }


def choose_aggregate(n_rows: int, n_groups: int, n_cols: int,
                     executor: str = "cost",
                     profile: Optional[CostProfile] = None) -> str:
    """Physical layout for one Aggregate: "xla" | "dense" | "partitioned"."""
    if executor == "xla":
        return "xla"
    if executor == "kernel":     # the tuned-path preference: always fused
        return "dense" if n_groups <= DENSE_GROUP_LIMIT else "partitioned"
    costs = aggregate_costs(n_rows, n_groups, n_cols, profile)
    return min(costs, key=costs.get)


def choose_join(n_probe: int, n_build: int, ctx: ExecutionContext) -> str:
    """"sorted" (searchsorted gather) vs "kernel" (join_probe probe).

    The broadcast-compare probe only beats the gather when the MXU actually
    executes it — its reference lowering is an O(n_probe * n_build / P)
    compare — so the cost rule requires a compiled Pallas backend plus a
    probe side large enough to amortize the partitioning pass."""
    if ctx.join is not None:
        return ctx.join
    if (kernel_mode(ctx.mode) == "pallas" and ctx.executor != "xla"
            and n_probe >= (1 << 14) and n_build >= 512):
        return "kernel"
    return "sorted"


def dist_join_costs(n_probe: int, n_build: int, n_shards: int,
                    profile: Optional[CostProfile] = None
                    ) -> Dict[str, float]:
    """Row-transfer-equivalent cost of each distributed Join lowering.

    broadcast    all-gathers the build side: every shard receives the
                 (n-1)/n of the build rows it does not already hold —
                 n_build * (n-1) rows on the wire, independent of the
                 probe side. Cheap while the build side fits a socket's
                 share; it is the cross-socket traffic the paper's Fig 5-7
                 placement results penalize once it does not.
    partitioned  routes BOTH sides by join-key hash (all-to-all): each row
                 moves once with probability (n-1)/n, and both sides pay
                 the routing layout pass (argsort by owner + capacity
                 padding), modeled by the dist_route_factor multiplier.

    The crossover: partitioned wins once the build side outgrows roughly
    probe/(n-1) rows — i.e. for large build sides on wide meshes."""
    p = profile or _COST_PROFILE
    n = max(int(n_shards), 2)
    return {
        "broadcast": float(n_build) * (n - 1),
        "partitioned": (float(n_probe) + float(n_build)) * (n - 1) / n
                       * p.dist_route_factor,
    }


def choose_dist_join(n_probe: int, n_build: int, n_shards: int,
                     ctx: "ExecutionContext",
                     profile: Optional[CostProfile] = None) -> str:
    """"broadcast" (all-gather build) vs "partitioned" (route both sides)
    for one distributed Join, from global row counts.

    The executor prices the PHYSICAL row counts it holds — for a probe
    that is itself the output of an upstream partitioned join, that
    includes the routed buffer's capacity padding, which really does ride
    every subsequent collective. explain(), which only sees logical
    shapes, can therefore report a different choice for the downstream
    joins of a chained-join plan."""
    if ctx.dist_join is not None:
        return ctx.dist_join
    if n_shards < 2:
        return "broadcast"       # nothing to move: routing is pure waste
    costs = dist_join_costs(n_probe, n_build, n_shards, profile)
    return min(costs, key=costs.get)


def stacked_width(aggs: Tuple[Tuple[str, Tuple[str, str]], ...]) -> int:
    """Width of the stacked values matrix: weights + distinct sum/avg."""
    return 1 + len({c for _, (op, c) in aggs if op in ("sum", "avg")})


@dataclass(frozen=True)
class Decision:
    """One planner choice, for ``explain`` output and tests."""
    node: str            # "Aggregate" | "Join"
    detail: str
    choice: str
    costs: Optional[Tuple[Tuple[str, float], ...]] = None

    def describe(self) -> str:
        c = ""
        if self.costs:
            c = " (" + ", ".join(f"{k}={v:.2f}" for k, v in self.costs) + ")"
        return f"{self.node}[{self.detail}] -> {self.choice}{c}"


# ---------------------------------------------------------------------------
# bounded LRU plan cache
# ---------------------------------------------------------------------------
class CacheInfo(NamedTuple):
    hits: int
    misses: int
    maxsize: int
    currsize: int


class LRUCache:
    """Bounded LRU, safe for concurrent get/put/evict.

    The service's worker pools hit the plan cache and join-index pool from
    many threads at once; unlocked, an interleaved move_to_end/popitem pair
    can race an eviction and raise KeyError, and the hit/miss counters can
    drop increments. Every mutation (including the counters, so
    ``plan_cache_info()`` is race-free) happens under one re-entrant lock —
    the critical sections are dict operations, far cheaper than the plan
    dispatch they guard."""

    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self._d: "OrderedDict" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self._lock = threading.RLock()

    def get(self, key):
        with self._lock:
            hit = self._d.get(key)
            if hit is None:
                self.misses += 1
                return None
            self._d.move_to_end(key)
            self.hits += 1
            return hit

    def put(self, key, value) -> None:
        with self._lock:
            self._d[key] = value
            self._d.move_to_end(key)
            while len(self._d) > self.maxsize:
                self._d.popitem(last=False)

    def resize(self, maxsize: int) -> None:
        with self._lock:
            self.maxsize = maxsize
            while len(self._d) > self.maxsize:
                self._d.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self._d.clear()
            self.hits = self.misses = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def info(self) -> CacheInfo:
        with self._lock:
            return CacheInfo(self.hits, self.misses, self.maxsize,
                             len(self._d))


DEFAULT_PLAN_CACHE_ENTRIES = 64
_PLAN_CACHE = LRUCache(DEFAULT_PLAN_CACHE_ENTRIES)


def configure_plan_cache(max_entries: int) -> None:
    """Bound the compiled-plan LRU (evicts oldest immediately if needed)."""
    if max_entries < 1:
        raise ValueError("plan cache needs at least one entry")
    _PLAN_CACHE.resize(max_entries)


def plan_cache_info() -> CacheInfo:
    return _PLAN_CACHE.info()


def plan_cache_size() -> int:
    return len(_PLAN_CACHE)


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()


# ---------------------------------------------------------------------------
# join build-side index pool
# ---------------------------------------------------------------------------
class JoinIndexPool:
    """(order, sorted_keys) argsorts keyed on column-array IDENTITY.

    The per-Table index cache (columnar.Table.index_cache) only lives for
    one trace: every compiled plan re-ran its build argsorts at dispatch
    time, and rebuilding the Tables pytree dropped the cache entirely. The
    pool keys on the underlying column array (``id`` plus an identity check
    against a WEAK reference, so recycled ids can never alias and the pool
    never keeps a dropped dataset alive on device), computes the argsort
    ONCE eagerly, and feeds it to the compiled plan as a traced argument —
    so the index survives Table reconstruction and is shared by every
    query/plan that joins through the same build column."""

    def __init__(self, maxsize: int = 256):
        self._lru = LRUCache(maxsize)
        self.builds = 0

    def get(self, table: str, column: str, arr) -> Tuple[jax.Array, jax.Array]:
        key = (table, column, id(arr))
        hit = self._lru.get(key)
        if hit is not None and hit[0]() is arr:
            return hit[1]
        # the argsort runs outside the lock: concurrent first-touchers of
        # the same column may both build (harmless — one entry survives),
        # but never block every other pool on an O(N log N) sort
        order = jnp.argsort(jnp.asarray(arr))
        idx = (order, jnp.asarray(arr)[order])
        with self._lru._lock:
            self._lru.put(key, (weakref.ref(arr), idx))
            self.builds += 1
            self._sweep_dead()
        return idx

    def _sweep_dead(self) -> None:
        with self._lru._lock:
            dead = [k for k, (ref, _) in self._lru._d.items()
                    if ref() is None]
            for k in dead:
                del self._lru._d[k]

    def info(self) -> CacheInfo:
        return self._lru.info()

    def clear(self) -> None:
        self._lru.clear()
        self.builds = 0


_INDEX_POOL = JoinIndexPool()


def join_index_pool() -> JoinIndexPool:
    return _INDEX_POOL


def required_indexes(root: L.Node) -> Tuple[Tuple[str, str], ...]:
    """(table, column) build-side sort indexes the plan's joins can use."""
    out: List[Tuple[str, str]] = []
    for node in L.walk(root):
        if isinstance(node, L.Join):
            sc = L.base_scan(node.build, node.build_key)
            if sc is not None and (sc.table, node.build_key) not in out:
                out.append((sc.table, node.build_key))
    return tuple(out)


# ---------------------------------------------------------------------------
# expression evaluation
# ---------------------------------------------------------------------------
def eval_expr(e: L.Expr, table: Table):
    if isinstance(e, L.Col):
        return table.col(e.name)
    if isinstance(e, L.Lit):
        return e.value
    if isinstance(e, L.UnOp):
        v = eval_expr(e.operand, table)
        if e.op == "abs":
            return jnp.abs(v)
        if e.op == "neg":
            return -v
        if e.op == "not":
            return ~v
        raise ValueError(f"unknown unary op {e.op!r}")
    if isinstance(e, L.BinOp):
        a, b = eval_expr(e.lhs, table), eval_expr(e.rhs, table)
        ops = {"add": lambda: a + b, "sub": lambda: a - b,
               "mul": lambda: a * b, "div": lambda: a / b,
               "le": lambda: a <= b, "lt": lambda: a < b,
               "ge": lambda: a >= b, "gt": lambda: a > b,
               "eq": lambda: a == b, "ne": lambda: a != b,
               "and": lambda: a & b, "or": lambda: a | b}
        try:
            return ops[e.op]()
        except KeyError:
            raise ValueError(f"unknown binary op {e.op!r}") from None
    raise TypeError(f"not an expression: {e!r}")


# ---------------------------------------------------------------------------
# physical execution
# ---------------------------------------------------------------------------
class _LocalExecutor:
    """Single-device lowering of a logical plan (trace-time recursion)."""

    def __init__(self, tables, ctx: ExecutionContext, indexes, true_rows,
                 profile: Optional[CostProfile] = None):
        self.tables = tables
        self.ctx = ctx
        self.indexes = indexes           # {"table.column": (order, sk)}
        self.true_rows = true_rows       # unpadded row counts per table
        self.profile = profile           # cost-constant snapshot (cache key)
        self.overflow = jnp.zeros((), jnp.int32)
        self._memo: Dict[L.Node, object] = {}

    # -- helpers ------------------------------------------------------------
    def resolve_groups(self, n: L.Cardinality) -> int:
        if isinstance(n, L.TableRows):
            return self.true_rows[n.table]
        return int(n)

    def run(self, node: L.Node):
        hit = self._memo.get(node)
        if hit is None:
            hit = self._eval(node)
            self._memo[node] = hit
        return hit

    # -- node lowerings -----------------------------------------------------
    def _eval(self, node: L.Node):
        method = getattr(self, "_" + type(node).__name__.lower())
        return method(node)

    def _scan(self, node: L.Scan) -> Table:
        cols = dict(self.tables[node.table])
        cache = {}
        for (key, idx) in self.indexes.items():
            t, _, c = key.partition(".")
            if t == node.table and c in cols:
                cache[c] = idx
        return Table(cols, None, cache)

    def _filter(self, node: L.Filter) -> Table:
        t = self.run(node.child)
        return t.filter(eval_expr(node.pred, t))

    def _project(self, node: L.Project) -> Table:
        t = self.run(node.child)
        return t.with_columns(**{n: eval_expr(e, t) for n, e in node.cols})

    def _join(self, node: L.Join) -> Table:
        probe = self.run(node.probe)
        build = self._build_side(node)
        strategy = choose_join(probe.n_rows, build.n_rows, self.ctx)
        if strategy == "kernel":
            joined, ovf = pkfk_join_kernel(
                probe, build, node.probe_key, node.build_key,
                dict(node.take), mode=self.ctx.mode,
                capacity_factor=self.ctx.capacity_factor)
            self.overflow = self.overflow + ovf
            return joined
        return pkfk_join(probe, build, node.probe_key, node.build_key,
                         dict(node.take))

    def _build_side(self, node: L.Join) -> Table:
        return self.run(node.build)

    def _attach(self, node: L.Attach) -> Table:
        t = self.run(node.child)
        src = self.run(node.source)
        first = src[node.cols[0][1]]
        pos = jnp.clip(t.col(node.key), 0, first.shape[0] - 1)
        return t.with_columns(**{new: src[s][pos] for new, s in node.cols})

    def _topk(self, node: L.TopK) -> Dict[str, jax.Array]:
        g = self.run(node.child)
        vals, idx = jax.lax.top_k(g[node.col], node.k)
        return {node.col: vals, node.index_name: idx}

    def _aggregate(self, node: L.Aggregate) -> Dict[str, jax.Array]:
        t = self.run(node.child)
        if node.key is None:
            return self._scalar_aggregate(node, t)
        G = self.resolve_groups(node.n_groups)
        layout = choose_aggregate(t.n_rows, G, stacked_width(node.aggs),
                                  self.ctx.executor, self.profile)
        out = self._grouped(node, t, G, layout)
        self.overflow = self.overflow + out["_overflow"]
        return out

    def _grouped(self, node: L.Aggregate, t: Table, G: int,
                 layout: str) -> Dict[str, jax.Array]:
        aggs = dict(node.aggs)
        if layout == "xla":
            return group_aggregate(t, node.key, G, aggs, executor="xla")
        return group_aggregate(t, node.key, G, aggs, executor="kernel",
                               layout=layout, mode=self.ctx.mode,
                               n_partitions=self.ctx.n_partitions,
                               capacity_factor=self.ctx.capacity_factor)

    def _scalar_aggregate(self, node: L.Aggregate,
                          t: Table) -> Dict[str, jax.Array]:
        w = t.weights()
        cnt = w.sum()[None]
        out: Dict[str, jax.Array] = {}
        for name, (op, col) in node.aggs:
            if op == "count":
                out[name] = cnt
                continue
            v = t.col(col).astype(jnp.float32)
            if op == "sum":
                out[name] = (v * w).sum()[None]
            elif op == "avg":
                out[name] = (v * w).sum()[None] / jnp.maximum(cnt, 1.0)
            elif op == "max":
                out[name] = jnp.where(w > 0, v, -jnp.inf).max()[None]
            elif op == "min":
                out[name] = jnp.where(w > 0, v, jnp.inf).min()[None]
            elif op == "median":
                k = jnp.where(w > 0, 0, -1)
                out[name] = segment_median(k, v, 1)[0]
            else:
                raise ValueError(f"unknown agg op {op!r}")
        out["_count"] = cnt
        out["_overflow"] = jnp.zeros((), jnp.int32)
        return out

    # -- plan root ----------------------------------------------------------
    def execute(self, plan: L.LogicalPlan) -> Dict[str, jax.Array]:
        res = self.run(plan.root)
        if isinstance(res, Table):
            raise TypeError("plan root must be an Aggregate or TopK node")
        out = dict(res)
        out["_overflow"] = self.overflow
        if plan.outputs is not None:
            out = {k: out[k] for k in plan.outputs}
        return out


class _DistributedExecutor(_LocalExecutor):
    """Placement-policy backend: runs inside an open shard_map over
    ``ctx.axis``. Tables arrive row-sharded (zero-padded, with a ``_valid``
    weight column folded into each Scan's mask); build sides are
    republished with an all-gather before probing; distributive Aggregates
    merge through the engine.py per-policy collectives. The merged group
    tables (and therefore every post-aggregation node) are replicated."""

    def __init__(self, tables, ctx: ExecutionContext, true_rows, n_shards,
                 profile: Optional[CostProfile] = None):
        super().__init__(tables, ctx, {}, true_rows, profile)
        self.n = n_shards

    def _scan(self, node: L.Scan) -> Table:
        cols = {c: a for c, a in self.tables[node.table].items()
                if c != "_valid"}
        return Table(cols, self.tables[node.table]["_valid"])

    def _join(self, node: L.Join) -> Table:
        """Distributed PK-FK join: broadcast vs key-partitioned, chosen by
        the wire-cost model (dist_join_costs) from GLOBAL row counts —
        shapes inside the shard_map are per-shard, so multiply back by n.
        The kernel probe stays a single-device lowering; both strategies
        gather through the sorted index once rows are placed."""
        probe = self.run(node.probe)
        build = self.run(node.build)
        strategy = choose_dist_join(probe.n_rows * self.n,
                                    build.n_rows * self.n, self.n,
                                    self.ctx, self.profile)
        if strategy == "partitioned":
            return self._partitioned_join(node, probe, build)
        return pkfk_join(probe, self._gathered(build), node.probe_key,
                         node.build_key, dict(node.take))

    def _gathered(self, build: Table) -> Table:
        """Broadcast lowering: republish the build side on every shard
        (all-gather — the first-touch faulting pattern)."""
        cols = gather_rows(build.columns, self.ctx.axis)
        mask = (None if build.mask is None
                else gather_rows(build.mask, self.ctx.axis))
        return Table(cols, mask)

    def _partitioned_join(self, node: L.Join, probe: Table,
                          build: Table) -> Table:
        """Partitioned lowering: route BOTH sides to the join key's hash
        owner (key % n, the dist_hash_join recipe) through one all-to-all
        each, then join shard-locally. O((N_probe+N_build)/n) received rows
        per shard instead of the whole build side; routed padding rows
        carry weight 0 and key -1, so they can never match a real key.
        Routing overflow (a destination's capacity exceeded) is surfaced
        through the plan's ``_overflow`` accumulator, never dropped
        silently."""
        axis, n, cf = self.ctx.axis, self.n, self.ctx.capacity_factor
        pk = probe.col(node.probe_key).astype(jnp.int32)
        bk = build.col(node.build_key).astype(jnp.int32)
        p_w0, b_w0 = probe.weights(), build.weights()
        p_cols, p_w, p_ovf = route_table_rows(
            probe.columns, p_w0, route_owner(pk, p_w0 > 0, n), n,
            routing_capacity(pk.shape[0], n, cf), axis)
        b_cols, b_w, b_ovf = route_table_rows(
            build.columns, b_w0, route_owner(bk, b_w0 > 0, n), n,
            routing_capacity(bk.shape[0], n, cf), axis)
        self.overflow = self.overflow + jax.lax.psum(
            p_ovf + b_ovf, axis).astype(jnp.int32)
        return pkfk_join(Table(p_cols, p_w), Table(b_cols, b_w),
                         node.probe_key, node.build_key, dict(node.take))

    def _aggregate(self, node: L.Aggregate) -> Dict[str, jax.Array]:
        t = self.run(node.child)
        policy = self.ctx.policy or PlacementPolicy.FIRST_TOUCH
        axis, n = self.ctx.axis, self.n
        if node.key is None:
            return self._dist_scalar_aggregate(node, t)
        G = self.resolve_groups(node.n_groups)
        dist_aggs = tuple((nm, oc) for nm, oc in node.aggs
                          if oc[0] != "median")
        med_out, med_counts, med_ovf = self._dist_medians(node, t, G, policy)
        if not dist_aggs:
            # median-only aggregate: counts come from the selection path —
            # no second routing/merge pass just for _count
            out = dict(med_out)
            out["_count"] = med_counts
            out["_overflow"] = med_ovf
            self.overflow = self.overflow + med_ovf
            return out
        keys, vals, src = stacked_columns(t, node.key, G, dict(dist_aggs))

        def local_sums(k, v, n_groups, allow_partitioned=True):
            layout = choose_aggregate(k.shape[0], n_groups, v.shape[1],
                                      self.ctx.executor, self.profile)
            if layout == "partitioned" and not allow_partitioned:
                # the routed interleave buffer masses its padding on one
                # drop slot; the partitioned layout's capacity accounting
                # counts those rows (see engine.interleave_group_sums), so
                # fall back to the occupancy-independent segment ops
                layout = "xla"
            return stacked_group_sums(
                k, v, n_groups, layout=layout, mode=self.ctx.mode,
                n_partitions=self.ctx.n_partitions,
                capacity_factor=self.ctx.capacity_factor)

        if policy in (PlacementPolicy.FIRST_TOUCH,
                      PlacementPolicy.LOCAL_ALLOC):
            partial, ovf = local_sums(keys, vals, G)
            sums = merge_partial_table(partial, policy, axis, n)
            overflow = jax.lax.psum(ovf, axis)
        elif policy == PlacementPolicy.INTERLEAVE:
            sums, overflow = interleave_group_sums(
                keys, vals, G, axis, n,
                functools.partial(local_sums, allow_partitioned=False),
                capacity_factor=self.ctx.capacity_factor)
        else:                                  # PREFERRED: converge rows
            ak, av = gather_rows((keys, vals), axis)
            sums, overflow = local_sums(ak, av, G)
        out = self._finalize_groups(dict(dist_aggs), t, keys, src, sums, G)
        out.update(med_out)
        out["_overflow"] = overflow.astype(jnp.int32) + med_ovf
        self.overflow = self.overflow + out["_overflow"]
        return out

    def _dist_medians(self, node: L.Aggregate, t: Table, G: int, policy
                      ) -> Tuple[Dict[str, jax.Array], Optional[jax.Array],
                                 jax.Array]:
        """Per-policy lowering of an Aggregate's holistic (median) aggs.

        Medians cannot merge from partials, so they bypass the stacked-sums
        collectives entirely: replication-based policies gather the records
        (the paper's holistic worst case), INTERLEAVE routes each group's
        records to its owner and selects there (distributed selection).
        Returns ({name: (G,) medians}, counts-or-None, overflow), all
        replicated in natural group order."""
        axis, n = self.ctx.axis, self.n
        med_aggs = tuple((nm, oc) for nm, oc in node.aggs
                         if oc[0] == "median")
        if not med_aggs:
            return {}, None, jnp.zeros((), jnp.int32)
        keys = jnp.clip(t.col(node.key), 0, G - 1).astype(jnp.int32)
        w = t.weights()
        cols = {name: t.col(colname).astype(jnp.float32)
                for name, (_op, colname) in med_aggs}
        if policy == PlacementPolicy.INTERLEAVE:
            meds, counts, ovf = interleave_group_median(
                keys, cols, w, G, axis, n,
                capacity_factor=self.ctx.capacity_factor)
            return meds, counts, ovf.astype(jnp.int32)
        meds, counts = replicated_group_median(keys, cols, w, G, axis)
        return meds, counts, jnp.zeros((), jnp.int32)

    def _dist_scalar_aggregate(self, node: L.Aggregate,
                               t: Table) -> Dict[str, jax.Array]:
        """Global aggregate: merge the SUMS across shards (an average of
        per-shard averages would weight shards, not rows)."""
        axis = self.ctx.axis
        w = t.weights()
        cnt = jax.lax.psum(w.sum(), axis)[None]
        out: Dict[str, jax.Array] = {}
        med_cols: Dict[str, jax.Array] = {}
        for name, (op, col) in node.aggs:
            if op == "count":
                out[name] = cnt
                continue
            v = t.col(col).astype(jnp.float32)
            if op in ("sum", "avg"):
                s = jax.lax.psum((v * w).sum(), axis)[None]
                out[name] = s if op == "sum" else s / jnp.maximum(cnt, 1.0)
            elif op == "max":
                out[name] = jax.lax.pmax(
                    jnp.where(w > 0, v, -jnp.inf).max(), axis)[None]
            elif op == "min":
                out[name] = jax.lax.pmin(
                    jnp.where(w > 0, v, jnp.inf).min(), axis)[None]
            elif op == "median":
                med_cols[name] = v       # batched below: gather rows once
            else:
                raise ValueError(f"unknown agg op {op!r}")
        if med_cols:
            # holistic: converge the records ONCE, select per column
            meds, _ = replicated_group_median(
                jnp.zeros_like(w, jnp.int32), med_cols, w, 1, axis)
            out.update(meds)
        out["_count"] = cnt
        out["_overflow"] = jnp.zeros((), jnp.int32)
        return out

    def _finalize_groups(self, aggs: Dict[str, Tuple[str, str]], t: Table,
                         keys, src, sums, G: int) -> Dict[str, jax.Array]:
        def order_stat(op, col):
            # local segment op, then a cross-shard tree reduction
            local = segment_order_stat(t, keys, G, op, col)
            reduce = jax.lax.pmax if op == "max" else jax.lax.pmin
            return reduce(local, self.ctx.axis)

        return finalize_stacked(aggs, src, sums, order_stat)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def _signature(tables) -> Tuple:
    return tuple(sorted((t, c, tuple(a.shape), str(a.dtype))
                        for t, cols in tables.items()
                        for c, a in cols.items()))


def table_signature(tables) -> Tuple:
    """Public shape signature of a {table: {column: array}} pytree — the
    axis of the plan-cache key that identifies "structurally identical
    data" (stable across dict rebuilds; the serving batcher groups on
    it)."""
    return _signature(tables)


def cached_executable(key: Tuple, build):
    """Fetch-or-build an executable in the shared bounded plan LRU.

    Public seam for auxiliary executables that must live under the same
    cache bound and thread-safety as compiled plans (e.g. the serving
    scheduler's per-morsel partial-aggregation functions). ``key`` should
    start with a distinguishing tag so it can never collide with
    compile_plan's (plan, ctx, signature, profile) keys."""
    fn = _PLAN_CACHE.get(key)
    if fn is None:
        fn = build()
        _PLAN_CACHE.put(key, fn)
    return fn


def _true_rows(tables) -> Dict[str, int]:
    return {t: next(iter(cols.values())).shape[0]
            for t, cols in tables.items()}


def _run_local(plan: L.LogicalPlan, ctx: ExecutionContext, profile, tables,
               indexes):
    ex = _LocalExecutor(tables, ctx, indexes, _true_rows(tables), profile)
    return ex.execute(plan)


def _run_distributed(plan: L.LogicalPlan, ctx: ExecutionContext, profile,
                     tables, indexes):
    del indexes          # full-table indexes don't survive the row padding
    mesh, axis = ctx.mesh, ctx.axis
    n = mesh.shape[axis]
    rows = _true_rows(tables)
    padded = {}
    for t, cols in tables.items():
        r = rows[t]
        pad = -r % n
        pcols = {c: jnp.pad(jnp.asarray(a), [(0, pad)] + [(0, 0)]
                            * (jnp.asarray(a).ndim - 1))
                 for c, a in cols.items()}
        pcols["_valid"] = (jnp.arange(r + pad) < r).astype(jnp.float32)
        padded[t] = pcols

    def local_fn(local_tables):
        ex = _DistributedExecutor(local_tables, ctx, rows, n, profile)
        return ex.execute(plan)

    specs = jax.tree_util.tree_map(lambda _: P(axis), padded)
    return shard_map(local_fn, mesh=mesh, in_specs=(specs,), out_specs=P(),
                     check_rep=False)(padded)


def _run_plan(plan: L.LogicalPlan, ctx: ExecutionContext, profile, tables,
              indexes):
    if ctx.mesh is None:
        return _run_local(plan, ctx, profile, tables, indexes)
    return _run_distributed(plan, ctx, profile, tables, indexes)


class CompiledPlan:
    """Re-entrant dispatch handle for one (plan, context, shape signature).

    ``compile_plan`` resolves the plan-cache entry ONCE; the handle can then
    be called from any worker thread without touching the planner again —
    only the join-index pool is consulted per call (a lock-protected LRU
    hit), so concurrent dispatch never re-plans, re-jits, or races an
    eviction. This is the entry point the serving scheduler pins into its
    worker pools."""

    __slots__ = ("plan", "ctx", "fn", "index_specs")

    def __init__(self, plan: L.LogicalPlan, ctx: ExecutionContext, fn,
                 index_specs: Tuple[Tuple[str, str], ...]):
        self.plan = plan
        self.ctx = ctx
        self.fn = fn
        self.index_specs = index_specs

    def __call__(self, tables) -> Dict[str, jax.Array]:
        indexes = {}
        if self.ctx.mesh is None:
            for t, c in self.index_specs:
                indexes[f"{t}.{c}"] = _INDEX_POOL.get(t, c, tables[t][c])
        return self.fn(tables, indexes)


def compile_plan(plan: L.LogicalPlan, tables,
                 ctx: Optional[ExecutionContext] = None) -> CompiledPlan:
    """Resolve (or build) the compiled executable for a logical plan.

    ``tables`` supplies only the shape signature — the returned handle runs
    on ANY tables pytree of the same shapes. The active CostProfile is
    snapshotted ONCE: it keys the cache AND is baked into the compiled
    closure (jit traces lazily on first call — reading the global there
    would let a concurrent recalibration plan under the new constants but
    cache under the old key)."""
    ctx = ctx or ExecutionContext()
    profile = current_cost_profile()
    key = (plan, ctx.cache_key(), _signature(tables), profile)
    fn = _PLAN_CACHE.get(key)
    if fn is None:
        L.validate(plan)     # fail fast (and once) instead of mid-trace
        fn = jax.jit(functools.partial(_run_plan, plan, ctx, profile))
        _PLAN_CACHE.put(key, fn)
    return CompiledPlan(plan, ctx, fn, required_indexes(plan.root))


def execute_plan(plan: L.LogicalPlan, tables,
                 ctx: Optional[ExecutionContext] = None
                 ) -> Dict[str, jax.Array]:
    """Compile (through the LRU plan cache) and run a logical plan.

    ``tables``: {table: {column: array}} pytree, passed to the compiled
    plan as traced arguments — one compilation serves any data of the same
    shape signature. Build-side join indexes are pulled from the
    JoinIndexPool and traced in alongside."""
    return compile_plan(plan, tables, ctx)(tables)


def explain(plan: L.LogicalPlan, tables,
            ctx: Optional[ExecutionContext] = None) -> List[Decision]:
    """Dry-run the planner's choices from shape metadata alone (no
    execution): one Decision per Join / grouped Aggregate, plan order."""
    ctx = ctx or ExecutionContext()
    rows = _true_rows(tables)
    decisions: List[Decision] = []

    def node_rows(node: L.Node) -> int:
        if isinstance(node, L.Scan):
            return rows[node.table]
        if isinstance(node, L.Aggregate):
            if node.key is None:
                return 1
            return (rows[node.n_groups.table]
                    if isinstance(node.n_groups, L.TableRows)
                    else int(node.n_groups))
        if isinstance(node, L.TopK):
            return node.k
        if isinstance(node, L.Join):
            return node_rows(node.probe)
        return node_rows(L.children(node)[0])

    def visit(node: L.Node) -> None:
        for c in L.children(node):
            visit(c)
        if isinstance(node, L.Join):
            n_probe, n_build = node_rows(node.probe), node_rows(node.build)
            if ctx.mesh is not None:
                n = ctx.mesh.shape[ctx.axis]
                decisions.append(Decision(
                    "DistJoin", f"{node.probe_key}={node.build_key}, "
                    f"probe={n_probe}, build={n_build}, shards={n}",
                    choose_dist_join(n_probe, n_build, n, ctx),
                    tuple(dist_join_costs(n_probe, n_build, n).items())))
            else:
                decisions.append(Decision(
                    "Join", f"{node.probe_key}={node.build_key}, "
                    f"probe={n_probe}, build={n_build}",
                    choose_join(n_probe, n_build, ctx)))
        elif isinstance(node, L.Aggregate) and node.key is not None:
            N = node_rows(node.child)
            G = (rows[node.n_groups.table]
                 if isinstance(node.n_groups, L.TableRows)
                 else int(node.n_groups))
            C = stacked_width(node.aggs)
            decisions.append(Decision(
                "Aggregate", f"key={node.key}, rows={N}, groups={G}, "
                f"cols={C}",
                choose_aggregate(N, G, C, ctx.executor),
                tuple(aggregate_costs(N, G, C).items())))

    visit(plan.root)
    return decisions
