"""Cost-based physical planner for the logical plan IR (plan.py).

This is the "execution strategy changes underneath" half of the paper's
application-agnostic thesis: one logical plan, many physical realizations.
Since PR 5 the planner is a genuine THREE-LAYER pipeline:

  logical plan  --lower(plan, ctx)-->  PHYSICAL PLAN  --walk-->  executors

``lower`` turns each logical node into an explicit physical operator
(physical.py) with every strategy decision resolved to a plain field —
join algorithm, aggregate layout, Exchange kind, compaction point — from
static shape metadata and the ``ExecutionContext``:

  Aggregate   -> XLA segment ops | dense-chunked fused kernel |
                 range-partitioned fused kernel (``choose_aggregate``, a
                 documented cost model over (n_rows, n_groups, n_cols))
  Join        -> sorted-index searchsorted gather (build argsorts hoisted
                 out of the compiled plan by ``JoinIndexPool``) | the
                 kernels/join_probe broadcast-compare kernel when the MXU
                 executes it (``choose_join``)
  dist Join   -> PJoin over Exchange(broadcast) | PJoin over two
                 Exchange(hash) routings, chosen by a wire-cost model
                 (``dist_join_costs``) over physical row counts
  dist Agg    -> PPartialAggregate + per-policy merge collectives; under
                 INTERLEAVE the record routing is an explicit
                 Exchange(hash) that three movement REWRITES then improve:

  (1) aggregate PUSH-DOWN: a distributive Aggregate splits into
      PPartialAggregate below a hash Exchange + merge above it, shipping
      ~n_groups partial rows per shard instead of n_rows records
      (physical.pushdown_profitable prices the split);
  (2) ROUTE-ONCE: structurally identical hash Exchanges deduplicate via
      executor memoization, and an Exchange whose child is already
      co-located by the same key (an upstream partitioned join on that
      key) is elided entirely — join AND aggregate route one time
      (physical.routes_once / placed_key);
  (3) occupancy-aware COMPACT: a routed buffer is cut back to
      COMPACT_MARGIN x its estimated alive rows before being routed
      again (engine.compact_routed_rows), so chained partitioned joins
      stop growing padding by a capacity_factor per hop
      (physical.maybe_compact).

``explain`` reports one Decision per physical Join/Aggregate/Exchange/
Compact (estimated moved rows included); ``explain_physical`` renders the
whole physical tree (golden-snapshot tested). The executors
(_LocalExecutor / _DistributedExecutor) are thin walkers over the
physical IR: they dispatch on node type and call the engine/columnar
primitives the node names — every placement policy, median strategy, and
routing plan that existed before the physical layer executes the same
primitives in the same order (the parity grids pin this).

The cost model is deliberately simple — everything is expressed in
equivalent passes over the input rows:

  cost(xla)         = C                       (one segment op per stacked
                                               column; C = count + distinct
                                               sum/avg sources)
  cost(dense)       = 1.2 + 0.45 * C          (one fused sweep; per-column
                                               slope for the wider MXU dot;
                                               valid iff n_groups <=
                                               profile.dense_group_limit)
  cost(partitioned) = cost(dense)
                      + 0.25 * log2(n_rows)   (the range-partition argsort)

Compiled plans live in a bounded LRU cache keyed by (logical plan
structure, context key, table shape signature, cost profile); the cache
VALUE is the (physical plan, jitted executable) pair, so the physical
tree is inspectable for every cached entry. Join build-side argsort
indexes are pooled across calls keyed on column-array *identity* and
enter the compiled plan as traced arguments.
"""
from __future__ import annotations

import functools
import json
import math
import threading
import time
import weakref
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.analytics import physical as PH
from repro.analytics import plan as L
from repro.analytics import telemetry
from repro.analytics import tracing
from repro.analytics.columnar import (DENSE_GROUP_LIMIT, Table,
                                      finalize_stacked, group_aggregate,
                                      pkfk_join, pkfk_join_kernel,
                                      segment_distinct, segment_median,
                                      segment_order_stat, segment_quantile,
                                      stacked_columns, stacked_group_sums)
from repro.analytics.engine import (compact_routed_rows, gather_rows,
                                    interleave_group_median,
                                    interleave_group_sums,
                                    merge_partial_table,
                                    placed_group_median,
                                    pushdown_group_sums,
                                    radix_route_table_rows,
                                    replicated_group_median, route_owner,
                                    route_table_rows, routing_capacity)
from repro.analytics.plan import (holistic_selector, is_holistic,
                                  parse_quantile)
from repro.core.config import PlacementPolicy
from repro.kernels.common import kernel_mode


# ---------------------------------------------------------------------------
# execution context
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ExecutionContext:
    """Everything the planner may vary without touching the logical plan.

    ``executor``: "xla" forces segment ops, "kernel" forces the fused
    sweeps (the Fig 8/9 untuned/tuned axis), "cost" lets the cost model
    choose per Aggregate. ``join``: None = cost-based, or force "sorted" /
    "kernel". A (mesh, policy) pair selects the distributed placement
    backend; ``axis`` names the sharded mesh axis. ``dist_join``: None =
    the wire-cost model chooses per distributed Join, or force
    "broadcast" / "partitioned". ``dist_route`` picks the owner function
    for partitioned-join routing: "hash" (default; multiplicative hash,
    robust to clustered/strided key spaces) or "modulo" (the legacy
    dense-id map — dist_hash_join pins it to reproduce the retired W3
    plans bit-identically). ``exchange_impl`` picks the routing LAYOUT
    pass of key-routing hash Exchanges: "cost" (default; exchange_costs
    chooses per Exchange from the routed rows), or force "argsort" /
    "radix" (the radix-partition histogram kernel path — bit-identical
    results, different layout cost). ``agg_pushdown``: None = push
    distributive aggregates below the exchange when n_groups < per-shard
    rows, or force True/False. ``route_once``: elide exchanges whose
    child is already placed by the same key (False disables).
    ``compact``: None = insert occupancy-aware Compact nodes before
    re-routing padded buffers (COMPACT_MARGIN occupancy headroom), False
    disables, a float overrides the margin. ``dist_topk`` picks the
    distributed TopK lowering: "cost" (default; topk_costs chooses from
    the group-table size vs the candidate volume), or force "replicated"
    (select on the merged replicated group table) / "candidates" (each
    shard selects local top-k candidates over the group slots it owns
    and a gather Exchange converges only k * n_shards candidate rows —
    bit-identical results, no group-table replication priced on the
    TopK)."""

    executor: str = "cost"
    mode: Optional[str] = None               # kernel lowering mode
    mesh: Optional[Mesh] = None
    policy: Optional[PlacementPolicy] = None
    axis: str = "data"
    join: Optional[str] = None
    n_partitions: int = 64
    capacity_factor: float = 2.0
    dist_join: Optional[str] = None
    dist_route: str = "hash"
    exchange_impl: str = "cost"
    dist_topk: str = "cost"
    agg_pushdown: Optional[bool] = None
    route_once: bool = True
    compact: Union[None, bool, int, float] = None

    def __post_init__(self):
        if self.executor not in ("xla", "kernel", "cost"):
            raise ValueError(f"unknown executor {self.executor!r}")
        if self.join not in (None, "sorted", "kernel"):
            raise ValueError(f"unknown join strategy {self.join!r}")
        if self.dist_join not in (None, "broadcast", "partitioned"):
            raise ValueError(
                f"unknown distributed join strategy {self.dist_join!r}")
        if self.dist_route not in ("hash", "modulo"):
            raise ValueError(f"unknown routing method {self.dist_route!r}")
        if self.exchange_impl not in ("argsort", "radix", "cost"):
            raise ValueError(
                f"unknown exchange impl {self.exchange_impl!r}")
        if self.dist_topk not in ("cost", "replicated", "candidates"):
            raise ValueError(
                f"unknown distributed TopK lowering {self.dist_topk!r}")
        if (not isinstance(self.compact, bool) and self.compact is not None
                and (not isinstance(self.compact, (int, float))
                     or self.compact < 1.0)):
            raise ValueError("compact must be None, a bool, or a numeric "
                             f"margin >= 1.0; got {self.compact!r}")

    def cache_key(self) -> Tuple:
        mesh_key = None
        if self.mesh is not None:
            mesh_key = (tuple(self.mesh.shape.items()),
                        tuple(str(d) for d in self.mesh.devices.flat))
        # compact keys by its RESOLVED margin (None when disabled):
        # compact=True, None and 1.5 lower to identical physical plans,
        # while the raw values would collide bool/int spellings of
        # DIFFERENT margins (True == 1 == 1.0 in Python)
        return (self.executor, self.mode, mesh_key, self.policy, self.axis,
                self.join, self.n_partitions, self.capacity_factor,
                self.dist_join, self.dist_route, self.exchange_impl,
                self.dist_topk, self.agg_pushdown, self.route_once,
                self.compact_margin())

    # -- rewrite-knob resolution -------------------------------------------
    def compact_margin(self) -> Optional[float]:
        """Occupancy headroom for Compact nodes, or None when disabled."""
        if self.compact is False:
            return None
        if self.compact is None or self.compact is True:
            return COMPACT_MARGIN
        return float(self.compact)           # numeric margin override


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------
FUSED_FIXED = 1.2        # fused sweep: one-hot build + table merge overhead
FUSED_PER_COL = 0.45     # marginal pass-equivalent per stacked column
SORT_PASS_FACTOR = 0.25  # argsort pass-equivalents per log2(n_rows)
DIST_ROUTE_FACTOR = 1.5  # partitioned-join routing overhead per moved row
#   (the argsort-by-owner layout + capacity padding both sides pay, relative
#   to the raw all-gather bytes of the broadcast lowering; measured by
#   scripts/calibrate_costs.py --dist from the observed crossover)
COMPACT_MARGIN = 1.5     # Compact budget: margin x estimated alive rows.
#   Routing capacity_factor absorbs per-destination ROUTING skew; this
#   margin absorbs occupancy-estimate error of an already-routed buffer.
#   Alive rows beyond the budget surface as _overflow, never vanish.
RADIX_ROUTE_FACTOR = 2.5  # radix Exchange layout: flat pass-equivalents
#   (block histograms + prefix sums are O(n) regardless of n_rows), vs the
#   argsort layout's sort_pass_factor * log2(n_rows) — crossover at
#   2^(radix/sort) ~ 1024 per-shard rows with the hand-set constants;
#   scripts/calibrate_costs.py --exchange fits it from the measured one.
FILTER_SELECTIVITY = 0.75  # est alive fraction surviving one PFilter.
#   Feeds three pricing decisions: Exchange.moved_rows (the priced wire
#   payload), the aggregate push-down crossover (pushdown_profitable is
#   priced on est * selectivity^filters, not physical rows), and the
#   Compact budget (maybe_compact folds it into the margin, CLAMPED at
#   1.0 x est so a selectivity prior can never shrink a buffer below its
#   estimated alive rows and surface phantom overflow — alive rows beyond
#   any budget still land in _overflow, never vanish).
#   telemetry.refresh_profile replaces it with the observed
#   alive_out/alive_in ratio, so all three decisions adapt to drift.
MORSEL_SPLIT_ROWS = 2048  # smallest LOCAL sorted-join probe side worth
#   splitting into per-pool morsels: below this, per-morsel dispatch
#   overhead (a jit call + partial merge per morsel) beats the
#   parallelism. Marks PJoin.morsel_split during lowering; the serving
#   scheduler's probe_split path honors the mark. Fitted by
#   scripts/calibrate_costs.py --morsel from the measured crossover.


@dataclass(frozen=True)
class CostProfile:
    """Pass-equivalent cost constants, either the hand-set defaults or a
    measured profile (scripts/calibrate_costs.py). Frozen/hashable so the
    active profile participates in the plan-cache key — plans compiled
    under one profile are never served after the constants change.
    ``dense_group_limit`` bounds the dense fused layout's key domain
    (measured by the --sweep-groups calibration; defaults to the VMEM
    model constant) and ``partition_capacity_factor``, when fitted,
    overrides the context's capacity factor for the range-partitioned
    aggregate layout only (routing capacities stay on the context).
    ``compact_margin``, when set (telemetry.refresh_profile fits it from
    observed Compact occupancy), replaces the hand-set COMPACT_MARGIN for
    contexts that leave ``compact`` at its default None — an explicit
    context override always wins."""

    fused_fixed: float = FUSED_FIXED
    fused_per_col: float = FUSED_PER_COL
    sort_pass_factor: float = SORT_PASS_FACTOR
    dist_route_factor: float = DIST_ROUTE_FACTOR
    radix_route_factor: float = RADIX_ROUTE_FACTOR
    filter_selectivity: float = FILTER_SELECTIVITY
    dense_group_limit: int = DENSE_GROUP_LIMIT
    morsel_split_rows: int = MORSEL_SPLIT_ROWS
    partition_capacity_factor: Optional[float] = None
    compact_margin: Optional[float] = None
    source: str = "builtin"


_COST_PROFILE = CostProfile()
_COST_PROFILE_LOCK = threading.Lock()


def current_cost_profile() -> CostProfile:
    return _COST_PROFILE


def set_cost_profile(profile: Optional[CostProfile]) -> CostProfile:
    """Install a cost profile (None restores the hand-set defaults)."""
    global _COST_PROFILE
    with _COST_PROFILE_LOCK:
        _COST_PROFILE = profile or CostProfile()
    return _COST_PROFILE


def load_cost_profile(path: str) -> CostProfile:
    """Install the measured constants written by scripts/calibrate_costs.py.

    The JSON carries {"fused_fixed", "fused_per_col", "sort_pass_factor"}
    plus, when the respective sweeps ran, "dist_route_factor",
    "dense_group_limit" and "partition_capacity_factor" (extra keys —
    backend, raw timings — are kept as provenance in ``source``); when
    present they replace the hand-set defaults for every subsequent
    planning decision."""
    with open(path) as f:
        raw = json.load(f)
    pcf = raw.get("partition_capacity_factor")
    cm = raw.get("compact_margin")
    return set_cost_profile(CostProfile(
        compact_margin=(None if cm is None else float(cm)),
        fused_fixed=float(raw["fused_fixed"]),
        fused_per_col=float(raw["fused_per_col"]),
        sort_pass_factor=float(raw.get("sort_pass_factor", SORT_PASS_FACTOR)),
        dist_route_factor=float(raw.get("dist_route_factor",
                                        DIST_ROUTE_FACTOR)),
        radix_route_factor=float(raw.get("radix_route_factor",
                                         RADIX_ROUTE_FACTOR)),
        filter_selectivity=float(raw.get("filter_selectivity",
                                         FILTER_SELECTIVITY)),
        dense_group_limit=int(raw.get("dense_group_limit",
                                      DENSE_GROUP_LIMIT)),
        morsel_split_rows=int(raw.get("morsel_split_rows",
                                      MORSEL_SPLIT_ROWS)),
        partition_capacity_factor=(None if pcf is None else float(pcf)),
        source=str(raw.get("backend", path))))


def aggregate_costs(n_rows: int, n_groups: int, n_cols: int,
                    profile: Optional[CostProfile] = None
                    ) -> Dict[str, float]:
    """Pass-equivalent cost of each physical Aggregate layout (see module
    docstring for the formulas). ``n_cols`` counts the stacked matrix width:
    1 (COUNT/weights) + distinct sum/avg source columns. The constants come
    from ``profile`` — callers that cache on a profile snapshot must pass
    it explicitly so a concurrent recalibration cannot leak into a plan
    keyed under the old profile — or the active CostProfile."""
    p = profile or _COST_PROFILE
    fused = p.fused_fixed + p.fused_per_col * n_cols
    return {
        "xla": float(n_cols),
        "dense": fused if n_groups <= p.dense_group_limit else math.inf,
        "partitioned": fused + p.sort_pass_factor * math.log2(max(n_rows, 2)),
    }


def choose_aggregate(n_rows: int, n_groups: int, n_cols: int,
                     executor: str = "cost",
                     profile: Optional[CostProfile] = None) -> str:
    """Physical layout for one Aggregate: "xla" | "dense" | "partitioned"."""
    p = profile or _COST_PROFILE
    if executor == "xla":
        return "xla"
    if executor == "kernel":     # the tuned-path preference: always fused
        return "dense" if n_groups <= p.dense_group_limit else "partitioned"
    costs = aggregate_costs(n_rows, n_groups, n_cols, p)
    return min(costs, key=costs.get)


def choose_join(n_probe: int, n_build: int, ctx: ExecutionContext) -> str:
    """"sorted" (searchsorted gather) vs "kernel" (join_probe probe).

    The broadcast-compare probe only beats the gather when the MXU actually
    executes it — its reference lowering is an O(n_probe * n_build / P)
    compare — so the cost rule requires a compiled Pallas backend plus a
    probe side large enough to amortize the partitioning pass."""
    if ctx.join is not None:
        return ctx.join
    if (kernel_mode(ctx.mode) == "pallas" and ctx.executor != "xla"
            and n_probe >= (1 << 14) and n_build >= 512):
        return "kernel"
    return "sorted"


def dist_join_costs(n_probe: int, n_build: int, n_shards: int,
                    profile: Optional[CostProfile] = None
                    ) -> Dict[str, float]:
    """Row-transfer-equivalent cost of each distributed Join lowering.

    broadcast    all-gathers the build side: every shard receives the
                 (n-1)/n of the build rows it does not already hold —
                 n_build * (n-1) rows on the wire, independent of the
                 probe side. Cheap while the build side fits a socket's
                 share; it is the cross-socket traffic the paper's Fig 5-7
                 placement results penalize once it does not.
    partitioned  routes BOTH sides by join-key hash (all-to-all): each row
                 moves once with probability (n-1)/n, and both sides pay
                 the routing layout pass (argsort by owner + capacity
                 padding), modeled by the dist_route_factor multiplier.

    The crossover: partitioned wins once the build side outgrows roughly
    probe/(n-1) rows — i.e. for large build sides on wide meshes."""
    p = profile or _COST_PROFILE
    n = max(int(n_shards), 2)
    return {
        "broadcast": float(n_build) * (n - 1),
        "partitioned": (float(n_probe) + float(n_build)) * (n - 1) / n
                       * p.dist_route_factor,
    }


def choose_dist_join(n_probe: int, n_build: int, n_shards: int,
                     ctx: "ExecutionContext",
                     profile: Optional[CostProfile] = None) -> str:
    """"broadcast" (all-gather build) vs "partitioned" (route both sides)
    for one distributed Join, from global row counts.

    The lowering prices the PHYSICAL row counts each side holds BEFORE
    the movement rewrites touch them — for a probe that is itself the
    output of an upstream partitioned join, that includes the routed
    buffer's full capacity padding. Compact is inserted after this
    choice, so the partitioned estimate is conservative (pads the cost of
    rows compaction will reclaim), biasing borderline chained joins
    toward broadcast; pricing post-compact rows is a ROADMAP
    refinement."""
    if ctx.dist_join is not None:
        return ctx.dist_join
    if n_shards < 2:
        return "broadcast"       # nothing to move: routing is pure waste
    costs = dist_join_costs(n_probe, n_build, n_shards, profile)
    return min(costs, key=costs.get)


def exchange_costs(n_rows: int, profile: Optional[CostProfile] = None
                   ) -> Dict[str, float]:
    """Pass-equivalent LAYOUT cost of each hash-Exchange routing impl for
    ``n_rows`` per-shard routed rows. Both paths ship the same bytes and
    produce bit-identical buffers; what differs is how the send layout is
    built: "argsort" pays a stable sort (sort_pass_factor * log2(n)),
    "radix" pays a flat histogram + prefix-sum pass (radix_route_factor,
    measured by scripts/calibrate_costs.py --exchange). argsort wins small
    buffers, radix wins past the crossover."""
    p = profile or _COST_PROFILE
    return {
        "argsort": p.sort_pass_factor * math.log2(max(n_rows, 2)),
        "radix": p.radix_route_factor,
    }


def choose_exchange_impl(n_rows: int, ctx: "ExecutionContext",
                         profile: Optional[CostProfile] = None) -> str:
    """"argsort" vs "radix" for one key-routing hash Exchange."""
    if ctx.exchange_impl != "cost":
        return ctx.exchange_impl
    costs = exchange_costs(n_rows, profile)
    return min(costs, key=costs.get)


def topk_costs(n_groups: int, k: int, n_shards: int,
               profile: Optional[CostProfile] = None) -> Dict[str, float]:
    """Row-transfer-equivalent cost of each distributed TopK lowering.

    replicated   selects on the merged group table, which must therefore
                 be replicated on every shard: the TopK is charged the
                 (n-1)/n of the G group rows each shard receives beyond
                 the slots it owns (the replication the merge collective
                 would otherwise not need — LOCAL_ALLOC's reduce_scatter,
                 for instance, is owner-sharded by nature).
    candidates   each shard selects its local top-k over the ~G/n group
                 slots it owns; a gather Exchange converges k rows per
                 shard — k * n_shards candidate rows on the wire,
                 independent of the group-table size.

    The crossover: candidates wins once G(n-1)/n > kn, i.e. for any group
    domain meaningfully larger than k * n (the common case — a TopK's k
    is tiny next to its group table)."""
    del profile                      # priced in raw rows, no fitted factor
    n = max(int(n_shards), 2)
    return {
        "replicated": float(n_groups) * (n - 1) / n,
        "candidates": float(k) * n,
    }


def choose_dist_topk(n_groups: int, k: int, n_shards: int,
                     ctx: "ExecutionContext",
                     profile: Optional[CostProfile] = None) -> str:
    """"replicated" vs "candidates" for one distributed TopK."""
    if ctx.dist_topk != "cost":
        return ctx.dist_topk
    if n_shards < 2:
        return "replicated"          # nothing to move: candidates is waste
    costs = topk_costs(n_groups, k, n_shards, profile)
    return min(costs, key=costs.get)


def stacked_width(aggs: Tuple[Tuple[str, Tuple[str, str]], ...]) -> int:
    """Width of the stacked values matrix: weights + distinct sum/avg."""
    return 1 + len({c for _, (op, c) in aggs if op in ("sum", "avg")})


def _stacked_src(aggs) -> list:
    """Distinct sum/avg source columns, insertion order — the static twin
    of the ``src`` list stacked_columns derives from data."""
    src: list = []
    for _name, (op, c) in aggs:
        if op in ("sum", "avg") and c not in src:
            src.append(c)
    return src


@dataclass(frozen=True)
class Decision:
    """One planner choice, for ``explain`` output and tests."""
    node: str            # "Aggregate" | "Join" | "DistJoin" | "Exchange" ...
    detail: str
    choice: str
    costs: Optional[Tuple[Tuple[str, float], ...]] = None

    def describe(self) -> str:
        c = ""
        if self.costs:
            c = " (" + ", ".join(f"{k}={v:.2f}" for k, v in self.costs) + ")"
        return f"{self.node}[{self.detail}] -> {self.choice}{c}"


# ---------------------------------------------------------------------------
# bounded LRU plan cache
# ---------------------------------------------------------------------------
class CacheInfo(NamedTuple):
    hits: int
    misses: int
    maxsize: int
    currsize: int


class LRUCache:
    """Bounded LRU, safe for concurrent get/put/evict.

    The service's worker pools hit the plan cache and join-index pool from
    many threads at once; unlocked, an interleaved move_to_end/popitem pair
    can race an eviction and raise KeyError, and the hit/miss counters can
    drop increments. Every mutation (including the counters, so
    ``plan_cache_info()`` is race-free) happens under one re-entrant lock —
    the critical sections are dict operations, far cheaper than the plan
    dispatch they guard."""

    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self._d: "OrderedDict" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self._lock = threading.RLock()

    def get(self, key):
        with self._lock:
            hit = self._d.get(key)
            if hit is None:
                self.misses += 1
                return None
            self._d.move_to_end(key)
            self.hits += 1
            return hit

    def put(self, key, value) -> None:
        with self._lock:
            self._d[key] = value
            self._d.move_to_end(key)
            while len(self._d) > self.maxsize:
                self._d.popitem(last=False)

    def resize(self, maxsize: int) -> None:
        with self._lock:
            self.maxsize = maxsize
            while len(self._d) > self.maxsize:
                self._d.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self._d.clear()
            self.hits = self.misses = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def info(self) -> CacheInfo:
        with self._lock:
            return CacheInfo(self.hits, self.misses, self.maxsize,
                             len(self._d))


DEFAULT_PLAN_CACHE_ENTRIES = 64
_PLAN_CACHE = LRUCache(DEFAULT_PLAN_CACHE_ENTRIES)


def configure_plan_cache(max_entries: int) -> None:
    """Bound the compiled-plan LRU (evicts oldest immediately if needed)."""
    if max_entries < 1:
        raise ValueError("plan cache needs at least one entry")
    _PLAN_CACHE.resize(max_entries)


def plan_cache_info() -> CacheInfo:
    return _PLAN_CACHE.info()


def plan_cache_size() -> int:
    return len(_PLAN_CACHE)


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()


# ---------------------------------------------------------------------------
# join build-side index pool
# ---------------------------------------------------------------------------
class JoinIndexPool:
    """(order, sorted_keys) argsorts keyed on column-array IDENTITY.

    The per-Table index cache (columnar.Table.index_cache) only lives for
    one trace: every compiled plan re-ran its build argsorts at dispatch
    time, and rebuilding the Tables pytree dropped the cache entirely. The
    pool keys on the underlying column array (``id`` plus an identity check
    against a WEAK reference, so recycled ids can never alias and the pool
    never keeps a dropped dataset alive on device), computes the argsort
    ONCE eagerly, and feeds it to the compiled plan as a traced argument —
    so the index survives Table reconstruction and is shared by every
    query/plan that joins through the same build column."""

    def __init__(self, maxsize: int = 256):
        self._lru = LRUCache(maxsize)
        self.builds = 0
        self.replicas = 0

    def get(self, table: str, column: str, arr) -> Tuple[jax.Array, jax.Array]:
        key = (table, column, id(arr))
        hit = self._lru.get(key)
        if hit is not None and hit[0]() is arr:
            return hit[1]
        # the argsort runs outside the lock: concurrent first-touchers of
        # the same column may both build (harmless — one entry survives),
        # but never block every other pool on an O(N log N) sort
        order = jnp.argsort(jnp.asarray(arr))
        idx = (order, jnp.asarray(arr)[order])
        with self._lru._lock:
            self._lru.put(key, (weakref.ref(arr), idx))
            self.builds += 1
            self._sweep_dead()
        return idx

    def replica(self, table: str, column: str, arr,
                pool_id: int) -> Tuple[jax.Array, jax.Array]:
        """A per-worker-pool copy of ``get``'s (order, sorted_keys) pair —
        the build-side replication of the paper's socket-local working
        sets. The base index is computed ONCE (``builds`` counts sorts);
        each pool then gets its own buffer copy (``replicas`` counts
        them), so every probe morsel a pool executes hits a pool-local
        build structure instead of contending on one shared buffer.
        Values are bit-identical to the base index by construction."""
        key = (table, column, id(arr), "replica", int(pool_id))
        hit = self._lru.get(key)
        if hit is not None and hit[0]() is arr:
            return hit[1]
        order, sk = self.get(table, column, arr)     # base: built once
        with self._lru._lock:
            # double-check under the lock: two workers of the SAME pool
            # can race their pool's first morsel, and "one replica per
            # pool" is the accounting invariant tests pin down
            hit = self._lru.get(key)
            if hit is not None and hit[0]() is arr:
                return hit[1]
            idx = (jnp.copy(order), jnp.copy(sk))
            self._lru.put(key, (weakref.ref(arr), idx))
            self.replicas += 1
            self._sweep_dead()
        return idx

    def _sweep_dead(self) -> None:
        with self._lru._lock:
            dead = [k for k, (ref, _) in self._lru._d.items()
                    if ref() is None]
            for k in dead:
                del self._lru._d[k]

    def info(self) -> CacheInfo:
        return self._lru.info()

    def clear(self) -> None:
        self._lru.clear()
        self.builds = 0
        self.replicas = 0


_INDEX_POOL = JoinIndexPool()


def join_index_pool() -> JoinIndexPool:
    return _INDEX_POOL


def required_indexes(root: L.Node) -> Tuple[Tuple[str, str], ...]:
    """(table, column) build-side sort indexes the plan's joins can use."""
    out: List[Tuple[str, str]] = []
    for node in L.walk(root):
        if isinstance(node, L.Join):
            sc = L.base_scan(node.build, node.build_key)
            if sc is not None and (sc.table, node.build_key) not in out:
                out.append((sc.table, node.build_key))
    return tuple(out)


# ---------------------------------------------------------------------------
# morsel-split probe analysis (the serving scheduler's split-probe oracle)
# ---------------------------------------------------------------------------
def _physical_base_scan(node: PH.PNode, column: str) -> Optional[PH.PScan]:
    """The PScan whose ``column`` reaches ``node`` value-identical (same
    rows, same order, never overwritten), or None. The physical twin of
    L.base_scan: it certifies that the pooled (order, sorted_keys) index
    built from the base table's column array is valid for this node's
    Table — Filter only masks, a local Join's output rows ARE its probe
    rows, Project/Attach only add columns (unless they shadow
    ``column``)."""
    while True:
        if isinstance(node, PH.PScan):
            return node
        if isinstance(node, PH.PFilter):
            node = node.child
        elif isinstance(node, PH.PProject):
            if any(n == column for n, _ in node.cols):
                return None
            node = node.child
        elif isinstance(node, PH.PJoin):
            if node.dist is not None or any(n == column
                                            for n, _ in node.take):
                return None
            node = node.probe
        elif isinstance(node, PH.PAttach):
            if any(n == column for n, _ in node.cols):
                return None
            node = node.child
        else:
            return None


@dataclass(frozen=True)
class PreludeSpec:
    """One subtree of a split-probe plan that executes ONCE per task (not
    per morsel): a join build side or an Attach source. ``is_table`` says
    whether its result is a Table (serialized as (columns, mask) across
    the jit boundary) or a replicated dict of group arrays; ``index`` is
    the (table, column) pooled sort index a pool-local replica must seed
    into the reconstructed build Table's index_cache (None for Attach
    sources, which need no index)."""
    node: PH.PNode
    is_table: bool
    index: Optional[Tuple[str, str]]


@dataclass(frozen=True)
class ProbeSplit:
    """probe_split()'s answer: the pieces the serving scheduler needs to
    run a marked join-probe pipeline as per-pool morsels. ``scan`` is the
    probe-side base scan (the morsel axis), ``pipeline_root`` the
    aggregate's input (the per-morsel pipeline: every node between scan
    and aggregate is per-row deterministic, so concatenating the morsel
    outputs in morsel order reproduces the serial intermediate table
    bit-for-bit), ``preludes`` the once-per-task subtrees, ``root`` /
    ``outputs`` what the finalize step runs over the merged table."""
    root: PH.PNode
    outputs: Optional[Tuple[str, ...]]
    scan: PH.PScan
    pipeline_root: PH.PNode
    preludes: Tuple[PreludeSpec, ...]
    n_rows: int


def probe_split(phys: PH.PhysicalPlan) -> Optional[ProbeSplit]:
    """Decompose a LOCAL physical plan into a morsel-splittable probe
    pipeline, or None when the plan must run whole.

    Splittable = (optional PTopK over) a PAggregate whose child chain
    down to one PScan is Filter/Project/Join/Attach where EVERY join is
    ``morsel_split``-marked (sorted strategy, probe side past the
    cost-model crossover) with a resolvable base-scan build index. Each
    on-path operator is per-row deterministic over the probe rows, so a
    row-range slice of the scan yields exactly that slice of the serial
    intermediate table — the bit-identity guarantee the whole-plan path
    already had, kept under intra-query parallelism. Declines (returns
    None) rather than degrade: an unresolvable build index would force a
    per-morsel argsort (defeating once-per-pool replication), and a
    kernel-strategy join changes overflow semantics under slicing."""
    if phys.n_shards != 1:
        return None
    node = phys.root
    while isinstance(node, PH.PTopK):
        node = node.child
    if not isinstance(node, PH.PAggregate):
        return None
    preludes: List[PreludeSpec] = []
    path: List[PH.PNode] = []
    cur = node.child
    while not isinstance(cur, PH.PScan):
        path.append(cur)
        if isinstance(cur, (PH.PFilter, PH.PProject)):
            cur = cur.child
        elif isinstance(cur, PH.PJoin):
            if not cur.morsel_split:
                return None          # cost model declined (or kernel join)
            base = _physical_base_scan(cur.build, cur.build_key)
            if base is None:
                return None          # no poolable build index: stay whole
            preludes.append(PreludeSpec(
                cur.build, True, (base.table, cur.build_key)))
            cur = cur.probe
        elif isinstance(cur, PH.PAttach):
            src = cur.source
            preludes.append(PreludeSpec(
                src, not isinstance(src, (PH.PAggregate, PH.PTopK)), None))
            cur = cur.child
        else:
            return None
    if not any(p.index is not None for p in preludes):
        return None                  # no join probe to parallelize
    scan = cur
    path.append(scan)
    # a prelude subtree structurally EQUAL to a path node would collide
    # in the executor's structural memo (the path is seeded with
    # morsel-sliced values, the prelude with whole-table ones) — decline
    # such self-join-like shapes instead of guessing
    path_set = set(path)
    if any(p.node in path_set for p in preludes):
        return None
    return ProbeSplit(phys.root, phys.outputs, scan, node.child,
                      tuple(preludes), scan.rows)


# ---------------------------------------------------------------------------
# expression evaluation
# ---------------------------------------------------------------------------
def eval_expr(e: L.Expr, table: Table):
    if isinstance(e, L.Col):
        return table.col(e.name)
    if isinstance(e, L.Lit):
        return e.value
    if isinstance(e, L.UnOp):
        v = eval_expr(e.operand, table)
        if e.op == "abs":
            return jnp.abs(v)
        if e.op == "neg":
            return -v
        if e.op == "not":
            return ~v
        raise ValueError(f"unknown unary op {e.op!r}")
    if isinstance(e, L.BinOp):
        a, b = eval_expr(e.lhs, table), eval_expr(e.rhs, table)
        ops = {"add": lambda: a + b, "sub": lambda: a - b,
               "mul": lambda: a * b, "div": lambda: a / b,
               "le": lambda: a <= b, "lt": lambda: a < b,
               "ge": lambda: a >= b, "gt": lambda: a > b,
               "eq": lambda: a == b, "ne": lambda: a != b,
               "and": lambda: a & b, "or": lambda: a | b}
        try:
            return ops[e.op]()
        except KeyError:
            raise ValueError(f"unknown binary op {e.op!r}") from None
    raise TypeError(f"not an expression: {e!r}")


# ---------------------------------------------------------------------------
# lowering: logical plan -> physical plan
# ---------------------------------------------------------------------------
def lower(plan: L.LogicalPlan, ctx: ExecutionContext,
          rows: Dict[str, int], profile: Optional[CostProfile] = None,
          n_shards: Optional[int] = None,
          observed=None) -> PH.PhysicalPlan:
    """Cost-driven lowering pass: resolve every strategy decision into an
    explicit physical tree, then let the movement rewrites (push-down,
    route-once, compaction — see module docstring) improve it.

    ``rows`` maps table name -> true row count (the shape signature the
    plan-cache key already carries). ``n_shards`` overrides the mesh width
    — lowering is pure shape arithmetic, so tests and explain can lower
    distributed plans without materializing fake devices. ``observed`` is
    the adaptive re-planning hook: an ``observed(probe_key, build_key) ->
    (probe_alive, build_alive) | None`` lookup (telemetry's recorded
    GLOBAL alive rows) consulted ONLY by the distributed-join cost choice
    — estimates and buffer shapes are untouched, so a re-lowering with
    unchanged decisions is structurally identical to the original."""
    profile = profile or current_cost_profile()
    if n_shards is None:
        n_shards = ctx.mesh.shape[ctx.axis] if ctx.mesh is not None else 1
        distributed = ctx.mesh is not None
    else:
        distributed = True
    lo = _Lowering(ctx, rows, profile, n_shards, distributed, observed)
    root = lo.node(plan.root)
    return PH.PhysicalPlan(root, plan.outputs,
                           n_shards if distributed else 1)


class _Lowering:
    """One lower() pass: shape propagation + strategy choice per node."""

    def __init__(self, ctx, rows, profile, n, distributed, observed=None):
        self.ctx = ctx
        self.rows = rows
        self.profile = profile
        self.n = n
        self.distributed = distributed
        self.observed = observed             # adaptive re-plan lookup
        margin = ctx.compact_margin()        # None = compaction disabled
        if ctx.compact is None and profile.compact_margin is not None:
            # context left the margin at its default: the profile's
            # telemetry-fitted margin replaces the hand-set constant
            margin = profile.compact_margin
        self.margin = margin

    def groups(self, card: L.Cardinality) -> int:
        if isinstance(card, L.TableRows):
            return self.rows[card.table]
        return int(card)

    def node(self, node: L.Node) -> PH.PNode:
        method = getattr(self, "_" + type(node).__name__.lower())
        return method(node)

    # -- relational nodes ---------------------------------------------------
    def _scan(self, node: L.Scan) -> PH.PScan:
        r = self.rows[node.table]
        per = (r + (-r % self.n)) // self.n if self.distributed else r
        return PH.PScan(node.table, rows=per, est=per)

    def _filter(self, node: L.Filter) -> PH.PNode:
        c = self.node(node.child)
        pushed = self._filter_below_exchange(c, node.pred)
        if pushed is not None:
            return pushed
        return PH.PFilter(c, node.pred, rows=c.rows, est=c.est)

    def _filter_below_exchange(self, c: PH.PNode,
                               pred: L.Expr) -> Optional[PH.PNode]:
        """Filter-below-Exchange peephole: a Filter over a partitioned
        PJoin whose predicate reads only PRE-ROUTE columns (none of the
        join's take columns, so every referenced column already exists on
        the probe side below its hash Exchange) is pushed beneath the
        probe routing. Rows the predicate kills become dead padding BEFORE
        the all-to-all — they re-route round-robin with zero weight — so
        the wire carries fewer alive rows, not just a cheaper layout.
        Results are bit-identical: the filter mask multiplies into the
        same selection weights either side of the routing, and dead rows
        can never match a join key or enter an aggregate. The Exchange's
        ``moved_rows`` estimate shrinks by the profile's
        filter_selectivity per pushed filter (capacity and est are
        untouched — occupancy budgets stay safe); telemetry's observed
        alive_in/alive_out refreshes the selectivity."""
        if not (self.distributed and isinstance(c, PH.PJoin)
                and c.dist == "partitioned"):
            return None
        ex = c.probe
        if not (isinstance(ex, PH.Exchange) and ex.kind == "hash"
                and ex.key is not None):
            return None
        cols = L.expr_cols(pred)
        if not cols or any(name in cols for name, _src in c.take):
            return None              # predicate reads a post-join column
        inner = PH.PFilter(ex.child, pred, rows=ex.child.rows,
                           est=ex.child.est, pushed=True)
        sel = self.profile.filter_selectivity ** PH.filters_below(inner)
        moved = int(ex.est * sel) * (self.n - 1) // self.n
        routed = PH.Exchange(inner, "hash", key=ex.key,
                             capacity=ex.capacity, method=ex.method,
                             rows=ex.rows, est=ex.est, moved_rows=moved,
                             impl=ex.impl)
        return PH.PJoin(routed, c.build, c.probe_key, c.build_key, c.take,
                        c.strategy, c.dist, rows=c.rows, est=c.est)

    def _project(self, node: L.Project) -> PH.PProject:
        c = self.node(node.child)
        return PH.PProject(c, node.cols, rows=c.rows, est=c.est)

    def _attach(self, node: L.Attach) -> PH.PAttach:
        c = self.node(node.child)
        src = self.node(node.source)
        return PH.PAttach(c, src, node.key, node.cols, rows=c.rows,
                          est=c.est)

    def _topk(self, node: L.TopK) -> PH.PTopK:
        c = self.node(node.child)
        if not self.distributed:
            return PH.PTopK(c, node.col, node.k, node.index_name,
                            rows=node.k, est=node.k)
        # distributed TopK: the child aggregate's merged group table is
        # replicated, so selecting on it directly ("replicated") is
        # correct but charges the TopK the table's replication. The
        # "candidates" lowering instead selects each shard's local top-k
        # over the ~G/n group slots it owns and converges only k rows per
        # shard through an explicit gather Exchange — k * n_shards
        # candidate rows on the wire, bit-identical results (within-shard
        # ties keep ascending global slot order, the shard-major gather
        # preserves it, and lax.top_k's lowest-index tie-break matches
        # the replicated selection).
        G = c.rows
        choice = choose_dist_topk(G, node.k, self.n, self.ctx, self.profile)
        if choice == "candidates":
            ex = PH.Exchange(c, "gather", rows=node.k * self.n,
                             est=node.k * self.n,
                             moved_rows=node.k * (self.n - 1))
            return PH.PTopK(ex, node.col, node.k, node.index_name,
                            dist="candidates", rows=node.k, est=node.k)
        return PH.PTopK(c, node.col, node.k, node.index_name,
                        dist="replicated", rows=node.k, est=node.k)

    # -- joins --------------------------------------------------------------
    def _join(self, node: L.Join) -> PH.PJoin:
        probe = self.node(node.probe)
        build = self.node(node.build)
        if not self.distributed:
            strategy = choose_join(probe.rows, build.rows, self.ctx)
            # morsel-splittable probe phase: the sorted-index gather is
            # per-probe-row deterministic against a fixed build index, so
            # the serving scheduler may slice the probe side into
            # per-pool morsels (build side replicated per pool) with
            # bit-identical results. The kernel join's partition-overflow
            # semantics change under row slicing, so only the sorted
            # strategy is markable; small probes stay whole-plan (the
            # per-morsel dispatch overhead loses below the fitted
            # morsel_split_rows crossover).
            split = (strategy == "sorted"
                     and probe.rows >= self.profile.morsel_split_rows)
            return PH.PJoin(probe, build, node.probe_key, node.build_key,
                            node.take, strategy, None,
                            rows=probe.rows, est=probe.est,
                            morsel_split=split)
        n_probe, n_build = probe.rows * self.n, build.rows * self.n
        if self.observed is not None:
            obs = self.observed(node.probe_key, node.build_key)
            if obs is not None:
                # re-plan: price the join from the alive rows execution
                # actually saw (filter selectivity, padding occupancy)
                # instead of the static physical buffer sizes
                n_probe, n_build = obs
        choice = choose_dist_join(n_probe, n_build,
                                  self.n, self.ctx, self.profile)
        if choice == "broadcast":
            b = PH.Exchange(build, "broadcast", rows=build.rows * self.n,
                            est=build.est * self.n,
                            moved_rows=build.rows * (self.n - 1))
            return PH.PJoin(probe, b, node.probe_key, node.build_key,
                            node.take, "sorted", "broadcast",
                            rows=probe.rows, est=probe.est)
        p_in = self._routed(probe, node.probe_key)
        b_in = self._routed(build, node.build_key)
        return PH.PJoin(p_in, b_in, node.probe_key, node.build_key,
                        node.take, "sorted", "partitioned",
                        rows=p_in.rows, est=probe.est)

    def _routed(self, side: PH.PNode, key: str) -> PH.PNode:
        """One partitioned-join side: route-once elision, else
        compact-then-hash-Exchange to the key's owner shards."""
        method = self.ctx.dist_route
        if (self.ctx.route_once
                and PH.placed_key(side) == (key, method)):
            return side              # rule 2: an upstream routing suffices
        side = PH.maybe_compact(
            side, self.margin or 0.0, self.margin is not None,
            self.profile.filter_selectivity
            ** PH.filters_below(side))                         # rule 3
        cap = routing_capacity(side.rows, self.n, self.ctx.capacity_factor)
        sel = self.profile.filter_selectivity ** PH.filters_below(side)
        return PH.Exchange(side, "hash", key=key, capacity=cap,
                           method=method, rows=self.n * cap, est=side.est,
                           moved_rows=int(side.est * sel)
                           * (self.n - 1) // self.n,
                           impl=choose_exchange_impl(side.rows, self.ctx,
                                                     self.profile))

    # -- aggregates ---------------------------------------------------------
    def _aggregate(self, node: L.Aggregate) -> PH.PAggregate:
        child = self.node(node.child)
        if node.key is None:
            merge = "scalar" if self.distributed else None
            return PH.PAggregate(child, None, 1, node.aggs, "xla", merge,
                                 None, rows=1, est=1)
        G = self.groups(node.n_groups)
        C = stacked_width(node.aggs)
        has_med = any(is_holistic(op) for _, (op, _c) in node.aggs)
        if not self.distributed:
            layout = choose_aggregate(child.rows, G, C, self.ctx.executor,
                                      self.profile)
            return PH.PAggregate(child, node.key, G, node.aggs, layout,
                                 None, None, rows=G, est=G)
        policy = self.ctx.policy or PlacementPolicy.FIRST_TOUCH
        if not has_med:
            med = None
        elif self.ctx.route_once and PH.routes_once(child, node.key):
            # rows already co-located by the group key (route-once): the
            # order statistic selects on the owner shard directly and the
            # merge is an owner-masked psum — O(G) wire rows instead of
            # re-routing O(N) records through a fresh Exchange
            med = "placed"
        else:
            med = ("route" if policy == PlacementPolicy.INTERLEAVE
                   else "replicate")
        dist_aggs = tuple((nm, oc) for nm, oc in node.aggs
                          if not is_holistic(oc[0]))
        if not dist_aggs:
            # holistic-only: counts come from the selection path, no
            # stacked-sums merge at all
            return PH.PAggregate(child, node.key, G, node.aggs, "xla",
                                 "holistic", med, rows=G, est=G)
        if policy in (PlacementPolicy.FIRST_TOUCH,
                      PlacementPolicy.LOCAL_ALLOC):
            layout = self._occupancy_safe(child, choose_aggregate(
                child.rows, G, C, self.ctx.executor, self.profile))
            partial = PH.PPartialAggregate(child, node.key, G, dist_aggs,
                                           layout, rows=G, est=G)
            # the merge collective is a first-class Exchange node, so
            # explain() prices EVERY policy's wire volume on the same
            # axis (pushdown already had one): FT's psum is a ring
            # allreduce over the (G, C) partial tables (reduce-scatter +
            # all-gather, ~2 G (n-1)/n partial rows on the wire), LA's
            # reduce_scatter is the first half only. Both execute FUSED
            # in PAggregate (merge_partial_table), like "gather".
            if policy == PlacementPolicy.FIRST_TOUCH:
                merge, kind = "psum", "allreduce"
                moved = 2 * G * (self.n - 1) // self.n
            else:
                merge, kind = "reduce_scatter", "reduce_scatter"
                moved = G * (self.n - 1) // self.n
            ex = PH.Exchange(partial, kind, rows=G, est=G,
                             moved_rows=moved)
            return PH.PAggregate(ex, node.key, G, node.aggs, layout,
                                 merge, med, rows=G, est=G)
        if policy == PlacementPolicy.PREFERRED:
            ex = PH.Exchange(child, "gather", rows=child.rows * self.n,
                             est=child.est * self.n,
                             moved_rows=child.rows * (self.n - 1))
            layout = self._occupancy_safe(child, choose_aggregate(
                child.rows * self.n, G, C, self.ctx.executor,
                self.profile))
            return PH.PAggregate(ex, node.key, G, node.aggs, layout,
                                 "gather", med, rows=G, est=G)
        return self._interleave_aggregate(node, child, G, C, dist_aggs, med)

    def _interleave_aggregate(self, node, child, G, C, dist_aggs, med):
        """INTERLEAVE grouped aggregation: route-once elision, push-down,
        or the record-routing Exchange — in that preference order."""
        ctx = self.ctx
        if ctx.route_once and PH.routes_once(child, node.key):
            # rule 2: rows already co-located by the group key — each
            # group's table is complete on one shard, merge is a psum of
            # disjoint tables. Records route ONE time, join + aggregate.
            layout = self._occupancy_safe(child, choose_aggregate(
                child.rows, G, C, ctx.executor, self.profile))
            return PH.PAggregate(child, node.key, G, node.aggs, layout,
                                 "placed", med, rows=G, est=G)
        # the push-down crossover is priced on the estimated ALIVE input
        # (est discounted by the telemetry-refreshed filter selectivity
        # per stacked filter), not the physical buffer rows: a heavily
        # filtered input ships fewer records than its buffer suggests,
        # which moves the G-vs-records crossover
        alive = max(int(child.est
                        * self.profile.filter_selectivity
                        ** PH.filters_below(child)), 1)
        pushdown = (ctx.agg_pushdown is True
                    or (ctx.agg_pushdown is None
                        and PH.pushdown_profitable(G, alive)))
        if pushdown:
            # rule 1: partial-aggregate below the exchange, ship ~G
            # partial rows instead of the records
            layout = self._occupancy_safe(child, choose_aggregate(
                child.rows, G, C, ctx.executor, self.profile))
            partial = PH.PPartialAggregate(child, node.key, G, dist_aggs,
                                           layout, rows=G, est=G)
            cap = routing_capacity(G, self.n, ctx.capacity_factor)
            ex = PH.Exchange(partial, "hash", key=None, capacity=cap,
                             rows=self.n * cap, est=G,
                             moved_rows=G * (self.n - 1) // self.n)
            return PH.PAggregate(ex, node.key, G, node.aggs, layout,
                                 "pushdown", med, rows=G, est=G)
        # record routing: the classic INTERLEAVE all-to-all of the data
        rchild = PH.maybe_compact(child, self.margin or 0.0,
                                  self.margin is not None,
                                  self.profile.filter_selectivity
                                  ** PH.filters_below(child))
        cap = routing_capacity(rchild.rows, self.n, ctx.capacity_factor)
        sel = self.profile.filter_selectivity ** PH.filters_below(rchild)
        ex = PH.Exchange(rchild, "hash", key=node.key, capacity=cap,
                         method="modulo", rows=self.n * cap, est=rchild.est,
                         moved_rows=int(rchild.est * sel)
                         * (self.n - 1) // self.n,
                         impl=choose_exchange_impl(rchild.rows, self.ctx,
                                                   self.profile))
        n_slots = (G + (-G % self.n)) // self.n
        layout = choose_aggregate(self.n * cap, n_slots + 1, C,
                                  ctx.executor, self.profile)
        if layout == "partitioned":
            # the routed buffer masses its padding on one drop slot; the
            # partitioned layout's capacity accounting counts those rows,
            # so fall back to the occupancy-independent segment ops
            layout = "xla"
        return PH.PAggregate(ex, node.key, G, node.aggs, layout, "owner",
                             med, rows=G, est=G)

    def _occupancy_safe(self, child: PH.PNode, layout: str) -> str:
        """Range-partitioned layouts size per-partition capacity from row
        COUNTS — on a routed buffer the padding would eat it (phantom
        overflow, dropped records), so fall back to segment ops there."""
        if layout == "partitioned" and PH.has_routed_buffer(child):
            return "xla"
        return layout


# ---------------------------------------------------------------------------
# physical execution: thin walkers over the physical IR
# ---------------------------------------------------------------------------
class _LocalExecutor:
    """Single-device walker over a physical plan (trace-time recursion).

    Memoization is by NODE STRUCTURE (physical nodes are frozen
    dataclasses), so structurally identical subtrees — including
    deduplicated Exchanges — execute exactly once."""

    def __init__(self, tables, ctx: ExecutionContext, indexes,
                 profile: Optional[CostProfile] = None,
                 record: bool = False):
        self.tables = tables
        self.ctx = ctx
        self.indexes = indexes           # {"table.column": (order, sk)}
        self.profile = profile
        # fitted partitioned-layout capacity (profile) falls back to ctx
        self.agg_cf = ((profile.partition_capacity_factor
                        if profile is not None else None)
                       or ctx.capacity_factor)
        self.overflow = jnp.zeros((), jnp.int32)
        self._memo: Dict[PH.PNode, object] = {}
        # telemetry: traced per-node counters, keyed by walk_unique id.
        # record=False adds ZERO traced ops — every recording site is
        # behind `if self.record`.
        self.record = record
        self.stats: Dict[int, Dict[str, jax.Array]] = {}
        self._ids: Dict[PH.PNode, int] = {}

    def run(self, node: PH.PNode):
        hit = self._memo.get(node)
        if hit is None:
            hit = self._eval(node)
            self._memo[node] = hit
        return hit

    def _note(self, node: PH.PNode, **vals) -> None:
        """Stash one node's observed counters (traced int32 scalars).
        Memoized subtrees note once — exactly like they execute once."""
        i = self._ids.get(node)
        if i is not None:
            self.stats[i] = {k: jnp.asarray(v).astype(jnp.int32)
                             for k, v in vals.items()}

    def _eval(self, node: PH.PNode):
        method = getattr(self, "_" + type(node).__name__.lower())
        return method(node)

    # -- node lowerings -----------------------------------------------------
    def _pscan(self, node: PH.PScan) -> Table:
        cols = dict(self.tables[node.table])
        cache = {}
        for (key, idx) in self.indexes.items():
            t, _, c = key.partition(".")
            if t == node.table and c in cols:
                cache[c] = idx
        return Table(cols, None, cache)

    def _pfilter(self, node: PH.PFilter) -> Table:
        t = self.run(node.child)
        out = t.filter(eval_expr(node.pred, t))
        self._record_filter(node, t, out)
        return out

    def _record_filter(self, node: PH.PFilter, t: Table,
                       out: Table) -> None:
        if self.record:
            # observed selectivity (alive_out / alive_in) is what
            # telemetry.refresh_profile fits filter_selectivity from
            self._note(node, alive_in=(t.weights() > 0).sum(),
                       alive_out=(out.weights() > 0).sum())

    def _pproject(self, node: PH.PProject) -> Table:
        t = self.run(node.child)
        return t.with_columns(**{n: eval_expr(e, t) for n, e in node.cols})

    def _pjoin(self, node: PH.PJoin) -> Table:
        probe = self.run(node.probe)
        build = self.run(node.build)
        if node.strategy == "kernel":
            joined, ovf = pkfk_join_kernel(
                probe, build, node.probe_key, node.build_key,
                dict(node.take), mode=self.ctx.mode,
                n_partitions=self.ctx.n_partitions,
                capacity_factor=self.ctx.capacity_factor)
            self.overflow = self.overflow + ovf
        else:
            joined = pkfk_join(probe, build, node.probe_key,
                               node.build_key, dict(node.take))
        self._record_join(node, probe, build, joined)
        return joined

    def _record_join(self, node: PH.PJoin, probe: Table, build: Table,
                     joined: Table) -> None:
        if self.record:
            self._note(node, out_alive=(joined.weights() > 0).sum())

    def _pattach(self, node: PH.PAttach) -> Table:
        t = self.run(node.child)
        src = self.run(node.source)
        first = src[node.cols[0][1]]
        pos = jnp.clip(t.col(node.key), 0, first.shape[0] - 1)
        return t.with_columns(**{new: src[s][pos] for new, s in node.cols})

    def _ptopk(self, node: PH.PTopK) -> Dict[str, jax.Array]:
        g = self.run(node.child)
        vals, idx = jax.lax.top_k(g[node.col], node.k)
        return {node.col: vals, node.index_name: idx}

    def _exchange(self, node: PH.Exchange):
        raise TypeError("Exchange in a single-device physical plan")

    def _compact(self, node: PH.Compact):
        raise TypeError("Compact in a single-device physical plan")

    def _ppartialaggregate(self, node: PH.PPartialAggregate):
        raise TypeError("PPartialAggregate in a single-device plan")

    def _paggregate(self, node: PH.PAggregate) -> Dict[str, jax.Array]:
        t = self.run(node.child)
        if node.key is None:
            return self._scalar_aggregate(node, t)
        out = self._grouped(node, t)
        self.overflow = self.overflow + out["_overflow"]
        if self.record:
            self._note(node, groups_occupied=(out["_count"] > 0).sum())
        return out

    def _grouped(self, node: PH.PAggregate, t: Table) -> Dict[str, jax.Array]:
        aggs = dict(node.aggs)
        if node.layout == "xla":
            return group_aggregate(t, node.key, node.n_groups, aggs,
                                   executor="xla")
        return group_aggregate(t, node.key, node.n_groups, aggs,
                               executor="kernel", layout=node.layout,
                               mode=self.ctx.mode,
                               n_partitions=self.ctx.n_partitions,
                               capacity_factor=self.agg_cf)

    def _scalar_aggregate(self, node: PH.PAggregate,
                          t: Table) -> Dict[str, jax.Array]:
        w = t.weights()
        cnt = w.sum()[None]
        out: Dict[str, jax.Array] = {}
        for name, (op, col) in node.aggs:
            if op == "count":
                out[name] = cnt
                continue
            v = t.col(col).astype(jnp.float32)
            if op == "sum":
                out[name] = (v * w).sum()[None]
            elif op == "avg":
                out[name] = (v * w).sum()[None] / jnp.maximum(cnt, 1.0)
            elif op == "max":
                out[name] = jnp.where(w > 0, v, -jnp.inf).max()[None]
            elif op == "min":
                out[name] = jnp.where(w > 0, v, jnp.inf).min()[None]
            elif op == "median":
                k = jnp.where(w > 0, 0, -1)
                out[name] = segment_median(k, v, 1)[0]
            elif op == "distinct":
                k = jnp.where(w > 0, 0, -1)
                out[name] = segment_distinct(k, v, 1)[0]
            elif parse_quantile(op) is not None:
                k = jnp.where(w > 0, 0, -1)
                out[name] = segment_quantile(k, v, 1, parse_quantile(op))[0]
            else:
                raise ValueError(f"unknown agg op {op!r}")
        out["_count"] = cnt
        out["_overflow"] = jnp.zeros((), jnp.int32)
        return out

    # -- plan root ----------------------------------------------------------
    def execute(self, phys: PH.PhysicalPlan) -> Dict[str, jax.Array]:
        if self.record:
            # node id = walk_unique enumerate order: deterministic for a
            # fixed tree, shared with the StatsRegistry's accounting
            self._ids = {n: i
                         for i, n in enumerate(PH.walk_unique(phys.root))}
        res = self.run(phys.root)
        if isinstance(res, Table):
            raise TypeError("plan root must be an Aggregate or TopK node")
        out = dict(res)
        out["_overflow"] = self.overflow
        if phys.outputs is not None:
            out = {k: out[k] for k in phys.outputs}
        if self.record:
            # reserved key, attached AFTER output filtering: the stats
            # ride the jit out alongside the results (replicated — every
            # distributed counter is psum'd or computed from replicated
            # tables) and are stripped at dispatch by CompiledPlan
            out["_stats"] = self.stats
        return out


class _DistributedExecutor(_LocalExecutor):
    """Placement-policy walker: runs inside an open shard_map over
    ``ctx.axis``. Tables arrive row-sharded (zero-padded, with a ``_valid``
    weight column folded into each Scan's mask); Exchange nodes execute
    the engine collectives (broadcast all-gathers, hash routes through
    route_table_rows), Compact nodes re-compact routed buffers, and
    PAggregate's ``merge`` field names the per-policy combine. The merged
    group tables (and therefore every post-aggregation node) are
    replicated.

    Two Exchange kinds execute FUSED inside their consuming aggregate
    rather than standalone: "gather" (the stacked (keys, vals) matrix is
    gathered, not the whole table — fewer columns on the wire, and the
    holistic path must see the un-gathered records exactly once) and the
    partial-sums hash exchange of a pushed-down aggregate (the routing and
    owner-merge are one engine primitive, pushdown_group_sums)."""

    def __init__(self, tables, ctx: ExecutionContext, n_shards,
                 profile: Optional[CostProfile] = None,
                 record: bool = False):
        super().__init__(tables, ctx, {}, profile, record)
        self.n = n_shards

    def _alive(self, w) -> jax.Array:
        """GLOBAL alive-row count of a row-sharded weight vector."""
        return jax.lax.psum((w > 0).sum(), self.ctx.axis)

    def _pscan(self, node: PH.PScan) -> Table:
        cols = {c: a for c, a in self.tables[node.table].items()
                if c != "_valid"}
        return Table(cols, self.tables[node.table]["_valid"])

    def _exchange(self, node: PH.Exchange) -> Table:
        if node.kind in ("gather", "allreduce", "reduce_scatter"):
            raise TypeError(f"{node.kind} Exchange executes fused in "
                            f"PAggregate")
        child = self.run(node.child)
        if node.kind == "broadcast":
            if self.record:
                alive = self._alive(child.weights())
                # every alive row lands on the n-1 shards that did not
                # already hold it (the all-gather's wire traffic)
                self._note(node, alive_in=alive,
                           moved=alive * (self.n - 1))
            cols = gather_rows(child.columns, self.ctx.axis)
            mask = (None if child.mask is None
                    else gather_rows(child.mask, self.ctx.axis))
            return Table(cols, mask)
        # hash: all-to-all route the table's rows to their key's owner.
        # Routed padding rows carry weight 0 and key -1, so they can never
        # match a real join key; routing overflow is surfaced through the
        # plan's ``_overflow`` accumulator, never dropped silently.
        keys = child.col(node.key).astype(jnp.int32)
        w0 = child.weights()
        owner = route_owner(keys, w0 > 0, self.n, node.method)
        if node.impl == "radix":
            cols, w, ovf = radix_route_table_rows(
                child.columns, w0, owner, self.n, node.capacity,
                self.ctx.axis, mode=self.ctx.mode)
        else:
            cols, w, ovf = route_table_rows(child.columns, w0, owner,
                                            self.n, node.capacity,
                                            self.ctx.axis)
        ovf_total = jax.lax.psum(ovf, self.ctx.axis).astype(jnp.int32)
        self.overflow = self.overflow + ovf_total
        if self.record:
            # "moved" counts ALIVE rows whose owner is another shard —
            # dead (padding) rows also travel in their round-robin slots,
            # but the estimate prices payload, so the observation does too
            me = jax.lax.axis_index(self.ctx.axis)
            moved = jax.lax.psum(
                ((w0 > 0) & (owner != me)).sum(), self.ctx.axis)
            self._note(node, alive_in=self._alive(w0), moved=moved,
                       alive_out=self._alive(w), overflow=ovf_total)
        return Table(cols, w)

    def _record_filter(self, node: PH.PFilter, t: Table,
                       out: Table) -> None:
        if self.record:
            self._note(node, alive_in=self._alive(t.weights()),
                       alive_out=self._alive(out.weights()))

    def _compact(self, node: PH.Compact) -> Table:
        t = self.run(node.child)
        cols, w, ovf = compact_routed_rows(t.columns, t.weights(),
                                           node.capacity)
        ovf_total = jax.lax.psum(ovf, self.ctx.axis).astype(jnp.int32)
        self.overflow = self.overflow + ovf_total
        if self.record:
            self._note(node, alive_in=self._alive(t.weights()),
                       alive_out=self._alive(w), overflow=ovf_total)
        return Table(cols, w)

    def _record_join(self, node: PH.PJoin, probe: Table, build: Table,
                     joined: Table) -> None:
        if not self.record:
            return
        build_alive = (self._alive(build.weights())
                       if node.dist != "broadcast"
                       # broadcast already gathered the build side: the
                       # local count IS the (replicated) global count
                       else (build.weights() > 0).sum())
        self._note(node, probe_alive=self._alive(probe.weights()),
                   build_alive=build_alive,
                   out_alive=self._alive(joined.weights()))

    def _ptopk(self, node: PH.PTopK) -> Dict[str, jax.Array]:
        if node.dist != "candidates":
            # "replicated": select on the merged (replicated) group table
            # — the inherited single-device lowering is already correct
            return super()._ptopk(node)
        # candidates: the child is a gather Exchange over the aggregate.
        # Each shard owns a contiguous slot range of the group table
        # (ceil(G/n) slots), selects its local top-k with GLOBAL slot
        # indices, and only the (k,) candidate pairs converge. Bit-exact
        # vs the replicated lowering: within a shard lax.top_k breaks
        # ties by ascending index, the shard-major all_gather preserves
        # ascending global index among equal values across shards, and
        # the final lax.top_k over the k*n candidates breaks its ties by
        # candidate position — which is exactly ascending global index.
        ex = node.child
        g = self.run(ex.child)
        vals = g[node.col]
        G = vals.shape[0]
        n, axis = self.n, self.ctx.axis
        slots = (G + (-G % n)) // n
        me = jax.lax.axis_index(axis)
        owned = (jnp.arange(G) // slots) == me
        local_vals, local_idx = jax.lax.top_k(
            jnp.where(owned, vals, -jnp.inf), node.k)
        cand_vals = jax.lax.all_gather(local_vals, axis, tiled=True)
        cand_idx = jax.lax.all_gather(local_idx, axis, tiled=True)
        if self.record:
            # the gather's wire volume: k candidate rows per shard, each
            # landing on the n-1 shards that did not produce it
            self._note(ex, alive_in=node.k * n,
                       moved=node.k * (n - 1) * n)
        top_vals, pos = jax.lax.top_k(cand_vals, node.k)
        return {node.col: top_vals, node.index_name: cand_idx[pos]}

    def _ppartialaggregate(self, node: PH.PPartialAggregate):
        """Local (n_groups, C) stacked partial sums — the below-the-
        exchange half of push-down and of the FT/LA partial-table merges."""
        t = self.run(node.child)
        keys, vals, _src = stacked_columns(t, node.key, node.n_groups,
                                           dict(node.aggs))
        return stacked_group_sums(
            keys, vals, node.n_groups, layout=node.layout,
            mode=self.ctx.mode, n_partitions=self.ctx.n_partitions,
            capacity_factor=self.agg_cf)

    def _table_source(self, node: PH.PNode) -> PH.PNode:
        """The table-producing node under an aggregate's movement/partial
        wrappers — order statistics and holistic medians must see the
        records exactly once, BEFORE any exchange."""
        while isinstance(node, (PH.Exchange, PH.PPartialAggregate)):
            node = node.child
        return node

    def _paggregate(self, node: PH.PAggregate) -> Dict[str, jax.Array]:
        if node.key is None:
            return self._dist_scalar_aggregate(node,
                                               self.run(node.child))
        t = self.run(self._table_source(node.child))
        G = node.n_groups
        dist_aggs = tuple((nm, oc) for nm, oc in node.aggs
                          if not is_holistic(oc[0]))
        med_out, med_counts, med_ovf = self._dist_medians(node, t, G)
        if not dist_aggs:
            # holistic-only aggregate: counts come from the selection path
            # — no second routing/merge pass just for _count
            out = dict(med_out)
            out["_count"] = med_counts
            out["_overflow"] = med_ovf
            self.overflow = self.overflow + med_ovf
            if self.record:
                self._note(node,
                           groups_occupied=(out["_count"] > 0).sum())
            return out
        sums, overflow = self._merged_sums(node, t, G, dist_aggs)
        out = finalize_stacked(dict(dist_aggs), _stacked_src(dist_aggs),
                               sums, self._order_stat_fn(t, node, G))
        out.update(med_out)
        out["_overflow"] = overflow.astype(jnp.int32) + med_ovf
        self.overflow = self.overflow + out["_overflow"]
        if self.record:
            self._note(node, groups_occupied=(out["_count"] > 0).sum())
        return out

    def _merged_sums(self, node: PH.PAggregate, t: Table, G: int,
                     dist_aggs) -> Tuple[jax.Array, jax.Array]:
        """The distributive stacked-sums table under ``node.merge``."""
        axis, n = self.ctx.axis, self.n
        merge = node.merge
        if merge in ("psum", "reduce_scatter"):
            # child is the fused allreduce/reduce_scatter Exchange (the
            # priced movement node); the partial table comes from BELOW it
            partial, ovf = self.run(node.child.child)
            policy = (PlacementPolicy.FIRST_TOUCH if merge == "psum"
                      else PlacementPolicy.LOCAL_ALLOC)
            return (merge_partial_table(partial, policy, axis, n),
                    jax.lax.psum(ovf, axis))
        if merge == "pushdown":
            partial, ovf = self.run(node.child.child)
            sums, route_ovf = pushdown_group_sums(
                partial, G, axis, n,
                capacity_factor=self.ctx.capacity_factor,
                capacity=node.child.capacity)
            return sums, jax.lax.psum(ovf, axis) + route_ovf
        if merge == "placed":
            # route-once: every group's rows are co-located, so the
            # per-shard tables are DISJOINT and the psum is exact
            keys, vals, _ = stacked_columns(t, node.key, G, dict(dist_aggs))
            sums, ovf = self._stacked(keys, vals, G, node.layout)
            return jax.lax.psum(sums, axis), jax.lax.psum(ovf, axis)
        if merge == "owner":
            keys, vals, _ = stacked_columns(t, node.key, G, dict(dist_aggs))
            agg_fn = functools.partial(self._stacked, layout=node.layout)
            # the Exchange node's capacity drives the routing: execution
            # can never drift from the rendered physical plan
            return interleave_group_sums(
                keys, vals, G, axis, n, agg_fn,
                capacity_factor=self.ctx.capacity_factor,
                capacity=node.child.capacity)
        if merge == "gather":
            keys, vals, _ = stacked_columns(t, node.key, G, dict(dist_aggs))
            ak, av = gather_rows((keys, vals), axis)
            return self._stacked(ak, av, G, node.layout)
        raise ValueError(f"unknown aggregate merge {merge!r}")

    def _stacked(self, keys, vals, n_groups, layout):
        return stacked_group_sums(
            keys, vals, n_groups, layout=layout, mode=self.ctx.mode,
            n_partitions=self.ctx.n_partitions, capacity_factor=self.agg_cf)

    def _order_stat_fn(self, t: Table, node: PH.PAggregate, G: int):
        keys = jnp.clip(t.col(node.key), 0, G - 1).astype(jnp.int32)

        def order_stat(op, col):
            # local segment op, then a cross-shard tree reduction
            local = segment_order_stat(t, keys, G, op, col)
            reduce = jax.lax.pmax if op == "max" else jax.lax.pmin
            return reduce(local, self.ctx.axis)

        return order_stat

    def _dist_medians(self, node: PH.PAggregate, t: Table, G: int
                      ) -> Tuple[Dict[str, jax.Array], Optional[jax.Array],
                                 jax.Array]:
        """Per-policy lowering of an Aggregate's holistic (median/
        quantile) aggs.

        Order statistics cannot merge from partials, so they bypass the
        stacked-sums collectives entirely: ``med_strategy`` "replicate"
        gathers the records (the paper's holistic worst case), "route"
        sends each group's records to its owner and selects there
        (distributed selection). Returns ({name: (G,) stats},
        counts-or-None, overflow), all replicated in natural group
        order."""
        axis, n = self.ctx.axis, self.n
        med_aggs = tuple((nm, oc) for nm, oc in node.aggs
                         if is_holistic(oc[0]))
        if not med_aggs:
            return {}, None, jnp.zeros((), jnp.int32)
        keys = jnp.clip(t.col(node.key), 0, G - 1).astype(jnp.int32)
        w = t.weights()
        cols = {name: t.col(colname).astype(jnp.float32)
                for name, (_op, colname) in med_aggs}
        ranks = {name: holistic_selector(op)
                 for name, (op, _c) in med_aggs}          # None = median
        if node.med_strategy == "route":
            meds, counts, ovf = interleave_group_median(
                keys, cols, w, G, axis, n,
                capacity_factor=self.ctx.capacity_factor, ranks=ranks)
            return meds, counts, ovf.astype(jnp.int32)
        if node.med_strategy == "placed":
            # route-once: the child is already placed by the group key,
            # select on the owner shard and psum the masked results
            meds, counts = placed_group_median(keys, cols, w, G, axis,
                                               ranks=ranks)
            return meds, counts, jnp.zeros((), jnp.int32)
        meds, counts = replicated_group_median(keys, cols, w, G, axis,
                                               ranks=ranks)
        return meds, counts, jnp.zeros((), jnp.int32)

    def _dist_scalar_aggregate(self, node: PH.PAggregate,
                               t: Table) -> Dict[str, jax.Array]:
        """Global aggregate: merge the SUMS across shards (an average of
        per-shard averages would weight shards, not rows)."""
        axis = self.ctx.axis
        w = t.weights()
        cnt = jax.lax.psum(w.sum(), axis)[None]
        out: Dict[str, jax.Array] = {}
        med_cols: Dict[str, jax.Array] = {}
        med_ranks: Dict[str, object] = {}    # holistic_selector values
        for name, (op, col) in node.aggs:
            if op == "count":
                out[name] = cnt
                continue
            v = t.col(col).astype(jnp.float32)
            if op in ("sum", "avg"):
                s = jax.lax.psum((v * w).sum(), axis)[None]
                out[name] = s if op == "sum" else s / jnp.maximum(cnt, 1.0)
            elif op == "max":
                out[name] = jax.lax.pmax(
                    jnp.where(w > 0, v, -jnp.inf).max(), axis)[None]
            elif op == "min":
                out[name] = jax.lax.pmin(
                    jnp.where(w > 0, v, jnp.inf).min(), axis)[None]
            elif is_holistic(op):
                med_cols[name] = v       # batched below: gather rows once
                med_ranks[name] = holistic_selector(op)
            else:
                raise ValueError(f"unknown agg op {op!r}")
        if med_cols:
            # holistic: converge the records ONCE, select per column
            meds, _ = replicated_group_median(
                jnp.zeros_like(w, jnp.int32), med_cols, w, 1, axis,
                ranks=med_ranks)
            out.update(meds)
        out["_count"] = cnt
        out["_overflow"] = jnp.zeros((), jnp.int32)
        return out


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def _signature(tables) -> Tuple:
    return tuple(sorted((t, c, tuple(a.shape), str(a.dtype))
                        for t, cols in tables.items()
                        for c, a in cols.items()))


def table_signature(tables) -> Tuple:
    """Public shape signature of a {table: {column: array}} pytree — the
    axis of the plan-cache key that identifies "structurally identical
    data" (stable across dict rebuilds; the serving batcher groups on
    it)."""
    return _signature(tables)


def cached_executable(key: Tuple, build):
    """Fetch-or-build an executable in the shared bounded plan LRU.

    Public seam for auxiliary executables that must live under the same
    cache bound and thread-safety as compiled plans (e.g. the serving
    scheduler's per-morsel partial-aggregation functions). ``key`` should
    start with a distinguishing tag so it can never collide with
    compile_plan's (plan, ctx, signature, profile) keys."""
    fn = _PLAN_CACHE.get(key)
    if fn is None:
        fn = build()
        _PLAN_CACHE.put(key, fn)
    return fn


def _true_rows(tables) -> Dict[str, int]:
    return {t: next(iter(cols.values())).shape[0]
            for t, cols in tables.items()}


def _run_local(phys: PH.PhysicalPlan, ctx: ExecutionContext, profile,
               record, tables, indexes):
    ex = _LocalExecutor(tables, ctx, indexes, profile, record)
    return ex.execute(phys)


def _run_distributed(phys: PH.PhysicalPlan, ctx: ExecutionContext, profile,
                     record, tables, indexes):
    del indexes          # full-table indexes don't survive the row padding
    mesh, axis = ctx.mesh, ctx.axis
    n = mesh.shape[axis]
    rows = _true_rows(tables)
    padded = {}
    for t, cols in tables.items():
        r = rows[t]
        pad = -r % n
        pcols = {c: jnp.pad(jnp.asarray(a), [(0, pad)] + [(0, 0)]
                            * (jnp.asarray(a).ndim - 1))
                 for c, a in cols.items()}
        pcols["_valid"] = (jnp.arange(r + pad) < r).astype(jnp.float32)
        padded[t] = pcols

    def local_fn(local_tables):
        ex = _DistributedExecutor(local_tables, ctx, n, profile, record)
        return ex.execute(phys)

    specs = jax.tree_util.tree_map(lambda _: P(axis), padded)
    return shard_map(local_fn, mesh=mesh, in_specs=(specs,), out_specs=P(),
                     check_rep=False)(padded)


def _run_plan(phys: PH.PhysicalPlan, ctx: ExecutionContext, profile,
              record, tables, indexes):
    if ctx.mesh is None:
        return _run_local(phys, ctx, profile, record, tables, indexes)
    return _run_distributed(phys, ctx, profile, record, tables, indexes)


class CompiledPlan:
    """Re-entrant dispatch handle for one (plan, context, shape signature).

    ``compile_plan`` resolves the plan-cache entry ONCE; the handle can then
    be called from any worker thread without touching the planner again —
    only the join-index pool is consulted per call (a lock-protected LRU
    hit), so concurrent dispatch never re-plans, re-jits, or races an
    eviction. This is the entry point the serving scheduler pins into its
    worker pools. ``physical`` is the explicit physical plan the
    executable walks — the plan-cache value, inspectable per handle.

    When compiled under telemetry (``record``), each call strips the
    reserved ``"_stats"`` output, materializes it (one device_get — the
    price of observing), and folds it into the StatsRegistry under
    ``cache_key`` together with the dispatch wall time. Every dispatch
    path — serial execute_plan, the serving scheduler's whole-plan morsel
    tasks — goes through this one __call__, so the registry sees them
    all."""

    __slots__ = ("plan", "ctx", "fn", "index_specs", "physical",
                 "cache_key", "record")

    def __init__(self, plan: L.LogicalPlan, ctx: ExecutionContext, fn,
                 index_specs: Tuple[Tuple[str, str], ...],
                 physical: PH.PhysicalPlan, cache_key: Tuple = (),
                 record: bool = False):
        self.plan = plan
        self.ctx = ctx
        self.fn = fn
        self.index_specs = index_specs
        self.physical = physical
        self.cache_key = cache_key
        self.record = record

    def __call__(self, tables) -> Dict[str, jax.Array]:
        # the tracing flag is read HERE, per dispatch — it is deliberately
        # NOT part of the plan-cache key: plan.execute is a host-side span
        # around an unchanged executable, so flipping it must never re-jit
        # (only telemetry's ``record`` adds traced operations)
        if not tracing.tracing_enabled():
            return self._execute(tables)
        t0 = time.monotonic()
        out = self._execute(tables)
        tracing.tracer().add_complete(
            "plan.execute", "plan", t0, time.monotonic(), pid="plan",
            key=hash(self.cache_key), recorded=self.record)
        return out

    def _execute(self, tables) -> Dict[str, jax.Array]:
        indexes = {}
        if self.ctx.mesh is None:
            for t, c in self.index_specs:
                indexes[f"{t}.{c}"] = _INDEX_POOL.get(t, c, tables[t][c])
        if not self.record:
            return self.fn(tables, indexes)
        t0 = time.perf_counter()
        out = dict(self.fn(tables, indexes))
        stats = out.pop("_stats", None)
        if stats is not None:
            concrete = {int(i): {k: int(v) for k, v in
                                 jax.device_get(vals).items()}
                        for i, vals in stats.items()}
            telemetry.registry().record(self.cache_key, self.physical,
                                        concrete,
                                        time.perf_counter() - t0)
        return out


def compile_plan(plan: L.LogicalPlan, tables,
                 ctx: Optional[ExecutionContext] = None) -> CompiledPlan:
    """Lower to a physical plan and resolve (or build) its executable.

    ``tables`` supplies only the shape signature — the returned handle runs
    on ANY tables pytree of the same shapes. The active CostProfile is
    snapshotted ONCE: it keys the cache AND parameterizes the lowering, so
    a concurrent recalibration can never plan under the new constants but
    cache under the old key. The cache VALUE is the (physical plan, jitted
    executable) pair — the physical tree is the product, the jit its
    interpretation."""
    ctx = ctx or ExecutionContext()
    profile = current_cost_profile()
    record = telemetry.telemetry_enabled()
    # the telemetry flag keys the cache: a recording jit carries extra
    # traced outputs, so it can never be served to an untracked caller
    key = (plan, ctx.cache_key(), _signature(tables), profile, record)
    entry = _PLAN_CACHE.get(key)
    if entry is None:
        traced = tracing.tracing_enabled()
        t0 = time.monotonic() if traced else 0.0
        L.validate(plan)     # fail fast (and once) instead of mid-trace
        phys = lower(plan, ctx, _true_rows(tables), profile)
        fn = jax.jit(functools.partial(_run_plan, phys, ctx, profile,
                                       record))
        entry = (phys, fn)
        _PLAN_CACHE.put(key, entry)
        if traced:
            # compile vs execute split per plan-cache key: this span is
            # the lowering + jit construction a cache hit amortizes away
            tracing.tracer().add_complete(
                "plan.compile", "plan", t0, time.monotonic(), pid="plan",
                key=hash(key))
    elif record:
        entry = _maybe_replan(key, entry, plan, ctx, profile, tables)
    phys, fn = entry
    return CompiledPlan(plan, ctx, fn, required_indexes(plan.root), phys,
                        key, record)


def _maybe_replan(key, entry, plan, ctx, profile, tables):
    """Adaptive re-planning on a plan-cache HIT: when the registry marked
    this plan as drifting, re-lower with the OBSERVED per-join alive rows
    and swap the cache entry if any Decision flipped. Results stay
    bit-identical — the observed hook only steers the broadcast-vs-
    partitioned cost choice, never the relational answer — and a
    re-lowering whose decisions all stand produces a structurally
    identical tree, so the existing jit keeps serving."""
    reg = telemetry.registry()
    if not reg.should_replan(key):
        return entry
    reg.note_replan_checked(key)
    phys = lower(plan, ctx, _true_rows(tables), profile,
                 observed=reg.observed_joins(key))
    if phys == entry[0]:
        return entry
    fn = jax.jit(functools.partial(_run_plan, phys, ctx, profile, True))
    entry = (phys, fn)
    _PLAN_CACHE.put(key, entry)
    reg.note_replanned(key, phys)
    return entry


def execute_plan(plan: L.LogicalPlan, tables,
                 ctx: Optional[ExecutionContext] = None
                 ) -> Dict[str, jax.Array]:
    """Compile (through the LRU plan cache) and run a logical plan.

    ``tables``: {table: {column: array}} pytree, passed to the compiled
    plan as traced arguments — one compilation serves any data of the same
    shape signature. Build-side join indexes are pulled from the
    JoinIndexPool and traced in alongside."""
    return compile_plan(plan, tables, ctx)(tables)


# ---------------------------------------------------------------------------
# explain: decisions + physical-tree rendering
# ---------------------------------------------------------------------------
def _strip_movement(node: PH.PNode) -> PH.PNode:
    """The record-producing node under movement/partial wrappers — what
    explain() reports row counts from (a split aggregate's input is its
    records, not its (n_groups, C) partial table)."""
    while isinstance(node, (PH.Exchange, PH.Compact,
                            PH.PPartialAggregate)):
        node = node.child
    return node


def explain(plan: L.LogicalPlan, tables,
            ctx: Optional[ExecutionContext] = None) -> List[Decision]:
    """The planner's choices from shape metadata alone (no execution):
    one Decision per Join / grouped Aggregate — plus, since the physical
    layer, per Exchange (kind + estimated moved rows) and per Compact —
    in plan order. Decisions are derived from the SAME lower() pass that
    produces the executed physical plan, so explain can never drift from
    execution."""
    ctx = ctx or ExecutionContext()
    phys = lower(plan, ctx, _true_rows(tables))
    n = phys.n_shards
    decisions: List[Decision] = []
    seen = set()

    def visit(node: PH.PNode) -> None:
        if node in seen:         # structural dedup == executor memoization
            return
        seen.add(node)
        for c in PH.children(node):
            visit(c)
        if isinstance(node, PH.PJoin):
            probe = _strip_movement(node.probe)
            build = _strip_movement(node.build)
            if node.dist is not None:
                decisions.append(Decision(
                    "DistJoin", f"{node.probe_key}={node.build_key}, "
                    f"probe={probe.rows * n}, build={build.rows * n}, "
                    f"shards={n}", node.dist,
                    tuple(dist_join_costs(probe.rows * n, build.rows * n,
                                          n).items())))
            else:
                decisions.append(Decision(
                    "Join", f"{node.probe_key}={node.build_key}, "
                    f"probe={probe.rows}, build={build.rows}",
                    node.strategy))
        elif isinstance(node, PH.Exchange):
            # key=None marks a partial-sums routing ONLY for hash
            # exchanges; broadcast/gather move whole tables and carry no
            # routing key at all
            if node.key is not None:
                detail = f"kind={node.kind}, key={node.key}"
                # key-routing hash exchange: the layout-pass impl is a
                # planner choice, priced alongside the wire estimate
                # (moved_rows stays FIRST — consumers index costs[0])
                costs = ((("moved_rows", float(node.moved_rows)),)
                         + tuple(exchange_costs(node.child.rows).items()))
                decisions.append(Decision(
                    "Exchange", f"{detail}, rows={node.rows}",
                    f"{node.kind}/{node.impl}", costs))
                return
            if node.kind == "hash":
                detail = f"kind={node.kind}, key=<group-partials>"
            else:
                detail = f"kind={node.kind}"
            decisions.append(Decision(
                "Exchange", f"{detail}, rows={node.rows}", node.kind,
                (("moved_rows", float(node.moved_rows)),)))
        elif isinstance(node, PH.PFilter) and node.pushed:
            decisions.append(Decision(
                "FilterBelowExchange", L.expr_str(node.pred),
                "pushed"))
        elif isinstance(node, PH.PTopK) and node.dist is not None:
            G = _strip_movement(node.child).rows
            decisions.append(Decision(
                "DistTopK", f"col={node.col}, k={node.k}, groups={G}, "
                f"shards={n}", node.dist,
                tuple(topk_costs(G, node.k, n).items())))
        elif isinstance(node, PH.Compact):
            decisions.append(Decision(
                "Compact", f"capacity={node.capacity}, "
                f"from={node.child.rows}", "compact",
                (("rows_cut", float(node.child.rows - node.capacity)),)))
        elif isinstance(node, PH.PAggregate) and node.key is not None:
            N = _strip_movement(node.child).rows
            C = stacked_width(node.aggs)
            G = node.n_groups
            # cost basis = the inputs the layout was actually CHOSEN from
            # (lower's per-merge arithmetic), so the printed table can
            # justify the printed choice: owner-merge aggregates run on
            # the routed buffer over per-shard slots, gather-merge on the
            # converged rows, everything else on the record input
            if node.merge == "owner" and isinstance(node.child, PH.Exchange):
                cost_n = node.child.rows
                cost_g = (G + (-G % n)) // n + 1
            elif node.merge == "gather":
                cost_n, cost_g = N * n, G
            else:
                cost_n, cost_g = N, G
            detail = f"key={node.key}, rows={N}, groups={G}, cols={C}"
            if node.merge is not None:
                detail += f", merge={node.merge}"
            decisions.append(Decision(
                "Aggregate", detail, node.layout,
                tuple(aggregate_costs(cost_n, cost_g, C).items())))

    visit(phys.root)
    return decisions


def explain_physical(plan: L.LogicalPlan, tables,
                     ctx: Optional[ExecutionContext] = None,
                     n_shards: Optional[int] = None) -> str:
    """Render the lowered physical tree (physical.describe): Exchange
    kinds with estimated moved rows, compaction points, resolved join/
    aggregate strategies. Deterministic for fixed table shapes — the
    golden-snapshot format. ``n_shards`` lowers for a mesh width without
    materializing devices."""
    ctx = ctx or ExecutionContext()
    return PH.describe(lower(plan, ctx, _true_rows(tables),
                             n_shards=n_shards))


# explain_analyze — the executable twin of explain_physical (runs the
# plan under telemetry and annotates the tree with observed rows) —
# lives in repro.analytics.telemetry; re-exported here for symmetry.
explain_analyze = telemetry.explain_analyze
