"""Deterministic fault injection for the serving tier.

The serving analog of ``runtime/ft.py``'s FailureInjector: every fault a
production deployment sees — a dispatch whose build raises, a task whose
``wait()`` poisons, a worker pool that dies mid-round, a pool that
straggles — is injectable on a fixed schedule (dispatch ordinals) or at a
seeded rate, so the recovery machinery (retry/backoff, pool quarantine,
morsel requeue, priority shedding) is exercised by tests and benchmarks
instead of only documented.

Determinism contract: the injector consumes its RNG exactly once per
fault axis per dispatch ordinal, under a lock, in dispatch order — the
same seed and the same submission sequence replay the same fault
schedule regardless of worker-thread timing. The hooks live behind a
single ``if faults is not None`` check in the scheduler, so production
pays zero cost when disabled.
"""
from __future__ import annotations

import threading
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.analytics import tracing


class InjectedServiceFault(RuntimeError):
    """Raised by ServiceFaultInjector hooks (build fail / wait poison)."""


class ServiceFaultInjector:
    """Seeded, schedule- or rate-driven faults for the serving tier.

    Schedules are DISPATCH ORDINALS: the scheduler ticks one ordinal per
    ``build_task`` call (retries re-tick — a dispatch that fails at
    ordinal k retries as ordinal k+1, so a transient fault is
    ``build_fail_at={k}`` and a persistent one covers every attempt).

      build_fail_at    ordinals whose build raises InjectedServiceFault
      poison_wait_at   ordinals whose task's wait() raises (the first
                       morsel of that dispatch raises inside the worker)
      kill_pool_at     (ordinal, pool_id): kill that worker pool right
                       after the ordinal's task is enqueued — mid-round
      straggle_pool    (pool_id, seconds): delay every morsel that pool
                       executes (the Fig 3 slow-socket analog)
      build_fail_rate / poison_rate
                       seeded Bernoulli per ordinal (chaos storms)
    """

    def __init__(self, seed: int = 0,
                 build_fail_at: Sequence[int] = (),
                 poison_wait_at: Sequence[int] = (),
                 kill_pool_at: Optional[Tuple[int, int]] = None,
                 straggle_pool: Optional[Tuple[int, float]] = None,
                 build_fail_rate: float = 0.0,
                 poison_rate: float = 0.0):
        self.seed = seed
        self.build_fail_at = frozenset(build_fail_at)
        self.poison_wait_at = frozenset(poison_wait_at)
        self.kill_pool_at = kill_pool_at
        self.straggle_pool = straggle_pool
        self.build_fail_rate = build_fail_rate
        self.poison_rate = poison_rate
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self._ordinal = 0
        self._poison_pending: set = set()
        self._kill_fired = False
        # observability: what actually fired (asserted by the chaos grid)
        self.builds_failed = 0
        self.waits_poisoned = 0
        self.pools_killed = 0

    def begin_dispatch(self) -> int:
        """Tick one dispatch ordinal; raise to fail this dispatch's build.

        Both rate draws happen unconditionally so the RNG stream depends
        only on the ordinal sequence, never on which faults fired."""
        with self._lock:
            o = self._ordinal
            self._ordinal += 1
            draw_build, draw_poison = self._rng.random(2)
            fail_build = (o in self.build_fail_at
                          or draw_build < self.build_fail_rate)
            if (o in self.poison_wait_at
                    or draw_poison < self.poison_rate):
                self._poison_pending.add(o)
            if fail_build:
                self.builds_failed += 1
                if tracing.tracing_enabled():
                    # flight recorder: every injected fault must leave a
                    # postmortem artifact (the chaos grid asserts it)
                    tracing.tracer().flight_dump(
                        "fault.build_fail", ordinal=o)
                raise InjectedServiceFault(
                    f"injected build failure at dispatch {o}")
            return o

    def on_submit(self, ordinal: int, task, scheduler) -> None:
        """Called by the scheduler after the ordinal's task is enqueued."""
        with self._lock:
            poison = ordinal in self._poison_pending
            self._poison_pending.discard(ordinal)
            kill = (self.kill_pool_at is not None and not self._kill_fired
                    and ordinal >= self.kill_pool_at[0])
            if kill:
                self._kill_fired = True
            if poison:
                self.waits_poisoned += 1
        if poison:
            if tracing.tracing_enabled():
                tracing.tracer().flight_dump(
                    "fault.wait_poison", ordinal=ordinal,
                    trace_id=task.trace_id)
            task.poison(InjectedServiceFault(
                f"injected wait poison at dispatch {ordinal}"))
        if kill:
            with self._lock:
                self.pools_killed += 1
            if tracing.tracing_enabled():
                tracing.tracer().flight_dump(
                    "fault.pool_kill", ordinal=ordinal,
                    pool=self.kill_pool_at[1])
            scheduler.kill_pool(self.kill_pool_at[1])

    def morsel_delay(self, pool_id: int) -> float:
        """Seconds a worker in ``pool_id`` sleeps before each morsel."""
        if self.straggle_pool is not None and pool_id == self.straggle_pool[0]:
            return self.straggle_pool[1]
        return 0.0
