"""Morsel-driven scheduler: socket-pinned worker pools + work stealing.

The execution analog of the paper's thread-placement axis (Figs 3/4):

  * A **WorkerPool** is the NUMA-socket analog — it owns a CONTIGUOUS
    slice of the device mesh (shard range) and a small set of worker
    threads pinned to it. On the single-controller JAX runtime the
    pinning is an affinity *model* (which pool's threads dispatch which
    work, and which shard slice that work is accounted against); on a
    real multi-host deployment the pool maps 1:1 to a host's devices.
  * A **morsel** is a contiguous row range of a scan (engine.morsel_slices)
    — the work unit that makes load balancing possible at all. Plans
    whose root is a distributive Aggregate over a Scan/Filter/Project
    chain are split into per-morsel partial aggregations merged in morsel
    order (engine.merge_morsel_partials — deterministic under stealing).
    Join-probe pipelines the planner marked ``morsel_split`` take the
    SPLIT-PROBE path (_probe_split_decompose): the build sides run once
    per task, each worker pool probes against its OWN replica of the
    pooled build index (JoinIndexPool.replica — the paper's socket-local
    working set, built once per pool, never per morsel), and the
    per-morsel intermediate tables concatenate in morsel order so the
    served result stays bit-identical to serial execution. Everything
    else (kernel joins, distributed contexts, sub-threshold probes)
    executes as one whole-plan morsel through the planner's CompiledPlan
    handle, which is bit-identical to a serial ``run_query`` by
    construction.
  * **ThreadPlacement** mirrors benchmarks/fig3_fig4_thread_placement.py:
    OS_DEFAULT round-robins morsels over pools in arrival order (the
    topology-oblivious baseline), DENSE packs a query's morsels onto one
    pool (contiguous shards, minimal cross-pool traffic), SPARSE stripes
    them across every pool (maximal aggregate bandwidth).
  * **Work stealing** is the AutoNUMA / kernel-load-balancing analog: an
    idle pool steals from the longest backlog; every steal is counted
    per pool and surfaced in SchedulerStats.
  * **Fault tolerance** ports runtime/ft.py's idiom to serving: workers
    stamp per-pool heartbeats and EWMA morsel-service times; a pool that
    dies (``kill_pool``, the drill analog of a lost host) or straggles
    past ``straggler_threshold`` x the fleet-median EWMA is QUARANTINED —
    its queued morsels are requeued onto surviving pools (counted in
    ``requeued``) and new dispatches avoid it, so the service keeps
    serving on a shrunk pool set. Results stay deterministic because
    whole-plan dispatch is idempotent and morsel partials merge in morsel
    order regardless of which pool ran them. All fault hooks sit behind
    one ``if self.faults is not None`` check — zero cost when disabled.
"""
from __future__ import annotations

import enum
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analytics import plan as L
from repro.analytics import planner
from repro.analytics import tracing
from repro.analytics.columnar import Table, finalize_stacked, stacked_columns
from repro.analytics.engine import (merge_morsel_partials, morsel_group_sums,
                                    morsel_slice_columns, morsel_slices)
from repro.analytics.planner import ExecutionContext


class ThreadPlacement(enum.Enum):
    """Pool-to-work affinity strategies (the Fig 3/4 axis).

    OS_DEFAULT  arrival-order round-robin, no affinity (the "OS free to
                migrate" baseline — MeshLayout.NONE's serving analog).
    DENSE       a query's morsels packed onto ONE pool: contiguous shard
                slice, minimal cross-pool hops (Fig 4's dense pinning).
    SPARSE      a query's morsels striped across ALL pools: maximal
                aggregate bandwidth per query (Fig 3/4's sparse pinning).
    """

    OS_DEFAULT = "os_default"
    DENSE = "dense"
    SPARSE = "sparse"


# Multi-device (mesh-context) computations must be dispatched by one
# thread at a time: concurrent shard_map dispatch from worker threads can
# interleave per-device enqueue order (A before B on dev0, B before A on
# dev1) and deadlock the collectives. A distributed plan owns the WHOLE
# mesh anyway — serializing its dispatch loses no parallelism; pools keep
# overlapping single-device work freely.
_MESH_DISPATCH_LOCK = threading.Lock()


@dataclass
class _Morsel:
    task: "QueryTask"
    seq: int                      # position in the task's morsel order
    lo: int
    length: int
    home_pool: int = -1           # assigned pool (stamped at dispatch)


class QueryTask:
    """One dispatch: a whole plan or a set of morsel partial-aggregations.

    ``wait()`` blocks until every morsel completed and the merged result
    is available. Exceptions raised by any morsel are captured and
    re-raised to the waiter."""

    def __init__(self, compiled: Optional[planner.CompiledPlan], tables,
                 morsel_fn: Optional[Callable] = None,
                 finalize: Optional[Callable] = None,
                 morsels: Optional[List[Tuple[int, int]]] = None):
        self.compiled = compiled            # None iff morsel-decomposed
        self.tables = tables
        self.morsel_fn = morsel_fn          # (tables, lo, length) -> partial
        self.finalize = finalize            # (sums, overflow) -> result dict
        self._partials: Dict[int, Any] = {}
        self._lock = threading.Lock()
        self._done = threading.Event()
        self._error: Optional[BaseException] = None
        self._poison: Optional[BaseException] = None
        self.fault_ordinal: Optional[int] = None
        self.result: Optional[Dict[str, jax.Array]] = None
        self.submit_t: float = 0.0          # scheduler.submit stamp
        self.merge_t: float = 0.0           # last morsel done, merge begins
        self.done_t: float = 0.0            # completion stamp (monotonic)
        self.trace_id: int = -1             # owning request id (service)
        if morsel_fn is None:
            self.morsels = [_Morsel(self, 0, 0, 0)]
        else:
            self.morsels = [_Morsel(self, i, lo, hi - lo)
                            for i, (lo, hi) in enumerate(morsels)]
        self._pending = len(self.morsels)

    def poison(self, error: BaseException) -> None:
        """Fault-injection hook: the next morsel to run raises ``error``,
        so every ``wait()`` on this task raises (a deterministic stand-in
        for a dispatch that dies inside the executor)."""
        with self._lock:
            self._poison = error

    @property
    def split(self) -> bool:
        return self.morsel_fn is not None

    @property
    def physical(self):
        """The explicit physical plan a whole-plan task dispatches (the
        plan-cache value compile_plan resolved); None for morsel-split
        tasks, whose unit is the per-morsel partial executable."""
        return None if self.compiled is None else self.compiled.physical

    def _run_morsel(self, m: _Morsel, pool_id: int = 0) -> None:
        try:
            with self._lock:
                if self._poison is not None:
                    raise self._poison
            if self.morsel_fn is None:
                if self.compiled.ctx.mesh is not None:
                    with _MESH_DISPATCH_LOCK:
                        out = jax.block_until_ready(
                            self.compiled(self.tables))
                else:
                    out = jax.block_until_ready(self.compiled(self.tables))
                with self._lock:
                    self.result = out
            else:
                # the EXECUTING pool's id, not home_pool: a stolen morsel
                # must probe against the thief's build replica
                part = jax.block_until_ready(
                    self.morsel_fn(self.tables, m.lo, length=m.length,
                                   pool=pool_id))
                with self._lock:
                    self._partials[m.seq] = part
        except BaseException as e:  # noqa: BLE001 — surfaced to waiter
            with self._lock:
                self._error = e
        finally:
            with self._lock:
                self._pending -= 1
                last = self._pending == 0
            if last:
                self._finish()

    def _finish(self) -> None:
        # the merge phase begins when the LAST morsel lands — everything
        # between merge_t and done_t is morsel-order merge + finalize
        self.merge_t = time.monotonic()
        if self._error is None and self.morsel_fn is not None:
            try:
                # merge in MORSEL order, not completion order: the served
                # result must not depend on which pool finished first
                sums, ovf = merge_morsel_partials(
                    [self._partials[i] for i in range(len(self.morsels))])
                self.result = jax.block_until_ready(self.finalize(sums, ovf))
            except BaseException as e:  # noqa: BLE001
                self._error = e
        # stamp completion HERE, not when a waiter gets around to joining:
        # per-query latency must not include time spent waiting on other
        # tasks in the drain loop
        self.done_t = time.monotonic()
        if tracing.tracing_enabled() and self.morsel_fn is not None:
            tracing.tracer().add_complete(
                "merge.partials", "scheduler", self.merge_t, self.done_t,
                trace_id=self.trace_id, n_morsels=len(self.morsels))
        self._done.set()

    def wait(self, timeout: Optional[float] = None) -> Dict[str, jax.Array]:
        if not self._done.wait(timeout):
            raise TimeoutError("query task did not complete in time")
        if self._error is not None:
            raise self._error
        return self.result


@dataclass
class WorkerPool:
    """The NUMA-socket analog: a contiguous shard slice + pinned workers."""

    pool_id: int
    shard_lo: int                 # [shard_lo, shard_hi) of the device mesh
    shard_hi: int
    executed: int = 0             # morsels run by this pool's workers
    steals: int = 0               # morsels this pool stole from another
    queue: deque = field(default_factory=deque, repr=False)
    # fault-tolerance state (mutated under the scheduler's condition)
    dead: bool = False            # killed: workers exited, no new work
    quarantined: bool = False     # straggler/hang: avoided by dispatch
    heartbeat_t: float = 0.0      # last worker take/finish (monotonic)
    inflight: int = 0             # morsels currently executing
    ewma_s: float = 0.0           # EWMA morsel service time (ft.py idiom)
    samples: int = 0

    @property
    def live(self) -> bool:
        return not (self.dead or self.quarantined)


class WorkerLeakError(RuntimeError):
    """close() could not join every worker thread — a wedged pool would
    otherwise leak threads invisibly across tests/sessions."""

    def __init__(self, unjoined: List[str]):
        super().__init__(f"unjoined worker threads after close(): "
                         f"{', '.join(unjoined)}")
        self.unjoined = list(unjoined)


@dataclass
class SchedulerStats:
    morsels_dispatched: int = 0
    tasks: int = 0
    executed_per_pool: Tuple[int, ...] = ()
    steals_per_pool: Tuple[int, ...] = ()
    requeued: int = 0             # morsels moved off dead/quarantined pools
    dead_pools: Tuple[int, ...] = ()
    quarantined_pools: Tuple[int, ...] = ()   # includes dead pools
    pool_ewma_s: Tuple[float, ...] = ()

    @property
    def steals(self) -> int:
        return sum(self.steals_per_pool)


class MorselScheduler:
    """Dispatch QueryTasks to socket-pinned pools under a ThreadPlacement.

    ``submit(task)`` enqueues the task's morsels per the placement policy
    and returns immediately; ``task.wait()`` joins. Pools steal from the
    longest backlog when their own deque runs dry (counted). The
    scheduler can be constructed ``started=False`` so tests can stage a
    backlog before any worker runs."""

    def __init__(self, n_pools: int = 2, workers_per_pool: int = 2,
                 placement: ThreadPlacement = ThreadPlacement.OS_DEFAULT,
                 morsel_rows: Optional[int] = None, steal: bool = True,
                 n_shards: Optional[int] = None, started: bool = True,
                 faults=None, straggler_threshold: float = 4.0,
                 straggler_warmup: int = 3, hang_after_s: float = 30.0):
        if n_pools < 1 or workers_per_pool < 1:
            raise ValueError("need at least one pool and one worker")
        self.placement = placement
        self.morsel_rows = morsel_rows
        self.steal = steal
        self.faults = faults                # ServiceFaultInjector | None
        self.straggler_threshold = straggler_threshold
        self.straggler_warmup = straggler_warmup
        self.hang_after_s = hang_after_s
        shards = jax.device_count() if n_shards is None else n_shards
        per = max(1, shards // n_pools)
        now = time.monotonic()
        self.pools = [WorkerPool(i, min(i * per, shards),
                                 min((i + 1) * per, shards) if i < n_pools - 1
                                 else shards, heartbeat_t=now)
                      for i in range(n_pools)]
        self._cv = threading.Condition()
        self._rr = 0                        # OS_DEFAULT round-robin cursor
        self._sparse_base = 0               # SPARSE per-task stripe offset
        self._tasks = 0
        self._dispatched = 0
        self._requeued = 0
        self._closed = False
        self._threads: List[threading.Thread] = []
        self._workers_per_pool = workers_per_pool
        if started:
            self.start()

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        if self._threads:
            return
        now = time.monotonic()
        for pool in self.pools:
            pool.heartbeat_t = now
            for w in range(self._workers_per_pool):
                t = threading.Thread(
                    target=self._worker, args=(pool,),
                    name=f"pool{pool.pool_id}-w{w}", daemon=True)
                t.start()
                self._threads.append(t)

    def close(self, timeout: float = 5.0) -> List[str]:
        """Stop workers, drain, join. Returns the names of worker threads
        that did NOT join within ``timeout`` — a wedged pool must be a
        visible report, never a silent daemon-thread leak (the facade
        raises WorkerLeakError on a non-empty report)."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        unjoined: List[str] = []
        for t in self._threads:
            t.join(timeout=timeout)
            if t.is_alive():
                unjoined.append(t.name)
        self._threads = []
        return unjoined

    def __enter__(self) -> "MorselScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- task construction --------------------------------------------------
    def build_task(self, plan: L.LogicalPlan, tables,
                   ctx: Optional[ExecutionContext] = None) -> QueryTask:
        """Compile (through the plan cache) and wrap a plan as a task.

        Decomposable plans (distributive Aggregate over a Scan chain, no
        mesh) become per-morsel partials when ``morsel_rows`` is set;
        planner-marked join-probe pipelines become split-probe tasks
        (build sides once per task, probe morsels per pool — see the
        module docstring); all others become a single whole-plan morsel
        whose result is bit-identical to serial execution by
        construction. Whole-plan dispatch goes through
        ``planner.compile_plan`` and therefore the EXPLICIT physical plan
        (lowered once, cached as the plan-cache value; inspectable via
        ``task.physical``) — the scheduler never re-derives strategy
        decisions at dispatch time. The whole-plan executable is only
        compiled on that fallback path — a split task must not push a
        never-invoked entry into the bounded plan cache."""
        ctx = ctx or ExecutionContext()
        # fault hook: one dispatch ordinal per build attempt (retries
        # re-tick); an injected build failure raises HERE, before any
        # compile work, exactly like a plan naming a missing table
        ordinal = (self.faults.begin_dispatch()
                   if self.faults is not None else None)
        if self.morsel_rows is not None and ctx.mesh is None:
            split = (_morsel_decompose(plan, tables, ctx)
                     or _probe_split_decompose(plan, tables, ctx))
            if split is not None:
                morsel_fn, finalize, n_rows = split
                task = QueryTask(None, tables, morsel_fn, finalize,
                                 morsel_slices(n_rows, self.morsel_rows))
                task.fault_ordinal = ordinal
                return task
        task = QueryTask(planner.compile_plan(plan, tables, ctx), tables)
        task.fault_ordinal = ordinal
        return task

    # -- dispatch -----------------------------------------------------------
    def _live_pools(self) -> List[WorkerPool]:
        """Call under the condition: pools eligible for new work."""
        return [p for p in self.pools if p.live]

    def submit(self, task: QueryTask) -> QueryTask:
        with self._cv:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            live = self._live_pools()
            if not live:
                raise RuntimeError("no live worker pools — every pool is "
                                   "dead or quarantined")
            self._tasks += 1
            task.submit_t = time.monotonic()
            dense_pool = min(live, key=lambda p: len(p.queue)).pool_id
            # SPARSE stripes a task's morsels across every live pool,
            # starting from a per-task rotating base — otherwise
            # single-morsel (whole-plan) tasks would all land on pool 0
            # (seq is always 0) and the other pools could only work via
            # steals
            sparse_base = self._sparse_base
            self._sparse_base += 1
            for m in task.morsels:
                if self.placement == ThreadPlacement.DENSE:
                    m.home_pool = dense_pool
                elif self.placement == ThreadPlacement.SPARSE:
                    m.home_pool = live[(sparse_base + m.seq)
                                       % len(live)].pool_id
                else:                       # OS_DEFAULT: arrival order
                    m.home_pool = live[self._rr % len(live)].pool_id
                    self._rr += 1
                self.pools[m.home_pool].queue.append(m)
                self._dispatched += 1
            self._cv.notify_all()
        # fault hook AFTER enqueue: a pool kill scheduled at this ordinal
        # fires mid-round — the task's morsels may sit on the killed
        # pool's queue until check_pools() requeues them
        if self.faults is not None and task.fault_ordinal is not None:
            self.faults.on_submit(task.fault_ordinal, task, self)
        return task

    # -- fault tolerance ----------------------------------------------------
    def kill_pool(self, pool_id: int) -> None:
        """Drill analog of losing a socket/host: the pool's workers exit
        (in-flight morsels finish — threads cannot be preempted — but no
        new morsel is taken) and its backlog waits for check_pools() to
        requeue it onto survivors."""
        with self._cv:
            self.pools[pool_id].dead = True
            self._cv.notify_all()

    def quarantine_pool(self, pool_id: int) -> None:
        """Mark a pool unschedulable and requeue its backlog (manual
        override of the straggler/hang detectors)."""
        with self._cv:
            pool = self.pools[pool_id]
            if sum(p.live for p in self.pools) > 1 or not pool.live:
                pool.quarantined = True
            self._requeue_locked()
            self._cv.notify_all()

    def _requeue_locked(self) -> None:
        """Move every morsel queued on a non-live pool onto live pools,
        round-robin, preserving order (call under the condition)."""
        moved: List[_Morsel] = []
        for p in self.pools:
            if not p.live and p.queue:
                moved.extend(p.queue)
                p.queue.clear()
        if not moved:
            return
        live = self._live_pools()
        if not live:                 # nothing to requeue onto; put back
            self.pools[moved[0].home_pool].queue.extend(moved)
            return
        for i, m in enumerate(moved):
            target = live[i % len(live)]
            m.home_pool = target.pool_id
            target.queue.append(m)
        self._requeued += len(moved)

    def check_pools(self, now: Optional[float] = None) -> List[int]:
        """Heartbeat + EWMA sweep (the serving port of ft.py's
        StragglerDetector): quarantine pools that are dead, hung (backlog
        but no heartbeat within ``hang_after_s``), or straggling (EWMA
        morsel time > ``straggler_threshold`` x the live-pool median),
        then requeue their backlogs onto survivors. Never quarantines the
        last live pool. Returns newly quarantined pool ids."""
        now = time.monotonic() if now is None else now
        newly: List[int] = []
        with self._cv:
            for p in self.pools:
                if not p.live:
                    continue
                if sum(q.live for q in self.pools) <= 1:
                    break
                if p.dead:
                    continue
                if p.queue and now - p.heartbeat_t > self.hang_after_s:
                    p.quarantined = True
                    newly.append(p.pool_id)
            ready = [p for p in self.pools
                     if p.live and p.samples >= self.straggler_warmup]
            if len(ready) >= 2:
                for p in ready:
                    if sum(q.live for q in self.pools) <= 1:
                        break
                    # median of the PEERS, not the whole fleet: with few
                    # pools a fleet median that includes the straggler is
                    # dragged up by it (2 pools: median == mean, and the
                    # threshold could mathematically never trip)
                    med = float(np.median([q.ewma_s for q in ready
                                           if q is not p]))
                    if med > 0 and p.ewma_s > self.straggler_threshold * med:
                        p.quarantined = True
                        newly.append(p.pool_id)
            self._requeue_locked()
            if newly:
                self._cv.notify_all()
        if newly and tracing.tracing_enabled():
            tr = tracing.tracer()
            for pid in newly:
                tr.instant("pool.quarantine", "scheduler",
                           pid=f"pool{pid}")
            tr.flight_dump("pool.quarantine", pools=list(newly))
        return newly

    def run(self, plan: L.LogicalPlan, tables,
            ctx: Optional[ExecutionContext] = None) -> Dict[str, jax.Array]:
        """Convenience: build, submit, wait."""
        return self.submit(self.build_task(plan, tables, ctx)).wait()

    # -- workers ------------------------------------------------------------
    def _take(self, pool: WorkerPool) -> Optional[_Morsel]:
        """Called under the lock: own head first, else steal the tail of
        the longest LIVE backlog (classic work stealing). A dead pool
        takes nothing (its workers are exiting); a quarantined pool only
        drains its own queue — a straggler must not slow other pools'
        work by stealing it."""
        if pool.dead:
            return None
        if pool.queue:
            return pool.queue.popleft()
        if not self.steal or pool.quarantined:
            return None
        victim = max((p for p in self.pools if p is not pool and p.live),
                     key=lambda p: len(p.queue), default=None)
        if victim is not None and victim.queue:
            pool.steals += 1
            m = victim.queue.pop()
            if tracing.tracing_enabled():
                tracing.tracer().instant(
                    "morsel.steal", "scheduler", trace_id=m.task.trace_id,
                    pid=f"pool{pool.pool_id}", victim=victim.pool_id,
                    seq=m.seq)
            return m
        return None

    def _worker(self, pool: WorkerPool) -> None:
        while True:
            with self._cv:
                m = self._take(pool)
                while m is None and not self._closed and not pool.dead:
                    self._cv.wait(timeout=0.1)
                    m = self._take(pool)
                if m is None:               # closed and drained, or killed
                    return
                pool.executed += 1
                pool.inflight += 1
                pool.heartbeat_t = time.monotonic()
            delay = (self.faults.morsel_delay(pool.pool_id)
                     if self.faults is not None else 0.0)
            if delay > 0.0:
                time.sleep(delay)
            t0 = time.monotonic()
            m.task._run_morsel(m, pool.pool_id)
            t1 = time.monotonic()
            if tracing.tracing_enabled():
                tracing.tracer().add_complete(
                    "morsel.run", "scheduler", t0, t1,
                    trace_id=m.task.trace_id, pid=f"pool{pool.pool_id}",
                    seq=m.seq, rows=m.length)
            dt = t1 - t0 + delay                # EWMA must see the straggle
            with self._cv:
                pool.inflight -= 1
                pool.heartbeat_t = time.monotonic()
                pool.samples += 1
                pool.ewma_s = (dt if pool.samples == 1
                               else 0.3 * dt + 0.7 * pool.ewma_s)

    def stats(self) -> SchedulerStats:
        with self._cv:
            return SchedulerStats(
                morsels_dispatched=self._dispatched, tasks=self._tasks,
                executed_per_pool=tuple(p.executed for p in self.pools),
                steals_per_pool=tuple(p.steals for p in self.pools),
                requeued=self._requeued,
                dead_pools=tuple(p.pool_id for p in self.pools if p.dead),
                quarantined_pools=tuple(p.pool_id for p in self.pools
                                        if not p.live),
                pool_ewma_s=tuple(p.ewma_s for p in self.pools))


# ---------------------------------------------------------------------------
# morsel decomposition of distributive-aggregate plans
# ---------------------------------------------------------------------------
_DISTRIBUTIVE = ("sum", "avg", "count")


def _scan_chain(root: L.Node) -> Optional[Tuple[L.Scan, List[L.Node]]]:
    """(scan, [transforms leaf->root]) when root's child chain is pure
    Scan/Filter/Project; None otherwise."""
    chain: List[L.Node] = []
    node = root
    while True:
        if isinstance(node, L.Scan):
            return node, list(reversed(chain))
        if isinstance(node, (L.Filter, L.Project)):
            chain.append(node)
            node = node.child
            continue
        return None


def _morsel_decompose(plan: L.LogicalPlan, tables, ctx: ExecutionContext):
    """(morsel_fn, finalize, n_rows) for a decomposable plan, else None.

    Decomposable = root Aggregate whose aggregates are all distributive
    sums (sum/avg/count) over a Scan/Filter/Project chain. The morsel
    partial is the stacked (n_groups, C) sums table over one row range —
    the same physical primitive the planner lowers Aggregates onto — so
    merged morsel results reuse finalize_stacked and can never drift from
    the planner's semantics. NOTE: per-morsel partial sums merge in morsel
    order, which is a DIFFERENT float summation order than the one-pass
    serial plan — the split path trades bit-identity for intra-query
    parallelism (the whole-plan path keeps bit-identity)."""
    root = plan.root
    if not isinstance(root, L.Aggregate):
        return None
    if any(op not in _DISTRIBUTIVE for _, (op, _c) in root.aggs):
        return None
    chain = _scan_chain(root.child)
    if chain is None:
        return None
    scan_node, transforms = chain
    # snapshot the cost profile ONCE: it keys the cache and is baked into
    # the traced closure (same stale-constants hazard as compile_plan)
    profile = planner.current_cost_profile()
    n_rows = next(iter(tables[scan_node.table].values())).shape[0]
    if root.key is None:
        n_groups = 1
    elif isinstance(root.n_groups, L.TableRows):
        n_groups = next(iter(
            tables[root.n_groups.table].values())).shape[0]
    else:
        n_groups = int(root.n_groups)
    aggs = dict(root.aggs)

    def partial(tbls, lo, *, length):
        t = Table(morsel_slice_columns(tbls[scan_node.table], lo, length))
        for node in transforms:
            if isinstance(node, L.Filter):
                t = t.filter(planner.eval_expr(node.pred, t))
            else:
                t = t.with_columns(**{n: planner.eval_expr(e, t)
                                      for n, e in node.cols})
        if root.key is None:
            t = t.with_columns(_g0=jnp.zeros((length,), jnp.int32))
            key = "_g0"
        else:
            key = root.key
        keys, vals, src = stacked_columns(t, key, n_groups, aggs)
        layout = planner.choose_aggregate(length, n_groups, vals.shape[1],
                                          ctx.executor, profile)
        return morsel_group_sums(keys, vals, n_groups, layout=layout,
                                 mode=ctx.mode,
                                 n_partitions=ctx.n_partitions,
                                 capacity_factor=ctx.capacity_factor)

    # one jitted executable per (plan, ctx, signature); per-morsel widths
    # specialize via the static ``length`` argument
    fn = planner.cached_executable(
        ("morsel", plan, ctx.cache_key(), planner.table_signature(tables),
         profile),
        lambda: jax.jit(partial, static_argnames=("length",)))

    def morsel_fn(tbls, lo, *, length, pool=0):
        del pool             # partial sums need no pool-local structures
        return fn(tbls, lo, length=length)

    src = [c for _, (op, c) in root.aggs
           if op in ("sum", "avg")]
    src = list(dict.fromkeys(src))          # distinct, insertion order

    def finalize(sums, overflow):
        out = finalize_stacked(aggs, src, sums, _no_order_stats)
        out["_overflow"] = overflow.astype(jnp.int32)
        if plan.outputs is not None:
            out = {k: out[k] for k in plan.outputs}
        return out

    return morsel_fn, finalize, n_rows


def _no_order_stats(op, col):
    raise ValueError(f"order statistic {op!r} is not distributive — "
                     "plan should not have been morsel-decomposed")


# ---------------------------------------------------------------------------
# split-probe decomposition of planner-marked join pipelines
# ---------------------------------------------------------------------------
def _build_probe_split(plan: L.LogicalPlan, ctx: ExecutionContext, tables,
                       profile):
    """Plan-cache value for a split-probe candidate: the string "whole"
    when the planner declines (cached, so repeat dispatches skip the
    re-analysis), else (probe_split, prelude_jit, morsel_jit, final_jit).

    Three executables because the three phases run at different
    cadences: the prelude (join build sides, Attach sources) once per
    TASK, the probe pipeline once per MORSEL (row-range specialized via
    the static ``length``, like the distributive-aggregate path), and
    the finalize (aggregate + TopK over the merged intermediate table)
    once per task after the morsel-order merge."""
    phys = planner.lower(plan, ctx,
                         {t: next(iter(c.values())).shape[0]
                          for t, c in tables.items()}, profile)
    split = planner.probe_split(phys)
    if split is None:
        return "whole"
    preludes = split.preludes

    def run_prelude(tbls, indexes):
        ex = planner._LocalExecutor(tbls, ctx, indexes, profile)
        vals = []
        for p in preludes:
            v = ex.run(p.node)
            # Tables serialize as (columns, mask) across the jit
            # boundary — index_cache is host state and is re-seeded per
            # morsel from the pool replicas instead
            vals.append((v.columns, v.mask) if p.is_table else v)
        return vals, ex.overflow

    def run_morsel(tbls, prelude_vals, replicas, lo, *, length):
        ex = planner._LocalExecutor(tbls, ctx, {}, profile)
        ri = 0
        for p, v in zip(preludes, prelude_vals):
            if p.is_table:
                cols, mask = v
                cache = {}
                if p.index is not None:
                    # the pool-local build replica seeds key_index, so a
                    # sorted join never re-argsorts inside a morsel
                    cache = {p.index[1]: replicas[ri]}
                    ri += 1
                ex._memo[p.node] = Table(dict(cols), mask, cache)
            else:
                ex._memo[p.node] = v
        ex._memo[split.scan] = Table(
            morsel_slice_columns(tbls[split.scan.table], lo, length))
        t = ex.run(split.pipeline_root)
        return (t.columns, t.mask), ex.overflow

    def run_final(merged, overflow):
        cols, mask = merged
        ex = planner._LocalExecutor({}, ctx, {}, profile)
        ex._memo[split.pipeline_root] = Table(dict(cols), mask)
        ex.overflow = ex.overflow + overflow
        out = dict(ex.run(split.root))
        out["_overflow"] = ex.overflow
        if split.outputs is not None:
            out = {k: out[k] for k in split.outputs}
        return out

    return (split, jax.jit(run_prelude),
            jax.jit(run_morsel, static_argnames=("length",)),
            jax.jit(run_final))


def _probe_split_decompose(plan: L.LogicalPlan, tables,
                           ctx: ExecutionContext):
    """(morsel_fn, finalize, n_rows) for a planner-marked split-probe
    join pipeline, else None.

    The division of labor mirrors the paper's socket-local working sets:
    the build side is materialized ONCE per task (prelude), its pooled
    sort index replicated ONCE per worker pool
    (JoinIndexPool.replica), and every probe morsel — wherever stealing
    lands it — probes the executing pool's replica. Per-morsel outputs
    are row slices of the serial intermediate table, so the morsel-order
    concat + finalize reproduces serial ``run_query`` bit-for-bit (the
    distributive-aggregate path cannot promise that; this path can,
    because the merge is a concat, not a float re-ordering)."""
    profile = planner.current_cost_profile()
    bundle = planner.cached_executable(
        ("morsel-probe", plan, ctx.cache_key(),
         planner.table_signature(tables), profile),
        lambda: _build_probe_split(plan, ctx, tables, profile))
    if bundle == "whole":
        return None
    split, prelude_jit, morsel_jit, final_jit = bundle
    join_pool = planner.join_index_pool()
    indexes = {f"{t}.{c}": join_pool.get(t, c, tables[t][c])
               for t, c in planner.required_indexes(plan.root)}
    # the prelude runs ONCE per task — its values are closed over by
    # every morsel of this task
    prelude_vals, prelude_ovf = prelude_jit(tables, indexes)
    specs = [p.index for p in split.preludes if p.index is not None]

    def morsel_fn(tbls, lo, *, length, pool=0):
        # per-POOL build replicas (an LRU hit after each pool's first
        # morsel), fetched by the EXECUTING pool — including on steals
        replicas = [join_pool.replica(t, c, tbls[t][c], pool)
                    for t, c in specs]
        return morsel_jit(tbls, prelude_vals, replicas, lo, length=length)

    def finalize(merged, overflow):
        return final_jit(merged, overflow + prelude_ovf)

    return morsel_fn, finalize, split.n_rows
