"""Morsel-driven scheduler: socket-pinned worker pools + work stealing.

The execution analog of the paper's thread-placement axis (Figs 3/4):

  * A **WorkerPool** is the NUMA-socket analog — it owns a CONTIGUOUS
    slice of the device mesh (shard range) and a small set of worker
    threads pinned to it. On the single-controller JAX runtime the
    pinning is an affinity *model* (which pool's threads dispatch which
    work, and which shard slice that work is accounted against); on a
    real multi-host deployment the pool maps 1:1 to a host's devices.
  * A **morsel** is a contiguous row range of a scan (engine.morsel_slices)
    — the work unit that makes load balancing possible at all. Plans
    whose root is a distributive Aggregate over a Scan/Filter/Project
    chain are split into per-morsel partial aggregations merged in morsel
    order (engine.merge_morsel_partials — deterministic under stealing);
    everything else (joins, TopK, distributed contexts) executes as one
    whole-plan morsel through the planner's CompiledPlan handle, which is
    bit-identical to a serial ``run_query`` by construction.
  * **ThreadPlacement** mirrors benchmarks/fig3_fig4_thread_placement.py:
    OS_DEFAULT round-robins morsels over pools in arrival order (the
    topology-oblivious baseline), DENSE packs a query's morsels onto one
    pool (contiguous shards, minimal cross-pool traffic), SPARSE stripes
    them across every pool (maximal aggregate bandwidth).
  * **Work stealing** is the AutoNUMA / kernel-load-balancing analog: an
    idle pool steals from the longest backlog; every steal is counted
    per pool and surfaced in SchedulerStats.
"""
from __future__ import annotations

import enum
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analytics import plan as L
from repro.analytics import planner
from repro.analytics.columnar import Table, finalize_stacked, stacked_columns
from repro.analytics.engine import (merge_morsel_partials, morsel_group_sums,
                                    morsel_slice_columns, morsel_slices)
from repro.analytics.planner import ExecutionContext


class ThreadPlacement(enum.Enum):
    """Pool-to-work affinity strategies (the Fig 3/4 axis).

    OS_DEFAULT  arrival-order round-robin, no affinity (the "OS free to
                migrate" baseline — MeshLayout.NONE's serving analog).
    DENSE       a query's morsels packed onto ONE pool: contiguous shard
                slice, minimal cross-pool hops (Fig 4's dense pinning).
    SPARSE      a query's morsels striped across ALL pools: maximal
                aggregate bandwidth per query (Fig 3/4's sparse pinning).
    """

    OS_DEFAULT = "os_default"
    DENSE = "dense"
    SPARSE = "sparse"


# Multi-device (mesh-context) computations must be dispatched by one
# thread at a time: concurrent shard_map dispatch from worker threads can
# interleave per-device enqueue order (A before B on dev0, B before A on
# dev1) and deadlock the collectives. A distributed plan owns the WHOLE
# mesh anyway — serializing its dispatch loses no parallelism; pools keep
# overlapping single-device work freely.
_MESH_DISPATCH_LOCK = threading.Lock()


@dataclass
class _Morsel:
    task: "QueryTask"
    seq: int                      # position in the task's morsel order
    lo: int
    length: int
    home_pool: int = -1           # assigned pool (stamped at dispatch)


class QueryTask:
    """One dispatch: a whole plan or a set of morsel partial-aggregations.

    ``wait()`` blocks until every morsel completed and the merged result
    is available. Exceptions raised by any morsel are captured and
    re-raised to the waiter."""

    def __init__(self, compiled: Optional[planner.CompiledPlan], tables,
                 morsel_fn: Optional[Callable] = None,
                 finalize: Optional[Callable] = None,
                 morsels: Optional[List[Tuple[int, int]]] = None):
        self.compiled = compiled            # None iff morsel-decomposed
        self.tables = tables
        self.morsel_fn = morsel_fn          # (tables, lo, length) -> partial
        self.finalize = finalize            # (sums, overflow) -> result dict
        self._partials: Dict[int, Any] = {}
        self._lock = threading.Lock()
        self._done = threading.Event()
        self._error: Optional[BaseException] = None
        self.result: Optional[Dict[str, jax.Array]] = None
        self.done_t: float = 0.0            # completion stamp (monotonic)
        if morsel_fn is None:
            self.morsels = [_Morsel(self, 0, 0, 0)]
        else:
            self.morsels = [_Morsel(self, i, lo, hi - lo)
                            for i, (lo, hi) in enumerate(morsels)]
        self._pending = len(self.morsels)

    @property
    def split(self) -> bool:
        return self.morsel_fn is not None

    @property
    def physical(self):
        """The explicit physical plan a whole-plan task dispatches (the
        plan-cache value compile_plan resolved); None for morsel-split
        tasks, whose unit is the per-morsel partial executable."""
        return None if self.compiled is None else self.compiled.physical

    def _run_morsel(self, m: _Morsel) -> None:
        try:
            if self.morsel_fn is None:
                if self.compiled.ctx.mesh is not None:
                    with _MESH_DISPATCH_LOCK:
                        out = jax.block_until_ready(
                            self.compiled(self.tables))
                else:
                    out = jax.block_until_ready(self.compiled(self.tables))
                with self._lock:
                    self.result = out
            else:
                part = jax.block_until_ready(
                    self.morsel_fn(self.tables, m.lo, length=m.length))
                with self._lock:
                    self._partials[m.seq] = part
        except BaseException as e:  # noqa: BLE001 — surfaced to waiter
            with self._lock:
                self._error = e
        finally:
            with self._lock:
                self._pending -= 1
                last = self._pending == 0
            if last:
                self._finish()

    def _finish(self) -> None:
        if self._error is None and self.morsel_fn is not None:
            try:
                # merge in MORSEL order, not completion order: the served
                # result must not depend on which pool finished first
                sums, ovf = merge_morsel_partials(
                    [self._partials[i] for i in range(len(self.morsels))])
                self.result = jax.block_until_ready(self.finalize(sums, ovf))
            except BaseException as e:  # noqa: BLE001
                self._error = e
        # stamp completion HERE, not when a waiter gets around to joining:
        # per-query latency must not include time spent waiting on other
        # tasks in the drain loop
        self.done_t = time.monotonic()
        self._done.set()

    def wait(self, timeout: Optional[float] = None) -> Dict[str, jax.Array]:
        if not self._done.wait(timeout):
            raise TimeoutError("query task did not complete in time")
        if self._error is not None:
            raise self._error
        return self.result


@dataclass
class WorkerPool:
    """The NUMA-socket analog: a contiguous shard slice + pinned workers."""

    pool_id: int
    shard_lo: int                 # [shard_lo, shard_hi) of the device mesh
    shard_hi: int
    executed: int = 0             # morsels run by this pool's workers
    steals: int = 0               # morsels this pool stole from another
    queue: deque = field(default_factory=deque, repr=False)


@dataclass
class SchedulerStats:
    morsels_dispatched: int = 0
    tasks: int = 0
    executed_per_pool: Tuple[int, ...] = ()
    steals_per_pool: Tuple[int, ...] = ()

    @property
    def steals(self) -> int:
        return sum(self.steals_per_pool)


class MorselScheduler:
    """Dispatch QueryTasks to socket-pinned pools under a ThreadPlacement.

    ``submit(task)`` enqueues the task's morsels per the placement policy
    and returns immediately; ``task.wait()`` joins. Pools steal from the
    longest backlog when their own deque runs dry (counted). The
    scheduler can be constructed ``started=False`` so tests can stage a
    backlog before any worker runs."""

    def __init__(self, n_pools: int = 2, workers_per_pool: int = 2,
                 placement: ThreadPlacement = ThreadPlacement.OS_DEFAULT,
                 morsel_rows: Optional[int] = None, steal: bool = True,
                 n_shards: Optional[int] = None, started: bool = True):
        if n_pools < 1 or workers_per_pool < 1:
            raise ValueError("need at least one pool and one worker")
        self.placement = placement
        self.morsel_rows = morsel_rows
        self.steal = steal
        shards = jax.device_count() if n_shards is None else n_shards
        per = max(1, shards // n_pools)
        self.pools = [WorkerPool(i, min(i * per, shards),
                                 min((i + 1) * per, shards) if i < n_pools - 1
                                 else shards)
                      for i in range(n_pools)]
        self._cv = threading.Condition()
        self._rr = 0                        # OS_DEFAULT round-robin cursor
        self._sparse_base = 0               # SPARSE per-task stripe offset
        self._tasks = 0
        self._dispatched = 0
        self._closed = False
        self._threads: List[threading.Thread] = []
        self._workers_per_pool = workers_per_pool
        if started:
            self.start()

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        if self._threads:
            return
        for pool in self.pools:
            for w in range(self._workers_per_pool):
                t = threading.Thread(
                    target=self._worker, args=(pool,),
                    name=f"pool{pool.pool_id}-w{w}", daemon=True)
                t.start()
                self._threads.append(t)

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)
        self._threads = []

    def __enter__(self) -> "MorselScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- task construction --------------------------------------------------
    def build_task(self, plan: L.LogicalPlan, tables,
                   ctx: Optional[ExecutionContext] = None) -> QueryTask:
        """Compile (through the plan cache) and wrap a plan as a task.

        Decomposable plans (distributive Aggregate over a Scan chain, no
        mesh) become per-morsel partials when ``morsel_rows`` is set; all
        others become a single whole-plan morsel whose result is
        bit-identical to serial execution by construction. Whole-plan
        dispatch goes through ``planner.compile_plan`` and therefore the
        EXPLICIT physical plan (lowered once, cached as the plan-cache
        value; inspectable via ``task.physical``) — the scheduler never
        re-derives strategy decisions at dispatch time. The whole-plan
        executable is only compiled on that fallback path — a split task
        must not push a never-invoked entry into the bounded plan cache."""
        ctx = ctx or ExecutionContext()
        if self.morsel_rows is not None and ctx.mesh is None:
            split = _morsel_decompose(plan, tables, ctx)
            if split is not None:
                morsel_fn, finalize, n_rows = split
                return QueryTask(None, tables, morsel_fn, finalize,
                                 morsel_slices(n_rows, self.morsel_rows))
        return QueryTask(planner.compile_plan(plan, tables, ctx), tables)

    # -- dispatch -----------------------------------------------------------
    def submit(self, task: QueryTask) -> QueryTask:
        with self._cv:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            self._tasks += 1
            dense_pool = min(self.pools, key=lambda p: len(p.queue)).pool_id
            # SPARSE stripes a task's morsels across every pool, starting
            # from a per-task rotating base — otherwise single-morsel
            # (whole-plan) tasks would all land on pool 0 (seq is always 0)
            # and the other pools could only work via steals
            sparse_base = self._sparse_base
            self._sparse_base += 1
            for m in task.morsels:
                if self.placement == ThreadPlacement.DENSE:
                    m.home_pool = dense_pool
                elif self.placement == ThreadPlacement.SPARSE:
                    m.home_pool = (sparse_base + m.seq) % len(self.pools)
                else:                       # OS_DEFAULT: arrival order
                    m.home_pool = self._rr % len(self.pools)
                    self._rr += 1
                self.pools[m.home_pool].queue.append(m)
                self._dispatched += 1
            self._cv.notify_all()
        return task

    def run(self, plan: L.LogicalPlan, tables,
            ctx: Optional[ExecutionContext] = None) -> Dict[str, jax.Array]:
        """Convenience: build, submit, wait."""
        return self.submit(self.build_task(plan, tables, ctx)).wait()

    # -- workers ------------------------------------------------------------
    def _take(self, pool: WorkerPool) -> Optional[_Morsel]:
        """Called under the lock: own head first, else steal the tail of
        the longest other backlog (classic work stealing)."""
        if pool.queue:
            return pool.queue.popleft()
        if not self.steal:
            return None
        victim = max((p for p in self.pools if p is not pool),
                     key=lambda p: len(p.queue), default=None)
        if victim is not None and victim.queue:
            pool.steals += 1
            return victim.queue.pop()
        return None

    def _worker(self, pool: WorkerPool) -> None:
        while True:
            with self._cv:
                m = self._take(pool)
                while m is None and not self._closed:
                    self._cv.wait(timeout=0.1)
                    m = self._take(pool)
                if m is None:               # closed and drained
                    return
                pool.executed += 1
            m.task._run_morsel(m)

    def stats(self) -> SchedulerStats:
        with self._cv:
            return SchedulerStats(
                morsels_dispatched=self._dispatched, tasks=self._tasks,
                executed_per_pool=tuple(p.executed for p in self.pools),
                steals_per_pool=tuple(p.steals for p in self.pools))


# ---------------------------------------------------------------------------
# morsel decomposition of distributive-aggregate plans
# ---------------------------------------------------------------------------
_DISTRIBUTIVE = ("sum", "avg", "count")


def _scan_chain(root: L.Node) -> Optional[Tuple[L.Scan, List[L.Node]]]:
    """(scan, [transforms leaf->root]) when root's child chain is pure
    Scan/Filter/Project; None otherwise."""
    chain: List[L.Node] = []
    node = root
    while True:
        if isinstance(node, L.Scan):
            return node, list(reversed(chain))
        if isinstance(node, (L.Filter, L.Project)):
            chain.append(node)
            node = node.child
            continue
        return None


def _morsel_decompose(plan: L.LogicalPlan, tables, ctx: ExecutionContext):
    """(morsel_fn, finalize, n_rows) for a decomposable plan, else None.

    Decomposable = root Aggregate whose aggregates are all distributive
    sums (sum/avg/count) over a Scan/Filter/Project chain. The morsel
    partial is the stacked (n_groups, C) sums table over one row range —
    the same physical primitive the planner lowers Aggregates onto — so
    merged morsel results reuse finalize_stacked and can never drift from
    the planner's semantics. NOTE: per-morsel partial sums merge in morsel
    order, which is a DIFFERENT float summation order than the one-pass
    serial plan — the split path trades bit-identity for intra-query
    parallelism (the whole-plan path keeps bit-identity)."""
    root = plan.root
    if not isinstance(root, L.Aggregate):
        return None
    if any(op not in _DISTRIBUTIVE for _, (op, _c) in root.aggs):
        return None
    chain = _scan_chain(root.child)
    if chain is None:
        return None
    scan_node, transforms = chain
    # snapshot the cost profile ONCE: it keys the cache and is baked into
    # the traced closure (same stale-constants hazard as compile_plan)
    profile = planner.current_cost_profile()
    n_rows = next(iter(tables[scan_node.table].values())).shape[0]
    if root.key is None:
        n_groups = 1
    elif isinstance(root.n_groups, L.TableRows):
        n_groups = next(iter(
            tables[root.n_groups.table].values())).shape[0]
    else:
        n_groups = int(root.n_groups)
    aggs = dict(root.aggs)

    def partial(tbls, lo, *, length):
        t = Table(morsel_slice_columns(tbls[scan_node.table], lo, length))
        for node in transforms:
            if isinstance(node, L.Filter):
                t = t.filter(planner.eval_expr(node.pred, t))
            else:
                t = t.with_columns(**{n: planner.eval_expr(e, t)
                                      for n, e in node.cols})
        if root.key is None:
            t = t.with_columns(_g0=jnp.zeros((length,), jnp.int32))
            key = "_g0"
        else:
            key = root.key
        keys, vals, src = stacked_columns(t, key, n_groups, aggs)
        layout = planner.choose_aggregate(length, n_groups, vals.shape[1],
                                          ctx.executor, profile)
        return morsel_group_sums(keys, vals, n_groups, layout=layout,
                                 mode=ctx.mode,
                                 n_partitions=ctx.n_partitions,
                                 capacity_factor=ctx.capacity_factor)

    # one jitted executable per (plan, ctx, signature); per-morsel widths
    # specialize via the static ``length`` argument
    fn = planner.cached_executable(
        ("morsel", plan, ctx.cache_key(), planner.table_signature(tables),
         profile),
        lambda: jax.jit(partial, static_argnames=("length",)))

    src = [c for _, (op, c) in root.aggs
           if op in ("sum", "avg")]
    src = list(dict.fromkeys(src))          # distinct, insertion order

    def finalize(sums, overflow):
        out = finalize_stacked(aggs, src, sums, _no_order_stats)
        out["_overflow"] = overflow.astype(jnp.int32)
        if plan.outputs is not None:
            out = {k: out[k] for k in plan.outputs}
        return out

    return fn, finalize, n_rows


def _no_order_stats(op, col):
    raise ValueError(f"order statistic {op!r} is not distributive — "
                     "plan should not have been morsel-decomposed")
