"""Admission queue: bounded multi-client intake with deadlines.

The serving layer's first placement decision is *whether work enters at
all*: a bounded queue turns overload into explicit backpressure
(``offer`` returning False) instead of unbounded memory growth, and
deadline checks at dispatch time shed requests that already missed their
budget while queued — the two levers the paper's co-running-queries
problem (Awan et al.) needs before any placement tuning can help.

Every counter is taken under the queue lock, so ``stats()`` snapshots are
race-free with respect to concurrent submitters and the drain loop.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, List, Mapping, Optional

from repro.analytics.plan import LogicalPlan
from repro.analytics.planner import ExecutionContext


@dataclass
class QueryRequest:
    """One client query: a logical plan + a tables reference + a budget.

    ``tables`` is a {table: {column: array}} mapping — held by reference,
    never copied; structurally identical requests over the SAME mapping
    are deduplicated into one dispatch by the batcher. ``deadline_s`` is
    an absolute ``time.monotonic()`` point; None = no deadline."""

    req_id: int
    plan: LogicalPlan
    tables: Mapping[str, Mapping[str, Any]]
    context: ExecutionContext
    deadline_s: Optional[float] = None
    client_id: int = 0
    submit_t: float = 0.0          # stamped by the queue at admission
    dispatch_t: float = 0.0        # stamped by the service at dispatch

    def expired(self, now: float) -> bool:
        return self.deadline_s is not None and now > self.deadline_s


@dataclass
class QueueStats:
    submitted: int = 0             # offers seen (admitted + rejected)
    admitted: int = 0
    rejected_full: int = 0         # backpressure: queue at max depth
    expired: int = 0               # missed deadline while queued
    depth: int = 0                 # current
    max_depth_seen: int = 0
    queue_wait_total_s: float = 0.0  # summed over dequeued requests

    def copy(self) -> "QueueStats":
        return QueueStats(**self.__dict__)


class AdmissionQueue:
    """Bounded FIFO of QueryRequests with race-free backpressure stats."""

    def __init__(self, max_depth: int = 256):
        if max_depth < 1:
            raise ValueError("queue needs max_depth >= 1")
        self.max_depth = max_depth
        self._q: "deque[QueryRequest]" = deque()
        self._lock = threading.Lock()
        self._stats = QueueStats()

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)

    def offer(self, req: QueryRequest,
              now: Optional[float] = None) -> bool:
        """Admit a request; False = rejected (queue full, backpressure)."""
        now = time.monotonic() if now is None else now
        with self._lock:
            self._stats.submitted += 1
            if len(self._q) >= self.max_depth:
                self._stats.rejected_full += 1
                return False
            req.submit_t = now
            self._q.append(req)
            self._stats.admitted += 1
            self._stats.depth = len(self._q)
            self._stats.max_depth_seen = max(self._stats.max_depth_seen,
                                             len(self._q))
            return True

    def take_batch(self, max_n: int, now: Optional[float] = None
                   ) -> "tuple[List[QueryRequest], List[QueryRequest]]":
        """Dequeue up to ``max_n`` live requests in FIFO order.

        Returns (live, expired): requests whose deadline passed while
        queued are shed — counted, and handed back so the serving loop can
        report their fate to the submitter instead of dropping silently."""
        now = time.monotonic() if now is None else now
        out: List[QueryRequest] = []
        shed: List[QueryRequest] = []
        with self._lock:
            while self._q and len(out) < max_n:
                req = self._q.popleft()
                self._stats.queue_wait_total_s += now - req.submit_t
                if req.expired(now):
                    self._stats.expired += 1
                    shed.append(req)
                    continue
                req.dispatch_t = now
                out.append(req)
            self._stats.depth = len(self._q)
        return out, shed

    def stats(self) -> QueueStats:
        with self._lock:
            return self._stats.copy()
