"""Admission queue: bounded, priority-classed, weighted-fair intake.

The serving layer's first placement decision is *whether work enters at
all*: a bounded queue turns overload into explicit backpressure
(``offer`` returning False) instead of unbounded memory growth, and
deadline checks at dispatch time shed requests that already missed their
budget while queued — the two levers the paper's co-running-queries
problem (Awan et al.) needs before any placement tuning can help.

Graceful degradation adds two more levers on top of plain backpressure:

  * **Priority classes** (``QueryRequest.priority``, higher = more
    important) order dequeue strictly: an interactive class is served
    before a batch class. Within a class, dequeue is weighted-fair
    round-robin across ``client_id`` — a flooding client cannot starve
    its peers, and a client's weight buys it proportionally more slots
    per turn.
  * **Overload shedding**: when depth crosses ``shed_watermark``, an
    incoming request evicts the newest LOWEST-priority queued request of
    a class strictly below its own (lowest-priority-first shedding); an
    incoming request that is itself the lowest class is rejected
    (backpressure). Victims are handed back via ``pop_overload_shed`` so
    the service reports a terminal result instead of dropping silently.

Every counter is taken under the queue lock, so ``stats()`` snapshots are
race-free, and they CONSERVE exactly:

    admitted == dequeued + expired + shed_overload + depth
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional

from repro.analytics import tracing
from repro.analytics.plan import LogicalPlan
from repro.analytics.planner import ExecutionContext


@dataclass
class QueryRequest:
    """One client query: a logical plan + a tables reference + a budget.

    ``tables`` is a {table: {column: array}} mapping — held by reference,
    never copied; structurally identical requests over the SAME mapping
    are deduplicated into one dispatch by the batcher. ``deadline_s`` is
    an absolute ``time.monotonic()`` point; None = no deadline.
    ``priority`` is the service class (higher = more important; dequeued
    first, shed last)."""

    req_id: int
    plan: LogicalPlan
    tables: Mapping[str, Mapping[str, Any]]
    context: ExecutionContext
    deadline_s: Optional[float] = None
    client_id: int = 0
    priority: int = 1
    submit_t: float = 0.0          # stamped by the queue at admission
    dispatch_t: float = 0.0        # stamped by the service at dispatch

    def expired(self, now: float) -> bool:
        return self.deadline_s is not None and now > self.deadline_s


@dataclass
class QueueStats:
    submitted: int = 0             # offers seen (admitted + rejected)
    admitted: int = 0
    rejected_full: int = 0         # backpressure: queue at max depth
    expired: int = 0               # missed deadline while queued
    dequeued: int = 0              # live requests handed to the service
    shed_overload: int = 0         # evicted lowest-priority-first
    depth: int = 0                 # current
    max_depth_seen: int = 0
    queue_wait_total_s: float = 0.0  # summed over dequeued requests
    by_class: Dict[int, Dict[str, int]] = field(default_factory=dict)

    def copy(self) -> "QueueStats":
        d = dict(self.__dict__)
        d["by_class"] = {p: dict(c) for p, c in self.by_class.items()}
        return QueueStats(**d)


class _ClassBucket:
    """One priority class: per-client FIFOs + a round-robin client ring."""

    def __init__(self) -> None:
        self.clients: Dict[int, deque] = {}
        self.ring: "deque[int]" = deque()     # client_ids, RR order
        self.depth = 0

    def push(self, req: QueryRequest) -> None:
        q = self.clients.get(req.client_id)
        if q is None:
            q = self.clients[req.client_id] = deque()
            self.ring.append(req.client_id)
        q.append(req)
        self.depth += 1

    def pop_newest(self) -> QueryRequest:
        """Evict the newest request of the client with the deepest FIFO
        (shed the flooder's freshest work first)."""
        cid = max(self.clients, key=lambda c: len(self.clients[c]))
        req = self.clients[cid].pop()
        self._gc(cid)
        return req

    def _gc(self, cid: int) -> None:
        self.depth -= 1
        if not self.clients[cid]:
            del self.clients[cid]
            self.ring.remove(cid)


class AdmissionQueue:
    """Bounded priority queue with race-free, exactly-conserving stats."""

    def __init__(self, max_depth: int = 256,
                 shed_watermark: Optional[int] = None,
                 client_weights: Optional[Mapping[int, int]] = None):
        if max_depth < 1:
            raise ValueError("queue needs max_depth >= 1")
        if shed_watermark is not None and shed_watermark < 1:
            raise ValueError("shed_watermark must be >= 1")
        self.max_depth = max_depth
        self.shed_watermark = shed_watermark
        self.client_weights = dict(client_weights or {})
        self._buckets: Dict[int, _ClassBucket] = {}
        self._depth = 0
        self._overload_shed: List[QueryRequest] = []
        self._lock = threading.Lock()
        self._stats = QueueStats()

    def __len__(self) -> int:
        with self._lock:
            return self._depth

    # -- internals (call under self._lock) ----------------------------------
    def _cls(self, priority: int) -> Dict[str, int]:
        return self._stats.by_class.setdefault(
            priority, {"admitted": 0, "dequeued": 0, "expired": 0,
                       "shed": 0, "rejected": 0})

    def _push(self, req: QueryRequest) -> None:
        b = self._buckets.get(req.priority)
        if b is None:
            b = self._buckets[req.priority] = _ClassBucket()
        b.push(req)
        self._depth += 1

    def _shed_lowest_below(self, priority: int) -> Optional[QueryRequest]:
        """Evict from the lowest non-empty class strictly below ``priority``."""
        for p in sorted(self._buckets):
            if p >= priority:
                return None
            b = self._buckets[p]
            if b.depth:
                victim = b.pop_newest()
                self._depth -= 1
                if not b.depth:
                    del self._buckets[p]
                return victim
        return None

    # -- producer side ------------------------------------------------------
    def offer(self, req: QueryRequest,
              now: Optional[float] = None) -> bool:
        """Admit a request; False = rejected (backpressure). Crossing the
        shed watermark evicts a strictly-lower-priority victim instead of
        rejecting a high-priority arrival — collect victims via
        ``pop_overload_shed``."""
        now = time.monotonic() if now is None else now
        with self._lock:
            self._stats.submitted += 1
            limit = self.max_depth
            if self.shed_watermark is not None:
                limit = min(limit, self.shed_watermark)
            if self._depth >= limit:
                victim = (self._shed_lowest_below(req.priority)
                          if self.shed_watermark is not None else None)
                if victim is None:
                    self._stats.rejected_full += 1
                    self._cls(req.priority)["rejected"] += 1
                    return False
                self._stats.shed_overload += 1
                self._cls(victim.priority)["shed"] += 1
                self._overload_shed.append(victim)
            req.submit_t = now
            self._push(req)
            self._stats.admitted += 1
            self._cls(req.priority)["admitted"] += 1
            self._stats.depth = self._depth
            self._stats.max_depth_seen = max(self._stats.max_depth_seen,
                                             self._depth)
            return True

    # -- consumer side ------------------------------------------------------
    def take_batch(self, max_n: int, now: Optional[float] = None
                   ) -> "tuple[List[QueryRequest], List[QueryRequest]]":
        """Dequeue up to ``max_n`` live requests: strict priority order
        across classes, weighted-fair round-robin across clients within a
        class, FIFO per client.

        Returns (live, expired): requests whose deadline passed while
        queued are shed — counted, and handed back so the serving loop can
        report their fate to the submitter instead of dropping silently."""
        now = time.monotonic() if now is None else now
        out: List[QueryRequest] = []
        shed: List[QueryRequest] = []
        with self._lock:
            for p in sorted(self._buckets, reverse=True):
                b = self._buckets.get(p)
                if b is None:
                    continue
                while b.depth and len(out) < max_n:
                    cid = b.ring[0]
                    quota = max(1, self.client_weights.get(cid, 1))
                    q = b.clients[cid]
                    while q and quota > 0 and len(out) < max_n:
                        req = q.popleft()
                        self._depth -= 1
                        self._stats.queue_wait_total_s += now - req.submit_t
                        if tracing.tracing_enabled():
                            # retrospective: the wait is only known at
                            # dequeue, when both stamps exist
                            tracing.tracer().add_complete(
                                "queue.wait", "queue", req.submit_t, now,
                                trace_id=req.req_id, cls=req.priority,
                                expired=req.expired(now))
                        if req.expired(now):
                            self._stats.expired += 1
                            self._cls(req.priority)["expired"] += 1
                            shed.append(req)
                            continue
                        req.dispatch_t = now
                        out.append(req)
                        self._stats.dequeued += 1
                        self._cls(req.priority)["dequeued"] += 1
                        quota -= 1
                    if not q:
                        del b.clients[cid]
                        b.ring.popleft()
                    else:
                        b.ring.rotate(-1)
                    b.depth = sum(len(d) for d in b.clients.values())
                    if not b.depth:
                        del self._buckets[p]
                        break
                if len(out) >= max_n:
                    break
            self._stats.depth = self._depth
        return out, shed

    def shed_expired(self, now: Optional[float] = None
                     ) -> List[QueryRequest]:
        """Sweep and remove every queued request whose deadline has
        passed — called between serving rounds so a request that expired
        while an earlier round was being served is shed promptly (counted
        in ``expired``) instead of waiting to be dequeued late."""
        now = time.monotonic() if now is None else now
        shed: List[QueryRequest] = []
        with self._lock:
            for p in list(self._buckets):
                b = self._buckets[p]
                for cid in list(b.clients):
                    q = b.clients[cid]
                    live = deque(r for r in q if not r.expired(now))
                    n = len(q) - len(live)
                    if n:
                        for r in q:
                            if r.expired(now):
                                shed.append(r)
                                self._stats.expired += 1
                                self._cls(r.priority)["expired"] += 1
                                self._stats.queue_wait_total_s += (
                                    now - r.submit_t)
                                if tracing.tracing_enabled():
                                    tracing.tracer().add_complete(
                                        "queue.wait", "queue",
                                        r.submit_t, now,
                                        trace_id=r.req_id,
                                        cls=r.priority, expired=True)
                        b.clients[cid] = live
                        b.depth -= n
                        self._depth -= n
                        if not live:
                            del b.clients[cid]
                            b.ring.remove(cid)
                if not b.depth:
                    del self._buckets[p]
            self._stats.depth = self._depth
        return shed

    def pop_overload_shed(self) -> List[QueryRequest]:
        """Hand back (and clear) requests evicted by overload shedding."""
        with self._lock:
            out, self._overload_shed = self._overload_shed, []
            return out

    def stats(self) -> QueueStats:
        with self._lock:
            return self._stats.copy()
