"""Multi-query batching: group admitted requests by plan-cache key.

Structurally identical queries — same logical plan, same ExecutionContext,
same table shape signature — resolve to the SAME plan-cache entry, so a
batch of them is one executable dispatched k times (no retrace) or, when
they also reference the same tables mapping, ONE dispatch whose result is
fanned out to every requester (the plan-cache-hot common case of a
dashboard fleet asking the same question). Accounting follows
runtime/serve_loop.ContinuousBatcher's style: a stats dataclass the
facade merges into ServiceStats.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.analytics import planner
from repro.analytics import tracing
from repro.analytics.service.queue import QueryRequest


@dataclass
class BatchStats:
    """Grouping-time counters only. Dispatch outcomes (dispatches issued,
    dedup hits) are counted by the service AFTER a share's task is
    successfully submitted — counting them here would report phantom
    dispatches for shares whose build/submit later fails."""

    batches: int = 0               # plan-cache-key groups formed
    batched_queries: int = 0       # requests that shared a group with >= 1 peer

    def copy(self) -> "BatchStats":
        return BatchStats(**self.__dict__)


@dataclass
class QueryBatch:
    """One plan-cache-key group; ``shares`` sub-groups requests by tables
    identity — each sub-group is a single dispatch fanned out to all of
    its members."""

    key: Tuple
    requests: List[QueryRequest] = field(default_factory=list)
    shares: List[List[QueryRequest]] = field(default_factory=list)


class AdaptiveBatchWindow:
    """Per-round batch-size controller for the always-on serve loop.

    Large rounds amortize grouping/dispatch overhead (QPS under backlog);
    small rounds keep queue-wait — and therefore p99 — low when traffic
    is light. The window doubles while the post-round backlog exceeds it
    (the queue is outrunning the service) and halves on an idle round,
    clamped to [min_batch, max_batch]. Multiplicative in both directions:
    it tracks load swings in O(log) rounds instead of creeping linearly."""

    def __init__(self, min_batch: int = 1, max_batch: int = 64):
        if not 1 <= min_batch <= max_batch:
            raise ValueError("need 1 <= min_batch <= max_batch")
        self.min_batch = min_batch
        self.max_batch = max_batch
        self.window = min_batch

    def observe(self, backlog: int) -> int:
        """Feed the post-round queue depth; returns the next window."""
        if backlog > self.window:
            self.window = min(self.max_batch, self.window * 2)
        elif backlog == 0:
            self.window = max(self.min_batch, self.window // 2)
        return self.window


class QueryBatcher:
    """Stateless grouping; stats accumulate across calls (mutated and
    snapshotted under a lock so a monitoring thread can never observe a
    torn BatchStats — the same race-free-stats guarantee every other
    component in the subsystem gives)."""

    def __init__(self) -> None:
        self._stats = BatchStats()
        self._lock = threading.Lock()

    def stats(self) -> BatchStats:
        with self._lock:
            return self._stats.copy()

    @staticmethod
    def batch_key(req: QueryRequest) -> Tuple:
        """The plan-cache key axis: (plan structure, context, shape
        signature) — deliberately the same triple planner.compile_plan
        caches on, so one batch == one executable."""
        return (req.plan, req.context.cache_key(),
                planner.table_signature(req.tables))

    def group(self, requests: List[QueryRequest]) -> List[QueryBatch]:
        t0 = time.monotonic() if tracing.tracing_enabled() else 0.0
        groups: Dict[Tuple, QueryBatch] = {}
        for req in requests:
            key = self.batch_key(req)
            if key not in groups:
                groups[key] = QueryBatch(key)
            groups[key].requests.append(req)
        with self._lock:
            for batch in groups.values():
                by_tables: Dict[int, List[QueryRequest]] = {}
                for req in batch.requests:
                    by_tables.setdefault(id(req.tables), []).append(req)
                batch.shares = list(by_tables.values())
                self._stats.batches += 1
                if len(batch.requests) > 1:
                    self._stats.batched_queries += len(batch.requests)
        if requests and t0 and tracing.tracing_enabled():
            tracing.tracer().add_complete(
                "batch.group", "batcher", t0, time.monotonic(),
                requests=len(requests), batches=len(groups))
        return list(groups.values())
