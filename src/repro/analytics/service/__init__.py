"""Concurrent query-serving subsystem over the cost-based planner.

The paper's biggest wins come from *placement of work* — thread placement
(Figs 3/4), kernel load balancing, and memory placement (Fig 5) decide
whether memory-intensive operators run near their data. This package is
the serving layer where those effects compound under concurrency:

    submit() -> AdmissionQueue -> QueryBatcher -> MorselScheduler -> pools

  queue.py      bounded admission with deadlines and backpressure stats
  batcher.py    multi-query batching by plan-cache key (structurally
                identical queries execute as one dispatch)
  scheduler.py  morsel-driven scheduling onto socket-pinned worker pools;
                ThreadPlacement (OS_DEFAULT/DENSE/SPARSE) controls
                pool-to-shard affinity, work stealing is the AutoNUMA /
                kernel-load-balancing analog (steals counted)
  service.py    the AnalyticsService facade: submit()/drain(),
                per-query latency + queue-wait histograms, ServiceStats
"""
from repro.analytics.service.batcher import BatchStats, QueryBatcher
from repro.analytics.service.queue import (AdmissionQueue, QueryRequest,
                                           QueueStats)
from repro.analytics.service.scheduler import (MorselScheduler,
                                               SchedulerStats,
                                               ThreadPlacement, WorkerPool)
from repro.analytics.service.service import (AnalyticsService, QueryResult,
                                             ServiceConfig, ServiceStats)

__all__ = [
    "AdmissionQueue", "AnalyticsService", "BatchStats", "MorselScheduler",
    "QueryBatcher", "QueryRequest", "QueryResult", "QueueStats",
    "SchedulerStats", "ServiceConfig", "ServiceStats", "ThreadPlacement",
    "WorkerPool",
]
