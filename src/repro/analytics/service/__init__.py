"""Concurrent query-serving subsystem over the cost-based planner.

The paper's biggest wins come from *placement of work* — thread placement
(Figs 3/4), kernel load balancing, and memory placement (Fig 5) decide
whether memory-intensive operators run near their data. This package is
the serving layer where those effects compound under concurrency:

    submit() -> AdmissionQueue -> QueryBatcher -> MorselScheduler -> pools

  queue.py      bounded admission with priority classes, weighted-fair
                dequeue, deadlines, and overload shedding
  batcher.py    multi-query batching by plan-cache key (structurally
                identical queries execute as one dispatch) + the
                adaptive per-round batching window
  scheduler.py  morsel-driven scheduling onto socket-pinned worker pools;
                ThreadPlacement (OS_DEFAULT/DENSE/SPARSE) controls
                pool-to-shard affinity, work stealing is the AutoNUMA /
                kernel-load-balancing analog (steals counted); pool
                heartbeats, straggler quarantine, and morsel requeue
  faults.py     deterministic fault injection (build failures, wait
                poison, pool kills, stragglers) behind zero-cost hooks
  retry.py      bounded-attempt exponential backoff with deterministic
                jitter, deadline-aware across attempts
  service.py    the AnalyticsService facade: submit()/drain(), the
                always-on background serve loop (start()/stop()),
                retry/recovery, per-class SLO stats, ServiceStats
"""
from repro.analytics.service.batcher import (AdaptiveBatchWindow, BatchStats,
                                             QueryBatcher)
from repro.analytics.service.faults import (InjectedServiceFault,
                                            ServiceFaultInjector)
from repro.analytics.service.queue import (AdmissionQueue, QueryRequest,
                                           QueueStats)
from repro.analytics.service.retry import RetryPolicy
from repro.analytics.service.scheduler import (MorselScheduler,
                                               SchedulerStats,
                                               ThreadPlacement,
                                               WorkerLeakError, WorkerPool)
from repro.analytics.service.service import (AnalyticsService, ClassStats,
                                             QueryResult, ServiceConfig,
                                             ServiceStats)

__all__ = [
    "AdaptiveBatchWindow", "AdmissionQueue", "AnalyticsService",
    "BatchStats", "ClassStats", "InjectedServiceFault", "MorselScheduler",
    "QueryBatcher", "QueryRequest", "QueryResult", "QueueStats",
    "RetryPolicy", "SchedulerStats", "ServiceConfig", "ServiceFaultInjector",
    "ServiceStats", "ThreadPlacement", "WorkerLeakError", "WorkerPool",
]
