"""AnalyticsService: the concurrent query-serving facade.

    service = AnalyticsService(ServiceConfig(...))
    rid = service.submit(plan, tables)          # None => backpressured
    results = service.drain()                   # {req_id: QueryResult}
    service.stats()                             # ServiceStats snapshot

``submit`` is non-blocking admission into the bounded queue; ``drain``
pulls FIFO batches, groups them by plan-cache key (batcher), dispatches
one task per distinct (plan, context, signature, tables) through the
morsel scheduler's socket-pinned pools, and fans shared results out.
Whole-plan dispatch (the default) is bit-identical to serial
``planner.execute_plan`` — it runs the same compiled executable on the
same inputs; setting ``morsel_rows`` turns on intra-query morsel
parallelism for decomposable plans (deterministic merge order, float
summation order differs from the one-pass serial plan).

Latency accounting: per-request queue wait (submit -> dispatch) and
total latency (submit -> result ready) feed p50/p95/p99 histograms in
``ServiceStats`` — the open-loop QPS x tail-latency surface the
fig_service_throughput benchmark measures.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.analytics.plan import LogicalPlan
from repro.analytics.planner import ExecutionContext
from repro.analytics.service.batcher import QueryBatcher
from repro.analytics.service.queue import AdmissionQueue, QueryRequest
from repro.analytics.service.scheduler import (MorselScheduler,
                                               ThreadPlacement)


@dataclass(frozen=True)
class ServiceConfig:
    n_pools: int = 2
    workers_per_pool: int = 2
    queue_depth: int = 256
    max_batch: int = 64            # requests pulled per drain round
    morsel_rows: Optional[int] = None   # None = whole-plan (bit-identical)
    placement: ThreadPlacement = ThreadPlacement.OS_DEFAULT
    batching: bool = True
    steal: bool = True
    # latency/queue-wait histograms keep the most recent N samples: a
    # long-lived service must stay memory-bounded, and the percentiles
    # should reflect CURRENT tail behavior, not be diluted by hours of
    # old samples
    histogram_window: int = 8192


@dataclass
class QueryResult:
    req_id: int
    value: Optional[Dict[str, Any]]     # None => expired or failed
    queue_wait_s: float = 0.0
    latency_s: float = 0.0
    batch_size: int = 1                 # requests served by this dispatch
    expired: bool = False
    error: Optional[str] = None         # execution failure, per dispatch


def _pct(sorted_vals: List[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    return float(np.percentile(np.asarray(sorted_vals), q))


@dataclass
class ServiceStats:
    submitted: int = 0
    admitted: int = 0
    rejected: int = 0
    expired: int = 0
    failed: int = 0
    completed: int = 0
    batches: int = 0
    dispatches: int = 0
    dedup_hits: int = 0
    morsels: int = 0
    steals: int = 0
    steals_per_pool: Tuple[int, ...] = ()
    qps: float = 0.0
    latency_p50_ms: float = 0.0
    latency_p95_ms: float = 0.0
    latency_p99_ms: float = 0.0
    queue_wait_p50_ms: float = 0.0
    queue_wait_p95_ms: float = 0.0
    queue_wait_p99_ms: float = 0.0

    def describe(self) -> str:
        return (f"completed={self.completed}/{self.submitted} "
                f"(rejected={self.rejected}, expired={self.expired}, "
                f"failed={self.failed}) "
                f"dispatches={self.dispatches} dedup={self.dedup_hits} "
                f"steals={self.steals} qps={self.qps:.1f} "
                f"p50={self.latency_p50_ms:.2f}ms "
                f"p99={self.latency_p99_ms:.2f}ms")


class AnalyticsService:
    """Queue -> batcher -> scheduler -> pools, with latency histograms."""

    def __init__(self, config: Optional[ServiceConfig] = None):
        self.config = config or ServiceConfig()
        self.queue = AdmissionQueue(self.config.queue_depth)
        self.batcher = QueryBatcher()
        self.scheduler = MorselScheduler(
            n_pools=self.config.n_pools,
            workers_per_pool=self.config.workers_per_pool,
            placement=self.config.placement,
            morsel_rows=self.config.morsel_rows,
            steal=self.config.steal)
        self._lock = threading.Lock()
        self._next_id = 0
        window = self.config.histogram_window
        self._latencies: "deque[float]" = deque(maxlen=window)
        self._waits: "deque[float]" = deque(maxlen=window)
        self._completed = 0
        self._failed = 0
        self._dispatches = 0       # tasks successfully submitted
        self._dedup_hits = 0       # requests served by a peer's dispatch
        self._busy_s = 0.0         # union of active-drain time (no idle)
        self._active_drains = 0
        self._busy_start = 0.0

    # -- client side --------------------------------------------------------
    def submit(self, plan: LogicalPlan,
               tables: Mapping[str, Mapping[str, Any]], *,
               context: Optional[ExecutionContext] = None,
               deadline_s: Optional[float] = None,
               client_id: int = 0) -> Optional[int]:
        """Admit one query. Returns the request id, or None when the queue
        is full (backpressure — the caller decides whether to retry).
        ``deadline_s`` is RELATIVE seconds from now."""
        with self._lock:
            rid = self._next_id
            self._next_id += 1
        req = QueryRequest(
            req_id=rid, plan=plan, tables=tables,
            context=context or ExecutionContext(),
            deadline_s=(None if deadline_s is None
                        else time.monotonic() + deadline_s),
            client_id=client_id)
        return rid if self.queue.offer(req) else None

    # -- serving loop -------------------------------------------------------
    def drain(self) -> Dict[int, QueryResult]:
        """Serve everything queued AT ENTRY; returns per-request results.

        Pull-based: each round takes up to ``max_batch`` requests, batches
        them, dispatches every (batch, tables-identity) group as one task,
        and waits for the round before pulling the next — queue-wait for
        later requests therefore includes earlier rounds' service time,
        exactly the open-loop backlog the p99 histogram should see. The
        backlog is SNAPSHOTTED at entry: requests admitted while this call
        is serving wait for the next drain, so a submitter keeping pace
        with the service can never pin drain() (and its result dict) in an
        unbounded loop."""
        out: Dict[int, QueryResult] = {}
        t_drain = time.monotonic()
        with self._lock:
            if self._active_drains == 0:
                self._busy_start = t_drain
            self._active_drains += 1
        try:
            self._drain_snapshot(out)
        finally:
            with self._lock:
                self._active_drains -= 1
                if self._active_drains == 0:
                    # busy time is the UNION of active-drain intervals:
                    # overlapping drains must not double-count (qps would
                    # be understated)
                    self._busy_s += time.monotonic() - self._busy_start
        return out

    def _drain_snapshot(self, out: Dict[int, QueryResult]) -> None:
        remaining = len(self.queue)
        while remaining > 0:
            round_reqs, shed = self.queue.take_batch(
                min(self.config.max_batch, remaining))
            remaining -= len(round_reqs) + len(shed)
            now = time.monotonic()
            for req in shed:
                out[req.req_id] = QueryResult(
                    req_id=req.req_id, value=None, expired=True,
                    queue_wait_s=now - req.submit_t,
                    latency_s=now - req.submit_t)
            if not round_reqs:
                if shed:
                    continue        # whole round expired; keep draining
                break
            if self.config.batching:
                batches = self.batcher.group(round_reqs)
                shares = [s for b in batches for s in b.shares]
            else:
                shares = [[r] for r in round_reqs]
            tasks = []
            for share in shares:
                rep = share[0]
                try:
                    # build/submit can raise eagerly (e.g. a plan naming a
                    # table its mapping lacks, caught at morsel decompose):
                    # that failure belongs to THIS share only, never to the
                    # round's other requests
                    task = self.scheduler.build_task(rep.plan, rep.tables,
                                                     rep.context)
                    self.scheduler.submit(task)
                except Exception as e:  # noqa: BLE001 — reported per share
                    now = time.monotonic()
                    err = f"{type(e).__name__}: {e}"
                    with self._lock:
                        self._failed += len(share)
                    for req in share:
                        out[req.req_id] = QueryResult(
                            req_id=req.req_id, value=None, error=err,
                            queue_wait_s=req.dispatch_t - req.submit_t,
                            latency_s=now - req.submit_t,
                            batch_size=len(share))
                    continue
                tasks.append((task, share))
            with self._lock:
                # counted only for shares whose submit SUCCEEDED — a share
                # that failed to build dispatched nothing and deduped nothing
                self._dispatches += len(tasks)
                self._dedup_hits += sum(len(s) - 1 for _, s in tasks)
            for task, share in tasks:
                # fault isolation: one failing dispatch must not discard
                # the round's other results or poison co-submitted clients
                error = None
                try:
                    value = task.wait()
                except Exception as e:  # noqa: BLE001 — reported per request
                    value, error = None, f"{type(e).__name__}: {e}"
                # latency uses the task's own completion stamp, not this
                # loop's join order (a fast query must not inherit a slow
                # peer's wait-loop position)
                done = task.done_t or time.monotonic()
                for req in share:
                    res = QueryResult(
                        req_id=req.req_id,
                        # shallow-copy per client: deduplicated peers must
                        # not see each other's in-place edits (the arrays
                        # inside are immutable and stay shared)
                        value=dict(value) if value is not None else None,
                        queue_wait_s=req.dispatch_t - req.submit_t,
                        latency_s=done - req.submit_t,
                        batch_size=len(share), error=error)
                    out[req.req_id] = res
                    with self._lock:
                        if error is None:
                            self._completed += 1
                            self._latencies.append(res.latency_s)
                            self._waits.append(res.queue_wait_s)
                        else:
                            self._failed += 1

    # -- stats --------------------------------------------------------------
    def stats(self) -> ServiceStats:
        qs = self.queue.stats()
        bs = self.batcher.stats()
        ss = self.scheduler.stats()
        with self._lock:
            lat = list(self._latencies)
            waits = list(self._waits)
            completed = self._completed
            failed = self._failed
            dispatches = self._dispatches
            dedup_hits = self._dedup_hits
            busy = self._busy_s
            if self._active_drains > 0:   # include the in-progress drain
                busy += time.monotonic() - self._busy_start
        return ServiceStats(
            submitted=qs.submitted, admitted=qs.admitted,
            rejected=qs.rejected_full, expired=qs.expired,
            failed=failed, completed=completed, batches=bs.batches,
            dispatches=dispatches, dedup_hits=dedup_hits,
            morsels=ss.morsels_dispatched, steals=ss.steals,
            steals_per_pool=ss.steals_per_pool,
            qps=(completed / busy) if busy > 0 else 0.0,
            latency_p50_ms=_pct(lat, 50) * 1e3,
            latency_p95_ms=_pct(lat, 95) * 1e3,
            latency_p99_ms=_pct(lat, 99) * 1e3,
            queue_wait_p50_ms=_pct(waits, 50) * 1e3,
            queue_wait_p95_ms=_pct(waits, 95) * 1e3,
            queue_wait_p99_ms=_pct(waits, 99) * 1e3)

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        self.scheduler.close()

    def __enter__(self) -> "AnalyticsService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
